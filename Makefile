# Convenience targets; see README.md for details.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test check-docs bench bench-quick

# Tier-1 verification: the full test suite plus the doc-link check.
verify: test check-docs

test:
	$(PYTHON) -m pytest -x -q

check-docs:
	$(PYTHON) tools/check_docs.py

bench:
	$(PYTHON) benchmarks/run.py

bench-quick:
	REPRO_BENCH_QUICK=1 $(PYTHON) benchmarks/run.py
