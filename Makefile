# Convenience targets; see README.md for details.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test lint check-bench check-docs bench bench-quick

# Tier-1 verification: the full test suite plus the static checks.
verify: test lint check-bench check-docs

test:
	$(PYTHON) -m pytest -x -q

# dyslint: the AST-based invariant linter (tools/lint/).  Needs only a
# bare Python — no numpy/jax import happens during linting.
lint:
	$(PYTHON) tools/lint/runner.py

check-bench:
	$(PYTHON) tools/check_bench.py

check-docs:
	$(PYTHON) tools/check_docs.py

bench:
	$(PYTHON) benchmarks/run.py

bench-quick:
	REPRO_BENCH_QUICK=1 $(PYTHON) benchmarks/run.py
