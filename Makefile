# Convenience targets; see README.md for details.

PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test lint pin-map check-bench check-docs bench bench-quick

# Tier-1 verification: the full test suite plus the static checks.
verify: test lint check-bench check-docs

test:
	$(PYTHON) -m pytest -x -q

# dyslint + dyflow: the AST-based invariant linter (tools/lint/) —
# per-module passes in parallel, plus the whole-program units and
# pin-impact passes.  Needs only a bare Python — no numpy/jax import
# happens during linting.
lint:
	$(PYTHON) tools/lint/runner.py --jobs 0

# Regenerate the committed pin-impact map after changing pin-covered
# code or the PINS declarations (lint fails while it is stale).
pin-map:
	$(PYTHON) tools/lint/runner.py --write-pin-map

check-bench:
	$(PYTHON) tools/check_bench.py

check-docs:
	$(PYTHON) tools/check_docs.py

bench:
	$(PYTHON) benchmarks/run.py

bench-quick:
	REPRO_BENCH_QUICK=1 $(PYTHON) benchmarks/run.py
