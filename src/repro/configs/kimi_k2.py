"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 384 experts top-8.
[arXiv:2501.kimi2 paper-table; unverified]
61L d_model=7168 64H (kv=8) d_ff=2048 vocab=163840, MoE 384e top-8."""

from repro.config.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    rope_style="full",
    rope_theta=5e6,
    norm="rmsnorm",
    mlp_act="swiglu",
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048, layout="all"),
    optimizer="adafactor",      # 1T params: factored states, bf16 params
    dtype="bfloat16",
)
