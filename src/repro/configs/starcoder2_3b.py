"""starcoder2-3b [dense] — GQA, RoPE.
[arXiv:2402.19173; hf]  30L d_model=3072 24H (kv=2) d_ff=12288 vocab=49152."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    rope_style="full",
    rope_theta=1e6,
    norm="layernorm",
    mlp_act="gelu",
    qkv_bias=True,
    optimizer="adamw",
)
