"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_style="full",
    rope_theta=1e6,
    norm="rmsnorm",
    mlp_act="swiglu",
    num_patches=256,            # stubbed patch embeddings per sample
    optimizer="adamw",
)
