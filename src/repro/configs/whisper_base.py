"""whisper-base [audio] — enc-dec, conv frontend stubbed.
[arXiv:2212.04356; unverified]  6L(dec)+6L(enc) d_model=512 8H (kv=8)
d_ff=2048 vocab=51865."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    rope_style="none",          # sinusoidal positions (see DESIGN.md)
    norm="layernorm",
    mlp_act="gelu",
    tie_embeddings=True,
    encoder_layers=6,
    encoder_len=1500,           # 30 s audio → 1500 frames post-conv (stub)
    optimizer="adamw",
)
