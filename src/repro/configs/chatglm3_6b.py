"""chatglm3-6b [dense] — 2-d RoPE (half rotary), GQA kv=2.
[arXiv:2406.12793; hf]  28L d_model=4096 32H (kv=2) d_ff=13696 vocab=65024."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_style="half",          # ChatGLM 2-d RoPE: rotate half the head dim
    norm="rmsnorm",
    mlp_act="swiglu",
    qkv_bias=True,              # chatglm uses qkv bias
    optimizer="adamw",
)
