"""granite-20b [dense] — llama-arch code model, MQA (kv=1).
[arXiv:2405.04324; hf]  52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,             # MQA
    d_ff=24576,
    vocab_size=49152,
    rope_style="none",          # gpt-bigcode uses learned abs pos; we use
                                # none+sinusoidal-free (documented deviation)
    norm="layernorm",
    mlp_act="gelu",
    optimizer="adamw",
)
