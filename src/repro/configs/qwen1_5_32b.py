"""qwen1.5-32b [dense] — QKV bias, near-MHA (kv=40).
[hf:Qwen/Qwen1.5-0.5B scaled family config; hf]
64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064."""

from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    rope_style="full",
    rope_theta=1e6,
    norm="rmsnorm",
    mlp_act="swiglu",
    qkv_bias=True,
    kv_cache_dtype="int8",
    optimizer="adamw",
)
