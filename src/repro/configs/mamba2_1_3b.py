"""mamba2-1.3b [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]
48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128."""

from repro.config.base import ArchConfig, MambaConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,                # attention-free
    num_kv_heads=0,
    d_ff=0,                     # no separate MLP (mamba block only)
    vocab_size=50280,
    rope_style="none",
    norm="rmsnorm",
    tie_embeddings=True,
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                      chunk=128),
    optimizer="adamw",
    sub_quadratic=True,         # runs long_500k
)
