"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer. [arXiv:2403.19887; hf]
72L d_model=8192 64H (kv=8) d_ff=24576 vocab=65536."""

from repro.config.base import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    rope_style="none",          # Jamba uses no positional encoding
    norm="rmsnorm",
    mlp_act="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=24576,
                  layout="every_other"),
    mamba=MambaConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                      chunk=128),
    attn_period=8,              # 1 attention : 7 mamba
    attn_offset=3,
    optimizer="adafactor",      # 398B: factored states, bf16 params
    dtype="bfloat16",
    sub_quadratic=True,         # runs long_500k
)
