"""One module per assigned architecture (exact public-literature configs)."""
