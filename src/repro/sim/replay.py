"""Replay harness: run workload suites under competing strategies and
aggregate the statistics the paper reports (mean / P99 latency deltas,
utilization, redistribution-applied fraction)."""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.engine import ClusterConfig, QueryResult, Simulator, StrategyConfig
from repro.sim.workload import QueryProfile, generate_query

# Strategy resolution for the legacy-vs-DySkew A/B the paper evaluates:
#
#   legacy: static round-robin for queries where it is safe; the default
#           1:1 link for locality-constrained queries (§II.B: the static
#           solution 'cannot be safely applied to all Snowpark UDF use
#           cases').
#   dyskew: the adaptive link with the query's declared policy (Eager for
#           ordinary Snowpark UDFs, Distribute-Late for
#           locality-constrained plans, Never where ordering forbids it).


def legacy_strategy(prof: QueryProfile) -> StrategyConfig:
    if prof.locality_constrained or prof.policy == Policy.NEVER:
        return StrategyConfig(kind="none")
    return StrategyConfig(kind="static_rr")


def dyskew_strategy(prof: QueryProfile) -> StrategyConfig:
    policy = prof.policy
    if prof.locality_constrained and policy == Policy.EAGER_SNOWPARK:
        policy = Policy.LATE
    model = (
        SkewModelKind.IDLE_TIME
        if policy in (Policy.LATE, Policy.EAGER_SNOWPARK)
        else SkewModelKind.ROW_PERCENTAGE
    )
    return StrategyConfig(
        kind="dyskew",
        dyskew=DySkewConfig(policy=policy, skew_model=model, n_strikes=2),
    )


def default_strategies() -> Dict[str, StrategyConfig]:
    return {
        "none": StrategyConfig(kind="none"),
        "static_rr": StrategyConfig(kind="static_rr"),
        "dyskew": StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, idle_grace=2),
        ),
    }


@dataclasses.dataclass
class SuiteResult:
    strategy: str
    results: List[QueryResult]

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.results])

    def mean_latency(self) -> float:
        return float(self.latencies.mean())

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))

    def mean_utilization(self) -> float:
        return float(np.mean([r.utilization for r in self.results]))

    def applied_fraction(self) -> float:
        return float(np.mean([r.redistribution_applied for r in self.results]))


def scan_arrival_gap(
    prof: QueryProfile, cluster: ClusterConfig, feed_factor: float = 2.0
) -> float:
    """Backpressured-scan model: batches arrive spread over the query's
    ideal (perfectly balanced) duration, `feed_factor`x faster than the
    workers can drain them in aggregate."""
    ideal = prof.n_rows * prof.mean_row_cost / cluster.num_workers
    nbatches = max(prof.n_rows // min(prof.batch_rows, prof.n_rows), 1)
    return ideal / (feed_factor * nbatches)


def run_suite(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    strategy: StrategyConfig,
    seed: int = 0,
    per_query_strategy: Optional[Dict[str, StrategyConfig]] = None,
    feed_factor: float = 2.0,
) -> SuiteResult:
    results = []
    for i, prof in enumerate(profiles):
        st = strategy
        if per_query_strategy and prof.name in per_query_strategy:
            st = per_query_strategy[prof.name]
        sim = Simulator(cluster, st, seed=seed + i)
        batches = generate_query(prof, cluster.num_workers, seed=seed * 1000 + i)
        gap = scan_arrival_gap(prof, cluster, feed_factor)
        results.append(sim.run_query(batches, arrival_gap=gap))
    return SuiteResult(strategy=strategy.kind, results=results)


def improvement(base: float, new: float) -> float:
    """Positive = new is faster, as a fraction of base."""
    return (base - new) / base


def compare_suites(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    strategies: Dict[str, StrategyConfig],
    seed: int = 0,
) -> Dict[str, SuiteResult]:
    return {
        name: run_suite(profiles, cluster, st, seed=seed)
        for name, st in strategies.items()
    }


def run_ab(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    seed: int = 0,
    feed_factor: float = 2.0,
) -> Dict[str, SuiteResult]:
    """The paper's A/B: legacy system vs DySkew, with per-query strategy
    resolution (locality constraints, declared policies)."""
    out: Dict[str, SuiteResult] = {}
    for name, resolve in (("legacy", legacy_strategy), ("dyskew", dyskew_strategy)):
        results = []
        for i, prof in enumerate(profiles):
            st = resolve(prof)
            sim = Simulator(cluster, st, seed=seed + i)
            batches = generate_query(prof, cluster.num_workers, seed=seed * 1000 + i)
            gap = scan_arrival_gap(prof, cluster, feed_factor)
            results.append(sim.run_query(batches, arrival_gap=gap))
        out[name] = SuiteResult(strategy=name, results=results)
    return out
