"""Replay harness: run workload suites under competing strategies and
aggregate the statistics the paper reports (mean / P99 latency deltas,
utilization, redistribution-applied fraction), plus the multi-tenant
traffic studies: closed-loop staggered tenants, open-loop Poisson/burst
streams with priority classes, per-class p50/p99/p999 tails and Jain's
fairness index over per-tenant slowdowns."""

from __future__ import annotations

import dataclasses
import multiprocessing
import warnings
from concurrent.futures import ProcessPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.admission import (
    AutoscaleConfig,
    DeadlineConfig,
    FairShareConfig,
)
from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.engine import (
    ClusterConfig,
    MultiQuerySimulator,
    QueryResult,
    Simulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.workload import (
    ArrivalProcess,
    QueryProfile,
    arrival_times,
    generate_query,
    generate_query_cached,
)

# Strategy resolution for the legacy-vs-DySkew A/B the paper evaluates:
#
#   legacy: static round-robin for queries where it is safe; the default
#           1:1 link for locality-constrained queries (§II.B: the static
#           solution 'cannot be safely applied to all Snowpark UDF use
#           cases').
#   dyskew: the adaptive link with the query's declared policy (Eager for
#           ordinary Snowpark UDFs, Distribute-Late for
#           locality-constrained plans, Never where ordering forbids it).


def legacy_strategy(prof: QueryProfile) -> StrategyConfig:
    if prof.locality_constrained or prof.policy == Policy.NEVER:
        return StrategyConfig(kind="none")
    return StrategyConfig(kind="static_rr")


def dyskew_strategy(prof: QueryProfile) -> StrategyConfig:
    policy = prof.policy
    if prof.locality_constrained and policy == Policy.EAGER_SNOWPARK:
        policy = Policy.LATE
    model = (
        SkewModelKind.IDLE_TIME
        if policy in (Policy.LATE, Policy.EAGER_SNOWPARK)
        else SkewModelKind.ROW_PERCENTAGE
    )
    return StrategyConfig(
        kind="dyskew",
        dyskew=DySkewConfig(policy=policy, skew_model=model, n_strikes=2),
    )


def default_strategies() -> Dict[str, StrategyConfig]:
    return {
        "none": StrategyConfig(kind="none"),
        "static_rr": StrategyConfig(kind="static_rr"),
        "dyskew": StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, idle_grace=2),
        ),
    }


@dataclasses.dataclass
class SuiteResult:
    strategy: str
    results: List[QueryResult]

    @property
    def latencies(self) -> np.ndarray:
        return np.array([r.latency for r in self.results])

    def mean_latency(self) -> float:
        return float(self.latencies.mean())

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))

    def mean_utilization(self) -> float:
        return float(np.mean([r.utilization for r in self.results]))

    def applied_fraction(self) -> float:
        return float(np.mean([r.redistribution_applied for r in self.results]))


def scan_arrival_gap(
    prof: QueryProfile, cluster: ClusterConfig, feed_factor: float = 2.0
) -> float:
    """Backpressured-scan model: batches arrive spread over the query's
    ideal (perfectly balanced) duration, `feed_factor`x faster than the
    workers can drain them in aggregate."""
    ideal = prof.n_rows * prof.mean_row_cost / cluster.num_workers
    nbatches = max(prof.n_rows // min(prof.batch_rows, prof.n_rows), 1)
    return ideal / (feed_factor * nbatches)


def _run_one_query(
    task: Tuple[QueryProfile, ClusterConfig, StrategyConfig, int, int, float],
) -> QueryResult:
    """One (profile, strategy) simulation — top-level so suite runs can
    fan out across a process pool."""
    prof, cluster, st, sim_seed, gen_seed, gap = task
    sim = Simulator(cluster, st, seed=sim_seed)
    batches = generate_query_cached(prof, cluster.num_workers, seed=gen_seed)
    return sim.run_query(batches, arrival_gap=gap)


_POOL: Optional[ProcessPoolExecutor] = None
_POOL_WORKERS = 0


def _get_pool(workers: int) -> ProcessPoolExecutor:
    """Lazily-created shared pool — spawned workers pay the jax import
    once per process, not once per suite."""
    global _POOL, _POOL_WORKERS
    if _POOL is None or _POOL_WORKERS < workers:
        if _POOL is not None:
            # Reap the replaced pool's processes before spawning the
            # larger one — wait=False here leaked live spawned workers
            # for the rest of the run.
            _POOL.shutdown(wait=True)
        ctx = multiprocessing.get_context("spawn")
        _POOL = ProcessPoolExecutor(max_workers=workers, mp_context=ctx)
        _POOL_WORKERS = workers
    return _POOL


def _discard_pool() -> None:
    """Drop the cached pool after a failure so the next `_map_queries`
    call rebuilds a fresh one.  Keeping the broken executor cached made a
    single failure permanent: every later suite re-raised inside ``map``,
    warned, and silently degraded to the serial path for the remainder of
    the process."""
    global _POOL, _POOL_WORKERS
    if _POOL is not None:
        try:
            _POOL.shutdown(wait=False, cancel_futures=True)
        except Exception:
            pass  # the executor may already be unusable/broken
    _POOL = None
    _POOL_WORKERS = 0


def _map_queries(
    tasks: List[Tuple], workers: Optional[int]
) -> List[QueryResult]:
    """Run simulation tasks, optionally on a 'spawn' process pool.

    Queries are independent, so results are deterministic regardless of
    ``workers``; any pool failure (restricted sandboxes) falls back to the
    serial path for THIS call and discards the broken pool, so the next
    call gets a fresh executor instead of inheriting the failure.
    """
    if workers and workers > 1 and len(tasks) > 1:
        try:
            # Small chunks: per-query cost varies by >10x, so fine-grained
            # scheduling beats lower dispatch overhead.  Even-sized chunks
            # keep run_ab's interleaved legacy/dyskew pairs in the same
            # worker process, so its per-process stream cache hits.
            chunk = max(len(tasks) // (workers * 10), 1)
            chunk += chunk % 2
            return list(
                _get_pool(workers).map(_run_one_query, tasks, chunksize=chunk)
            )
        except Exception as e:  # pool infra failure (spawn blocked, OOM-killed worker)
            _discard_pool()
            warnings.warn(
                f"simulation pool failed ({type(e).__name__}: {e}); "
                "re-running suite serially (pool reset for the next call)",
                RuntimeWarning,
            )
    return [_run_one_query(t) for t in tasks]


def _warm_worker() -> bool:
    """No-op task that forces a spawned worker to pay its heavy imports."""
    return True


def _surface_warm_failure(future) -> None:
    """Done-callback for warm-up tasks: a worker that crashes during the
    jax warm-import used to be silently dropped (futures discarded) and
    resurfaced later as an opaque suite failure — surface it now."""
    if future.cancelled():
        # Pool torn down (e.g. _discard_pool after a map failure) before
        # the warm task ran: not a worker crash, nothing to surface —
        # and future.exception() would raise CancelledError here.
        return
    exc = future.exception()
    if exc is not None:
        warnings.warn(
            f"pool warm-up worker failed ({type(exc).__name__}: {exc}); "
            "parallel replay may fall back to serial",
            RuntimeWarning,
        )


def warm_pool(workers: Optional[int]) -> list:
    """Kick off worker-process startup (jax import) in the background so
    it overlaps the caller's own setup.  Non-blocking; best-effort.  The
    warm-up futures are collected (and returned, mainly for tests): the
    first crash is surfaced as a RuntimeWarning instead of being
    swallowed."""
    futures: list = []
    if workers and workers > 1:
        try:
            pool = _get_pool(workers)
            for _ in range(workers):
                f = pool.submit(_warm_worker)
                f.add_done_callback(_surface_warm_failure)
                futures.append(f)
        except Exception:
            pass
    return futures


def run_suite(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    strategy: StrategyConfig,
    seed: int = 0,
    per_query_strategy: Optional[Dict[str, StrategyConfig]] = None,
    feed_factor: float = 2.0,
    workers: Optional[int] = None,
) -> SuiteResult:
    tasks = []
    for i, prof in enumerate(profiles):
        st = strategy
        if per_query_strategy and prof.name in per_query_strategy:
            st = per_query_strategy[prof.name]
        gap = scan_arrival_gap(prof, cluster, feed_factor)
        tasks.append((prof, cluster, st, seed + i, seed * 1000 + i, gap))
    return SuiteResult(
        strategy=strategy.kind, results=_map_queries(tasks, workers)
    )


def improvement(base: float, new: float) -> float:
    """Positive = new is faster, as a fraction of base."""
    return (base - new) / base


def compare_suites(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    strategies: Dict[str, StrategyConfig],
    seed: int = 0,
) -> Dict[str, SuiteResult]:
    return {
        name: run_suite(profiles, cluster, st, seed=seed)
        for name, st in strategies.items()
    }


def run_ab(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    seed: int = 0,
    feed_factor: float = 2.0,
    workers: Optional[int] = None,
) -> Dict[str, SuiteResult]:
    """The paper's A/B: legacy system vs DySkew, with per-query strategy
    resolution (locality constraints, declared policies)."""
    arms = (("legacy", legacy_strategy), ("dyskew", dyskew_strategy))
    # Both arms in ONE submission (no pool idle at the barrier), with the
    # two arms of each query adjacent so a pool worker re-uses the cached
    # generated streams for the pair.
    tasks = []
    for i, prof in enumerate(profiles):
        gap = scan_arrival_gap(prof, cluster, feed_factor)
        for name, resolve in arms:
            tasks.append(
                (prof, cluster, resolve(prof), seed + i, seed * 1000 + i, gap)
            )
    results = _map_queries(tasks, workers)
    return {
        name: SuiteResult(strategy=name, results=results[j::len(arms)])
        for j, (name, _) in enumerate(arms)
    }


# ------------------------------------------------------------------ #
# Multi-tenant replay (concurrent queries on one shared cluster)
# ------------------------------------------------------------------ #


def staggered_tenants(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    resolve: Callable[[QueryProfile], StrategyConfig],
    seed: int = 0,
    stagger_frac: float = 0.25,
    feed_factor: float = 2.0,
) -> List[TenantQuery]:
    """Materialize one tenant per profile with arrivals staggered by
    ``stagger_frac`` of the mean ideal query duration, so neighbouring
    queries genuinely overlap on the shared cluster."""
    ideals = [
        p.n_rows * p.mean_row_cost / cluster.num_workers for p in profiles
    ]
    stagger = stagger_frac * float(np.mean(ideals)) if ideals else 0.0
    tenants = []
    for i, prof in enumerate(profiles):
        tenants.append(TenantQuery(
            name=prof.name,
            streams=generate_query(prof, cluster.num_workers,
                                   seed=seed * 1000 + i),
            strategy=resolve(prof),
            arrival=i * stagger,
            arrival_gap=scan_arrival_gap(prof, cluster, feed_factor),
        ))
    return tenants


def run_multi_tenant_ab(
    profiles: Sequence[QueryProfile],
    cluster: ClusterConfig,
    seed: int = 0,
    stagger_frac: float = 0.25,
    feed_factor: float = 2.0,
    fair_share: Optional[FairShareConfig] = None,
    weights: Optional[Sequence[float]] = None,
) -> Dict[str, SuiteResult]:
    """Legacy vs DySkew with all ``profiles`` running CONCURRENTLY as
    tenants of one shared cluster (same streams, same arrival schedule).
    ``fair_share``/``weights`` switch on the weighted admission layer."""
    out: Dict[str, SuiteResult] = {}
    for name, resolve in (("legacy", legacy_strategy), ("dyskew", dyskew_strategy)):
        tenants = staggered_tenants(
            profiles, cluster, resolve, seed=seed,
            stagger_frac=stagger_frac, feed_factor=feed_factor,
        )
        if weights is not None:
            if len(weights) != len(tenants):
                raise ValueError(
                    f"weights length {len(weights)} != tenant count "
                    f"{len(tenants)}"
                )
            for t, w in zip(tenants, weights):
                t.weight = float(w)
        results = MultiQuerySimulator(cluster, fair_share=fair_share).run(tenants)
        out[name] = SuiteResult(strategy=name, results=results)
    return out


# ------------------------------------------------------------------ #
# Open-loop traffic (Poisson / burst arrivals, priority classes)
# ------------------------------------------------------------------ #


def jain_fairness(values: Sequence[float]) -> float:
    """Jain's fairness index (sum x)^2 / (n * sum x^2): 1.0 = perfectly
    even, 1/n = one value holds everything.

    An empty or all-zero set (e.g. a run in which no query of a priority
    class completed) has no defined fairness — there is nothing to share —
    and returns NaN rather than crashing on the 0/0 or masquerading as
    perfectly fair.  NaN propagates visibly through aggregations, which
    is the point: a report showing NaN says 'no completions', not 1.0."""
    x = np.asarray(list(values), dtype=np.float64)
    if len(x) == 0 or not np.any(x):
        return float("nan")
    return float(x.sum() ** 2 / (len(x) * (x ** 2).sum()))


def tenant_class(t: TenantQuery) -> str:
    """Class key of an open-loop tenant (name is '<class>#<arrival_idx>'
    for generated traffic; standalone tenants are their own class)."""
    return t.name.split("#", 1)[0]


def ideal_latency(t: TenantQuery, cluster: ClusterConfig) -> float:
    """Perfectly-balanced lower bound: total hidden UDF seconds spread
    over every interpreter in the warehouse."""
    total_cost = sum(float(b.costs.sum()) for s in t.streams for b in s)
    return total_cost / cluster.num_workers


def open_loop_rate(
    profiles: Sequence[QueryProfile], cluster: ClusterConfig,
    load: float = 0.7,
) -> float:
    """Arrival rate (queries/s) that offers ``load`` fraction of the
    cluster's aggregate service capacity, for the given query mix."""
    work = [p.n_rows * p.mean_row_cost for p in profiles]
    return load * cluster.num_workers / float(np.mean(work))


def open_loop_tenants(
    specs: Sequence[Tuple],
    cluster: ClusterConfig,
    resolve: Callable[[QueryProfile], StrategyConfig],
    process: ArrivalProcess,
    num_queries: int,
    seed: int = 0,
    feed_factor: float = 2.0,
    grid_align: Optional[float] = None,
) -> List[TenantQuery]:
    """Materialize an open-loop query stream: ``num_queries`` arrivals at
    :func:`arrival_times` timestamps, cycling over ``specs`` —
    (profile, fair-share weight) pairs, e.g. from
    `workload.priority_class_suite`, or (profile, weight, slo_target)
    triples, e.g. from `workload.slo_suite` (the target becomes each
    arrival's `TenantQuery.slo_target`, seconds from arrival).  Each
    arrival is an independent tenant (fresh streams, own link state)
    named '<profile>#<index>'.

    ``grid_align`` snaps every arrival down onto the chained float grid
    ``0, I, I+I, ...`` of that step — the engine's metrics subsystem
    quantizes observation to tick boundaries anyway, and arrivals that
    sit exactly on a shared tick grid put the whole fleet inside the
    PROVEN batched-tick equivalence envelope (`sim/engine.py`'s
    ``_arrivals_on_grid``), so `MultiQuerySimulator`'s auto default
    drives hundreds of link tenants through one coalesced jitted tick
    per cadence while staying bit-identical to the per-tenant path.
    The grid values are built by the same chained additions the engine's
    grid-tick event walks, so the float equality is exact by
    construction, not approximate."""
    times = arrival_times(process, num_queries, seed=seed + 977)
    if grid_align is not None and num_queries:
        step = float(grid_align)
        kmax = int(np.floor(float(times.max()) / step)) + 1
        chain = np.empty(kmax + 1)
        t = 0.0
        for k in range(kmax + 1):
            chain[k] = t
            t += step
        idx = np.searchsorted(chain, times, side="right") - 1
        times = chain[np.clip(idx, 0, kmax)]
    tenants: List[TenantQuery] = []
    for i in range(num_queries):
        spec = specs[i % len(specs)]
        prof, weight = spec[0], spec[1]
        slo = spec[2] if len(spec) > 2 else None
        tenants.append(TenantQuery(
            name=f"{prof.name}#{i:03d}",
            streams=generate_query(prof, cluster.num_workers,
                                   seed=seed * 1000 + i),
            strategy=resolve(prof),
            arrival=float(times[i]),
            arrival_gap=scan_arrival_gap(prof, cluster, feed_factor),
            weight=weight,
            slo_target=slo,
        ))
    return tenants


def summarize_open_loop(
    tenants: Sequence[TenantQuery],
    results: Sequence[QueryResult],
    cluster: ClusterConfig,
    fault_stats: Optional[Dict[str, object]] = None,
) -> Dict[str, object]:
    """Aggregate an open-loop run into the numbers the multi-tenant bench
    reports: per-class latency percentiles (p50/p99/p999) + mean
    slowdown, Jain's fairness index over per-tenant slowdowns
    (latency / perfectly-balanced ideal; equal slowdowns = fair), and —
    for tenants that declare an `slo_target` — per-class SLO attainment
    (fraction of completed queries whose latency met the deadline) and
    p99 tardiness (seconds past the deadline, 0 when met).

    Honest economics: ``worker_seconds_spent`` is every second a worker
    was busy — including service voided by a crash (from
    ``fault_stats['wasted_service_s']`` when supplied) and the charged
    re-execution after it — and ``cost_per_slo`` divides that spend by
    the SLO-met count, so a policy that buys attainment by burning
    workers is visible on the frontier next to one that meets the same
    deadlines cheaply."""
    classes: Dict[str, List[Tuple[float, float]]] = {}
    # Per class: met flags (incl. never-completed = missed) and the
    # tardiness samples of COMPLETED queries only.
    slo_by_class: Dict[str, Dict[str, list]] = {}
    slowdowns: List[float] = []
    slo_met = slo_total = 0
    for t, r in zip(tenants, results):
        cls = classes.setdefault(tenant_class(t), [])
        sb = (
            slo_by_class.setdefault(
                tenant_class(t), {"met": [], "tard": []}
            )
            if t.slo_target is not None else None
        )
        if r is None:
            # Tenant did not complete (aborted/partial run): its class
            # still appears in the report, with n=0 and NaN latency
            # stats — but a deadline it can no longer meet is a MISS,
            # not a gap in the books (otherwise a truncated run looks
            # better than one that finished its work).
            if sb is not None:
                sb["met"].append(False)
                slo_total += 1
            continue
        ideal = max(ideal_latency(t, cluster), 1e-12)
        sd = r.latency / ideal
        slowdowns.append(sd)
        cls.append((r.latency, sd))
        if sb is not None:
            met = r.latency <= t.slo_target
            sb["met"].append(met)
            sb["tard"].append(max(r.latency - t.slo_target, 0.0))
            slo_total += 1
            slo_met += int(met)
    nan = float("nan")
    per_class: Dict[str, Dict[str, float]] = {}
    for name, vals in sorted(classes.items()):
        lat = np.array([v[0] for v in vals])
        sds = np.array([v[1] for v in vals])
        # A class with zero completed queries reports NaN percentiles
        # (np.percentile on an empty array raises) — NaN means 'no
        # completions to measure', same convention as jain_fairness.
        empty = len(vals) == 0
        per_class[name] = {
            "n": len(vals),
            "p50": nan if empty else float(np.percentile(lat, 50)),
            "p99": nan if empty else float(np.percentile(lat, 99)),
            "p999": nan if empty else float(np.percentile(lat, 99.9)),
            "mean": nan if empty else float(lat.mean()),
            "mean_slowdown": nan if empty else float(sds.mean()),
        }
        if name in slo_by_class:
            sb = slo_by_class[name]
            per_class[name]["slo_attainment"] = (
                float(np.mean(sb["met"])) if sb["met"] else nan
            )
            # Tardiness is measurable only for completed queries.
            per_class[name]["p99_tardiness"] = (
                float(np.percentile(np.array(sb["tard"]), 99))
                if sb["tard"] else nan
            )
    # Worker-seconds actually spent: useful service billed to every
    # tenant, plus (with faults) the partial service crashes voided —
    # re-executed rows bill their second pass through per_worker_busy,
    # so wasted + billed is the true spend, never double-counted.
    worker_seconds = float(sum(
        float(np.asarray(r.per_worker_busy).sum())
        for r in results if r is not None
    ))
    if fault_stats is not None:
        worker_seconds += float(fault_stats.get("wasted_service_s", 0.0))
    return {
        "per_class": per_class,
        "jain": jain_fairness(slowdowns),
        "mean_latency": (
            float(np.mean([r.latency for r in results if r is not None]))
            if any(r is not None for r in results) else nan
        ),
        "slo_attainment": (slo_met / slo_total) if slo_total else nan,
        "slo_met_count": slo_met,
        "worker_seconds_spent": worker_seconds,
        # Spend per met SLO (inf when nothing met): the frontier metric.
        "cost_per_slo": (
            worker_seconds / slo_met if slo_met else float("inf")
        ),
    }


def run_open_loop(
    specs: Sequence[Tuple],
    cluster: ClusterConfig,
    process: ArrivalProcess,
    num_queries: int,
    seed: int = 0,
    resolve: Callable[[QueryProfile], StrategyConfig] = dyskew_strategy,
    fair_share: Optional[FairShareConfig] = None,
    feed_factor: float = 2.0,
    batch_ticks: Optional[bool] = None,
    none_closed_form: Optional[bool] = None,
    closed_form_drain: Optional[bool] = None,
    grid_align: Optional[float] = None,
    deadline_aware: bool = False,
    deadline_cfg: Optional["DeadlineConfig"] = None,
    preemption: bool = False,
    autoscale: Optional["AutoscaleConfig"] = None,
    faults: Optional["FaultSchedule"] = None,
    fault_cfg: Optional["FaultConfig"] = None,
    sim_seed: int = 0,
) -> Dict[str, object]:
    """One open-loop scenario end to end: materialize the arrival stream,
    run it on one shared cluster (optionally under fair-share admission),
    and summarize per-class tails + fairness (+ SLO attainment/tardiness
    when ``specs`` carry slo targets).  ``batch_ticks`` /
    ``none_closed_form`` / ``closed_form_drain`` and the SLO-layer flags
    (``deadline_aware`` / ``deadline_cfg`` / ``preemption`` /
    ``autoscale``) forward to :class:`MultiQuerySimulator`;
    ``grid_align`` snaps arrivals onto a shared tick grid (see
    :func:`open_loop_tenants`), which puts a homogeneous fleet inside
    the batched-tick auto envelope — the many-tenant bench relies on
    this so hundreds of tenants batch BY DEFAULT.  The run's per-kind
    event counters are returned under ``"event_counts"`` and its resize
    log under ``"resizes"``.  ``sim_seed`` feeds the engine's per-tenant
    policy RNG streams (stochastic registry policies; the deterministic
    built-ins never consult theirs)."""
    tenants = open_loop_tenants(
        specs, cluster, resolve, process, num_queries, seed=seed,
        feed_factor=feed_factor, grid_align=grid_align,
    )
    sim = MultiQuerySimulator(
        cluster, fair_share=fair_share, batch_ticks=batch_ticks,
        none_closed_form=none_closed_form,
        closed_form_drain=closed_form_drain,
        deadline_aware=deadline_aware, deadline_cfg=deadline_cfg,
        preemption=preemption, autoscale=autoscale,
        faults=faults, fault_cfg=fault_cfg, seed=sim_seed,
    )
    results = sim.run(tenants)
    out = summarize_open_loop(
        tenants, results, cluster, fault_stats=sim.last_fault_stats
    )
    out["tenants"] = tenants
    out["results"] = results
    out["event_counts"] = dict(sim.last_event_counts)
    out["resizes"] = list(sim.last_resizes)
    out["fault_stats"] = dict(sim.last_fault_stats)
    return out


# ------------------------------------------------------------------ #
# Multi-stage pipelines (skew propagation)
# ------------------------------------------------------------------ #


def imbalance_coefficient(loads: Sequence[float]) -> float:
    """Skew coefficient of a per-worker load vector: max/mean.  1.0 is
    perfectly balanced; k means the hottest worker holds k times its
    fair share (the quantity DySkew's waterfill drives toward 1).
    Empty/all-zero loads have nothing to imbalance and return NaN."""
    x = np.asarray(list(loads), dtype=np.float64)
    if len(x) == 0 or not np.any(x):
        return float("nan")
    return float(x.max() / x.mean())


def amplification_ratios(imbalances: Sequence[float]) -> List[float]:
    """Stage-over-stage skew amplification: ratio of consecutive
    imbalance coefficients.  >1 means the exchange AMPLIFIED skew
    (e.g. a key-collision groupby), <1 means it attenuated."""
    imb = list(imbalances)
    return [
        float(imb[k + 1] / imb[k]) if imb[k] and np.isfinite(imb[k])
        else float("nan")
        for k in range(len(imb) - 1)
    ]


def summarize_pipeline(pres) -> Dict[str, object]:
    """Aggregate a `repro.sim.pipeline.PipelineResult` into the skew
    propagation report: per-stage INPUT imbalance (rows offered per
    worker — what the shuffle produced), per-stage WORK imbalance
    (busy seconds per worker — what redistribution achieved against
    that input), stage-over-stage amplification of the input skew, and
    the end-to-end makespan vs the sum of per-stage makespans (equal
    for one tenant; a gap measures cross-tenant stage overlap)."""
    input_imb = [
        imbalance_coefficient(s.input_rows_per_worker) for s in pres.stages
    ]
    work_imb = [
        imbalance_coefficient(s.busy_per_worker) for s in pres.stages
    ]
    return {
        "stages": [s.name for s in pres.stages],
        "strategies": [s.strategy for s in pres.stages],
        "input_imbalance": input_imb,
        "work_imbalance": work_imb,
        "amplification": amplification_ratios(input_imb),
        "stage_makespans": [s.makespan for s in pres.stages],
        "makespan": pres.makespan,
        "stage_makespan_sum": pres.stage_makespan_sum,
        "rows_out": list(pres.rows_out),
    }


def run_pipeline_ab(
    stages,
    inputs,
    cluster: ClusterConfig,
    kinds: Sequence[str] = ("dyskew", "static_rr", "p2c"),
    seed: int = 0,
) -> Dict[str, Dict[str, object]]:
    """A/B a chained-stage pipeline across registry policies: the SAME
    stages, inputs and seed (so keys/costs/fanout draws are identical
    across arms), with every stage's redistribution strategy overridden
    to each ``kinds`` entry in turn.  Returns
    ``{kind: summarize_pipeline(result)}``."""
    from repro.sim.pipeline import PipelineSimulator, override_strategy

    out: Dict[str, Dict[str, object]] = {}
    for kind in kinds:
        sim = PipelineSimulator(
            cluster, override_strategy(stages, kind), seed=seed,
        )
        out[kind] = summarize_pipeline(sim.run(inputs))
    return out
