"""Multi-stage UDF pipelines: skew that PROPAGATES.

Every other scenario in `repro.sim` is a single operator stage.  Real
Snowpark workloads are DAGs where one UDF's skewed output becomes the
next stage's skewed input — the regime Reshape (adaptive result-aware
skew handling) and Lachesis (partitioning for UDF-centric DAGs) target.
This module chains `MultiQuerySimulator` stages through inter-stage
shuffles while preserving per-row lineage, so skew amplification and
attenuation are measurable stage by stage:

  * :class:`StageSpec` — one UDF operator stage: a per-stage cost/size
    model over the row KEYS, an output fanout + key transform (the UDF's
    result shape), a redistribution `StrategyConfig` resolved through
    the `repro.core.policy` registry, and the exchange mode feeding the
    NEXT stage.
  * :class:`PipelineSimulator` — runs the stages in sequence.  Stage k
    executes all tenants concurrently on the shared cluster (one
    `MultiQuerySimulator.run` with ``trace_placement=True``); each
    tenant's stage-(k+1) arrival is its stage-k completion (a blocking
    exchange, like a sort/aggregate barrier), and the shuffle builds the
    next stage's per-producer streams from the traced per-row worker
    placements.

Two exchange modes, two skew mechanisms:

  ``worker`` — output rows are produced where their parent row ran, so
      the next stage's input partition IS this stage's placement: a
      stage that redistributed well hands the next stage balanced input
      (skew attenuates), a stage that didn't hands its skew downstream
      (skew propagates).
  ``hash`` — output rows are hash-partitioned on their (transformed)
      key: placement history is erased, but key collisions concentrate
      rows (a groupby onto few groups AMPLIFIES skew regardless of how
      well the previous stage balanced).

Modeling note: the exchange is a per-tenant barrier, so cross-tenant
contention is modeled within each stage (tenants share workers/NICs in
virtual time) but a tenant's stage k+1 never overlaps another tenant's
stage k — stages run in separate simulator invocations, with arrivals
carrying the absolute virtual-time offsets across them.

Determinism: every random quantity (keys, costs, sizes, fanout) comes
from a locally constructed ``np.random.default_rng`` seeded by
``(pipeline seed, stage, tenant)``, so two same-seed runs are
bit-identical end to end (pinned by tests/test_pipeline.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.admission import FairShareConfig
from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    QueryResult,
    StrategyConfig,
    TenantQuery,
)

#: Knuth multiplicative hash — decorrelates key values from worker ids
#: so hash partitioning is uniform unless keys genuinely collide.
_HASH_MULT = np.int64(2654435761)
_HASH_MASK = np.int64((1 << 31) - 1)


def hash_partition(keys: np.ndarray, n_workers: int) -> np.ndarray:
    """Deterministic hash partitioning of int keys onto workers."""
    k = np.asarray(keys, np.int64)
    return ((k * _HASH_MULT) & _HASH_MASK) % n_workers


def zipf_keys(
    n_rows: int, num_keys: int, alpha: float, rng: np.random.Generator
) -> np.ndarray:
    """``n_rows`` keys drawn from a Zipf(alpha) popularity distribution
    over ``num_keys`` distinct key values (alpha<=0 = uniform)."""
    if num_keys <= 0:
        raise ValueError("num_keys must be positive")
    if alpha <= 0.0:
        return rng.integers(0, num_keys, n_rows).astype(np.int64)
    probs = 1.0 / np.arange(1, num_keys + 1, dtype=np.float64) ** alpha
    probs /= probs.sum()
    return rng.choice(num_keys, size=n_rows, p=probs).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One UDF operator stage of a pipeline.

    The per-row model functions all take ``(keys, rng)`` and return an
    array aligned with ``keys``; they MUST be pure functions of their
    arguments (the rng is seeded per (pipeline seed, stage, tenant)) so
    pipelines replay deterministically.

      cost_fn   — per-row UDF seconds (default: lognormal around
                  ``mean_row_cost`` with ``cost_sigma``);
      size_fn   — per-row bytes (default: constant ``row_bytes``);
      fanout_fn — output rows per input row, int >= 0 (default: 1);
      key_fn    — transform applied to the OUTPUT rows' keys (default:
                  identity).  Collapsing transforms (``k % 8``) model
                  skew-amplifying groupbys; rekeying transforms model
                  skew-attenuating explodes.

    ``shuffle`` is the exchange feeding the NEXT stage (ignored for the
    last stage): ``"worker"`` keeps output rows on the worker that
    produced them, ``"hash"`` repartitions by transformed key.
    """

    name: str
    # Distribute-Late + idle-time detection + LOOPING link: the
    # UDF-stage configuration.  Idle-time because §III.A calls it the
    # most effective model for variable per-row costs (row-percentage
    # triggers on transient arrival imbalance and spreads even balanced
    # stages); looping because a continuously-fed exchange needs
    # multi-wave redistribution — the non-looping link fires once and
    # goes terminal with most of the stage still arriving.  Note
    # `override_strategy` switches only the KIND, so the dyskew arm of
    # an A/B keeps this detection config.
    strategy: StrategyConfig = dataclasses.field(
        default_factory=lambda: StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(
                policy=Policy.LATE,
                skew_model=SkewModelKind.IDLE_TIME,
                n_strikes=2,
                looping=True,
            ),
            tick_interval=4e-3,
        )
    )
    shuffle: str = "hash"              # exchange AFTER this stage
    mean_row_cost: float = 4e-4        # seconds of UDF compute per row
    cost_sigma: float = 0.5            # lognormal sigma (cost skew)
    row_bytes: float = 512.0
    cost_fn: Optional[Callable] = None
    size_fn: Optional[Callable] = None
    fanout_fn: Optional[Callable] = None
    key_fn: Optional[Callable] = None
    #: Explicit inter-batch arrival gap; None (default) models the
    #: upstream exchange as a backpressured scan feeding ``feed_factor``x
    #: faster than the workers drain in aggregate (same model as
    #: `replay.scan_arrival_gap`) — rows must still be ARRIVING while
    #: skew detection runs, or distribute-late has nothing left to move.
    arrival_gap: Optional[float] = None
    feed_factor: float = 2.0
    batch_rows: int = 64

    def __post_init__(self):
        if self.shuffle not in ("worker", "hash"):
            raise ValueError(
                f"unknown shuffle mode {self.shuffle!r} "
                "(expected 'worker' or 'hash')"
            )

    def costs(self, keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        if self.cost_fn is not None:
            return np.asarray(self.cost_fn(keys, rng), np.float64)
        mu = np.log(self.mean_row_cost) - 0.5 * self.cost_sigma ** 2
        return rng.lognormal(mu, self.cost_sigma, len(keys))

    def sizes(self, keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        if self.size_fn is not None:
            return np.asarray(self.size_fn(keys, rng), np.float64)
        return np.full(len(keys), float(self.row_bytes))

    def fanout(self, keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        if self.fanout_fn is None:
            return np.ones(len(keys), np.int64)
        fan = np.asarray(self.fanout_fn(keys, rng), np.int64)
        if fan.shape != keys.shape or (len(fan) and fan.min() < 0):
            raise ValueError(
                f"stage {self.name!r}: fanout_fn must return one count "
                ">= 0 per input row"
            )
        return fan

    def transform_keys(
        self, keys: np.ndarray, rng: np.random.Generator
    ) -> np.ndarray:
        if self.key_fn is None:
            return keys
        out = np.asarray(self.key_fn(keys, rng), np.int64)
        if out.shape != keys.shape:
            raise ValueError(
                f"stage {self.name!r}: key_fn must return one key per "
                "output row"
            )
        return out


@dataclasses.dataclass(frozen=True)
class PipelineInput:
    """One tenant's source table for stage 0: ``n_rows`` rows whose keys
    follow a Zipf(``zipf_alpha``) popularity over ``num_keys`` distinct
    values, partitioned onto producers by ``partition`` ('hash' — hot
    keys pile onto one producer, the classic skewed scan — or 'rr',
    round-robin balanced)."""

    name: str
    n_rows: int = 4096
    num_keys: int = 512
    zipf_alpha: float = 1.1
    partition: str = "hash"            # hash | rr
    weight: float = 1.0
    arrival: float = 0.0

    def __post_init__(self):
        if self.partition not in ("hash", "rr"):
            raise ValueError(
                f"unknown partition mode {self.partition!r} "
                "(expected 'hash' or 'rr')"
            )


@dataclasses.dataclass
class _RowSet:
    """A tenant's live row population between stages (the lineage)."""

    keys: np.ndarray        # (m,) int64
    producers: np.ndarray   # (m,) int64 — worker holding each row
    arrival: float          # virtual time the rows become available


@dataclasses.dataclass
class StageReport:
    """Everything measurable about one executed stage."""

    name: str
    strategy: str
    results: List[QueryResult]          # per tenant
    arrivals: List[float]               # per tenant (absolute)
    completions: List[float]            # per tenant (absolute)
    rows_in: List[int]                  # per tenant
    bytes_in: List[float]               # per tenant
    input_rows_per_worker: np.ndarray   # (n,) summed over tenants
    busy_per_worker: np.ndarray         # (n,) summed over tenants
    makespan: float                     # max completion - min arrival


@dataclasses.dataclass
class PipelineResult:
    stages: List[StageReport]
    makespan: float             # end-to-end: last completion - first arrival
    stage_makespan_sum: float   # sum of per-stage makespans
    rows_out: List[int]         # per tenant, after the last stage's fanout


class PipelineSimulator:
    """Chain :class:`MultiQuerySimulator` stages through blocking
    exchanges, preserving per-row (tenant, key, placement) lineage.

    ``strategy_override`` replaces EVERY stage's redistribution strategy
    (the per-stage A/B knob: same pipeline, same seeds, different
    policy).  ``fair_share``/``batch_ticks`` forward to each stage's
    engine invocation unchanged.
    """

    def __init__(
        self,
        cluster: ClusterConfig,
        stages: Sequence[StageSpec],
        seed: int = 0,
        fair_share: Optional[FairShareConfig] = None,
        batch_ticks: Optional[bool] = None,
        strategy_override: Optional[StrategyConfig] = None,
    ):
        if not stages:
            raise ValueError("a pipeline needs at least one stage")
        self.cluster = cluster
        self.stages = list(stages)
        self.seed = seed
        self.fair_share = fair_share
        self.batch_ticks = batch_ticks
        self.strategy_override = strategy_override

    # -- deterministic sub-seeds ------------------------------------- #

    def stage_seed(self, k: int) -> int:
        """Engine seed for stage ``k`` (feeds the per-tenant policy RNG
        streams) — mixed so distinct (pipeline seed, stage) pairs get
        distinct, reproducible streams."""
        return int(
            np.random.SeedSequence([self.seed, k]).generate_state(1)[0]
        )

    def _rng(self, k: int, tenant: int, lane: int) -> np.random.Generator:
        return np.random.default_rng([self.seed, k, tenant, lane])

    # -- stage construction (public: the differential pin replays it) - #

    def initial_rows(self, inputs: Sequence[PipelineInput]) -> List[_RowSet]:
        """Materialize every tenant's stage-0 row population."""
        n = self.cluster.num_workers
        rows = []
        for ti, inp in enumerate(inputs):
            rng = self._rng(0, ti, lane=0)
            keys = zipf_keys(inp.n_rows, inp.num_keys, inp.zipf_alpha, rng)
            if inp.partition == "hash":
                prod = hash_partition(keys, n)
            else:
                prod = np.arange(inp.n_rows, dtype=np.int64) % n
            rows.append(_RowSet(
                keys=keys, producers=prod, arrival=float(inp.arrival),
            ))
        return rows

    def stage_tenants(
        self,
        k: int,
        rows: Sequence[_RowSet],
        inputs: Sequence[PipelineInput],
    ) -> List[TenantQuery]:
        """Build stage ``k``'s engine tenants from the live row sets.
        Batches carry contiguous lineage ids (0..m-1 per tenant) so the
        engine's placement trace aligns with the row arrays."""
        stage = self.stages[k]
        strategy = self.strategy_override or stage.strategy
        tenants = []
        for ti, rs in enumerate(rows):
            rng = self._rng(k, ti, lane=1)
            costs = stage.costs(rs.keys, rng)
            sizes = stage.sizes(rs.keys, rng)
            streams = self._build_streams(rs.producers, costs, sizes,
                                          stage.batch_rows)
            gap = stage.arrival_gap
            if gap is None:
                # Backpressured exchange feed: batches spread over the
                # ideal (balanced) stage duration, feed_factor-x faster
                # than aggregate drain.
                ideal = float(costs.sum()) / self.cluster.num_workers
                nbatches = max(len(costs) // stage.batch_rows, 1)
                gap = ideal / (stage.feed_factor * nbatches)
            tenants.append(TenantQuery(
                name=f"{inputs[ti].name}@{stage.name}",
                streams=streams,
                strategy=strategy,
                arrival=rs.arrival,
                arrival_gap=gap,
                weight=inputs[ti].weight,
            ))
        return tenants

    def _build_streams(
        self, producers: np.ndarray, costs: np.ndarray, sizes: np.ndarray,
        batch_rows: int,
    ) -> List[List[Batch]]:
        n = self.cluster.num_workers
        streams: List[List[Batch]] = []
        for p in range(n):
            idx = np.flatnonzero(producers == p)
            stream: List[Batch] = []
            for i in range(0, len(idx), batch_rows):
                sel = idx[i:i + batch_rows]
                stream.append(Batch(
                    costs=costs[sel].copy(),
                    sizes=sizes[sel].copy(),
                    ids=sel.astype(np.int64),
                ))
            streams.append(stream)
        return streams

    # -- the pipeline loop ------------------------------------------- #

    def run(self, inputs: Sequence[PipelineInput]) -> PipelineResult:
        if not inputs:
            raise ValueError("a pipeline run needs at least one input")
        n = self.cluster.num_workers
        rows = self.initial_rows(inputs)
        first_arrival = min(rs.arrival for rs in rows)
        reports: List[StageReport] = []
        for k, stage in enumerate(self.stages):
            tenants = self.stage_tenants(k, rows, inputs)
            sim = MultiQuerySimulator(
                self.cluster,
                fair_share=self.fair_share,
                batch_ticks=self.batch_ticks,
                trace_placement=True,
                seed=self.stage_seed(k),
            )
            results = sim.run(tenants)
            placements = sim.last_placement
            in_per_worker = np.zeros(n, np.int64)
            busy = np.zeros(n)
            completions = []
            for ti, rs in enumerate(rows):
                if len(rs.producers):
                    in_per_worker += np.bincount(rs.producers, minlength=n)
                busy += np.asarray(results[ti].per_worker_busy)
                completions.append(rs.arrival + results[ti].latency)
            arrivals = [rs.arrival for rs in rows]
            reports.append(StageReport(
                name=stage.name,
                strategy=(self.strategy_override or stage.strategy).kind,
                results=results,
                arrivals=arrivals,
                completions=completions,
                rows_in=[len(rs.keys) for rs in rows],
                bytes_in=[
                    float(sum(b.total_bytes for s in t.streams for b in s))
                    for t in tenants
                ],
                input_rows_per_worker=in_per_worker,
                busy_per_worker=busy,
                makespan=max(completions) - min(arrivals),
            ))
            # ---- exchange: this stage's output -> next stage's input --
            rows = [
                self._exchange(k, stage, ti, rs, placements[ti],
                               completions[ti])
                for ti, rs in enumerate(rows)
            ]
        last = max(
            reports[-1].completions[ti] for ti in range(len(inputs))
        )
        return PipelineResult(
            stages=reports,
            makespan=last - first_arrival,
            stage_makespan_sum=float(sum(r.makespan for r in reports)),
            rows_out=[len(rs.keys) for rs in rows],
        )

    def _exchange(
        self,
        k: int,
        stage: StageSpec,
        ti: int,
        rs: _RowSet,
        placement: Optional[np.ndarray],
        completion: float,
    ) -> _RowSet:
        """Apply stage ``k``'s UDF result shape (fanout + key transform)
        to tenant ``ti``'s rows and repartition for the next stage."""
        n = self.cluster.num_workers
        m = len(rs.keys)
        if m == 0:
            return _RowSet(
                keys=np.empty(0, np.int64),
                producers=np.empty(0, np.int64),
                arrival=completion,
            )
        if placement is None or (placement < 0).any():
            raise RuntimeError(
                f"stage {stage.name!r}: incomplete placement trace — "
                "a routed row was never recorded (engine bug)"
            )
        rng = self._rng(k, ti, lane=2)
        fan = stage.fanout(rs.keys, rng)
        child_keys = stage.transform_keys(np.repeat(rs.keys, fan), rng)
        if stage.shuffle == "worker":
            producers = np.repeat(placement[:m], fan)
        else:
            producers = hash_partition(child_keys, n)
        return _RowSet(
            keys=child_keys, producers=producers, arrival=completion,
        )


def override_strategy(
    stages: Sequence[StageSpec], kind: str, **replace_kw
) -> List[StageSpec]:
    """Copy ``stages`` with every stage's strategy switched to registry
    policy ``kind`` (other strategy knobs preserved) — the per-stage A/B
    helper the benches use."""
    return [
        dataclasses.replace(
            s,
            strategy=dataclasses.replace(s.strategy, kind=kind, **replace_kw),
        )
        for s in stages
    ]
