"""Discrete-event simulator of Snowpark-style UDF execution.

This is the *paper-faithful* layer: it models the asynchronous engine the
paper describes — virtual-warehouse nodes hosting pools of Python
interpreter processes (workers), producer link instances 1:1 with workers,
batches of rows flowing through adaptive data links, and a network that
charges for cross-node row movement.  Per-row UDF cost is OPAQUE to the
link (the defining difficulty in §I): routing decisions use only observed
backlog and the sibling-observable metrics of §III.A.

The state machines/skew models are the exact `repro.core` implementations,
jitted once per configuration and driven with host numpy arrays, so the
simulator and the SPMD training/serving paths share one algorithm.  State
machines tick on a fixed virtual-time cadence (`tick_interval`), modelling
the engine's metrics subsystem; batch routing consults the latest
distribute mask plus the shared per-batch admission planner
(`repro.core.admission`: Row Size Model density guard, cost gate,
self-skip eligibility — the same planner the serving engine and the data
pipeline call).

The engine core is array-backed: queued rows live in contiguous per-worker
ring buffers (`_RowRing`), batch routing groups rows per destination with
one stable sort instead of per-destination masking, and event payloads are
numpy segments rather than per-row Python tuples.  The original
list-of-tuples implementation is preserved in `repro.sim.legacy` and the
two are pinned against each other by `tests/test_sim_equivalence.py`.

Strategies:
  none       — default 1:1 link (no redistribution)
  static_rr  — the legacy Snowpark solution: per-row round-robin across all
               interpreters from the start (paper §II.B, Fig. 1)
  dyskew     — the paper's adaptive link (configurable policy/models)

Multi-tenant execution: `MultiQuerySimulator` interleaves N concurrent
queries (tenants) over ONE shared cluster — shared interpreter pools and
shared per-node NIC occupancy — while each tenant keeps its own
`AdaptiveLinkSim`, cost estimator, flow-control window and strategy, as in
the paper's production setting where many Snowpark queries contend for the
same virtual warehouse.  Tenants arrive staggered in virtual time; the
result is one `QueryResult` per tenant (latency measured from the tenant's
arrival), which `benchmarks/bench_multi_tenant.py` aggregates into
per-query p50/p99 under legacy vs DySkew scheduling.
"""

from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import state_machine
from repro.core.admission import BatchAdmission
from repro.core.types import DySkewConfig, Policy


# --------------------------------------------------------------------- #
# Cluster / workload dataclasses
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    num_nodes: int = 4
    interpreters_per_node: int = 8
    # Cross-node NIC bandwidth and per-batch latency.
    network_bandwidth: float = 1.25e9      # bytes/s (10 GbE)
    network_latency: float = 200e-6        # s per cross-node batch hop
    # Same-node IPC (VW thread → interpreter) costs.
    ipc_bandwidth: float = 8e9
    ipc_latency: float = 20e-6
    # Fixed serialization overhead per row crossing a process boundary
    # (§III.B: 'mandatory data serialization across process boundaries').
    per_row_serialize: float = 2e-6
    # Model per-node egress NIC occupancy (transfers from one node
    # serialize on its uplink — what saturates on 100 GB+ heavy rows).
    model_contention: bool = True
    # Credit-based flow control: a producer pauses once its destination's
    # outstanding (sent-unacked) rows exceed this window. Link-level
    # redistribution relieves exactly this backpressure — the mechanism by
    # which DySkew unblocks straggler pipelines.
    flow_window_rows: int = 32

    @property
    def num_workers(self) -> int:
        return self.num_nodes * self.interpreters_per_node

    def node_of(self, worker: int) -> int:
        return worker // self.interpreters_per_node


@dataclasses.dataclass
class Batch:
    """A rowset batch: costs are the TRUE (hidden) per-row UDF seconds."""

    costs: np.ndarray   # (rows,) float64
    sizes: np.ndarray   # (rows,) float64 bytes

    @property
    def num_rows(self) -> int:
        return len(self.costs)

    @property
    def total_bytes(self) -> float:
        # Cached: batches are immutable in practice and re-routed often
        # (once per strategy under comparison).
        tb = self.__dict__.get("_total_bytes")
        if tb is None:
            tb = self.__dict__["_total_bytes"] = float(self.sizes.sum())
        return tb


@dataclasses.dataclass
class QueryResult:
    latency: float
    utilization: float
    bytes_moved_remote: float
    rows_redistributed: int
    redistribution_applied: bool
    per_worker_busy: np.ndarray
    decision_overhead: float
    num_ticks: int = 0


# --------------------------------------------------------------------- #
# Adaptive link driver (jitted core state machine on host arrays)
# --------------------------------------------------------------------- #


class _JittedMachine:
    """Caches one jitted `state_machine.tick` per (config, n_instances)."""

    _cache: Dict[Tuple, Callable] = {}

    @classmethod
    def get(cls, cfg: DySkewConfig, n: int) -> Callable:
        key = (cfg, n)
        fn = cls._cache.get(key)
        if fn is None:
            fn = jax.jit(partial(_tick_impl, cfg=cfg))
            cls._cache[key] = fn
        return fn


def _tick_impl(link, rows, sync, density, bpr, signal, *, cfg):
    return state_machine.tick(
        link,
        cfg,
        rows_this_tick=rows,
        sync_time_this_tick=sync,
        batch_density=density,
        bytes_per_row=bpr,
        signal_this_tick=signal,
    )


def _host_link_state(n: int, cfg: DySkewConfig) -> Dict[str, np.ndarray]:
    """Host-numpy mirror of `types.link_state_init` (same tree/dtypes, no
    device round-trip — the simulator creates one link per query)."""
    return {
        "state": np.zeros((n,), np.int32),  # LinkState.INIT == 0
        "strikes": np.zeros((n,), np.int32),
        "metrics": {
            "rows": np.zeros((n,), np.float32),
            "idle_ticks": np.zeros((n,), np.float32),
            "sync_window": np.zeros((n, cfg.slope_window), np.float32),
            "batch_density": np.zeros((n,), np.float32),
            "bytes_per_row": np.zeros((n,), np.float32),
        },
        "transitions": np.zeros((n,), np.int32),
        "tick": np.zeros((), np.int32),
    }


class AdaptiveLinkSim:
    """Host-side wrapper around the core state machines for all producer
    link instances of one query (they are siblings of each other)."""

    def __init__(self, cfg: DySkewConfig, n: int):
        self.cfg = cfg
        self.n = n
        # State lives on-device between ticks; only the distribute mask is
        # pulled back each tick (the state tree round-trip dominated the
        # metrics-subsystem cost in the seed implementation).
        self.state = _host_link_state(n, cfg)
        self._tick = _JittedMachine.get(cfg, n)

    def tick(self, rows, sync, density, bpr, signal) -> np.ndarray:
        self.state, distribute = self._tick(
            self.state,
            rows.astype(np.float32),
            sync.astype(np.float32),
            density.astype(np.float32),
            bpr.astype(np.float32),
            signal.astype(bool),
        )
        return np.asarray(distribute)

    @property
    def states(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["state"]))

    @property
    def transitions(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["transitions"]))


# --------------------------------------------------------------------- #
# Routing helpers
# --------------------------------------------------------------------- #


def waterfill_counts(backlog: np.ndarray, k: int, unit: float) -> np.ndarray:
    """Assign ``k`` unit-cost rows to bins so resulting loads are as level
    as possible (vectorized least-backlog greedy for identical costs).

    The continuous water level is solved in closed form (with the j lowest
    backlogs submerged, level_j = (k*unit + sum of those backlogs) / j; the
    true level is the largest j consistent with its own submerged set) and
    the integer counts are floored from it, so no bisection loop is needed;
    the trim/top-up passes below repair the floor rounding exactly.
    """
    n = len(backlog)
    finite = np.isfinite(backlog)
    out = np.zeros(n, np.int64)
    if k == 0:
        return out
    if not finite.any():
        out[0] = k
        return out
    bl = backlog.copy()
    blf = np.sort(bl[finite])
    levels = (k * unit + np.cumsum(blf)) / np.arange(1, len(blf) + 1)
    j = int(np.nonzero(levels >= blf)[0][-1])  # always valid at j=0
    counts = np.floor(np.maximum(levels[j] - bl, 0.0) / unit)
    counts[~finite] = 0
    counts = counts.astype(np.int64)
    diff = int(counts.sum()) - k
    while diff > 0:
        # Trim one item at a time from the currently most-loaded bin —
        # bulk-trimming a single bin un-levels the fill (hypothesis-found).
        loads = np.where(counts > 0, bl + counts * unit, -np.inf)
        d = int(np.argmax(loads))
        counts[d] -= 1
        diff -= 1
    if diff < 0:
        order = np.argsort(np.where(finite, bl + counts * unit, np.inf))
        ne = int(finite.sum())
        i = 0
        while diff < 0:
            counts[order[i % ne]] += 1
            diff += 1
            i += 1
    return counts


class _RowRing:
    """Contiguous FIFO ring of queued row costs for ONE worker.

    Segments are appended with a single vectorized copy; service bursts
    pop a contiguous view.  Popped views must be consumed before the next
    push (a push may compact the buffer).  When ``track_qids`` is set a
    parallel int32 lane records the owning tenant of each row (used by
    `MultiQuerySimulator` for per-query accounting in shared pools).
    """

    __slots__ = ("buf", "qbuf", "head", "tail")

    def __init__(self, cap: int = 256, track_qids: bool = False):
        self.buf = np.empty(cap, np.float64)
        self.qbuf = np.empty(cap, np.int32) if track_qids else None
        self.head = 0
        self.tail = 0

    def __len__(self) -> int:
        return self.tail - self.head

    def push(self, costs: np.ndarray, qid: int = 0) -> None:
        k = len(costs)
        if self.tail + k > self.buf.size:
            self._compact_grow(k)
        self.buf[self.tail:self.tail + k] = costs
        if self.qbuf is not None:
            self.qbuf[self.tail:self.tail + k] = qid
        self.tail += k

    def _compact_grow(self, k: int) -> None:
        live = self.tail - self.head
        cap = self.buf.size
        while cap < live + k:
            cap *= 2
        if cap > self.buf.size:
            new = np.empty(cap, np.float64)
            new[:live] = self.buf[self.head:self.tail]
            self.buf = new
            if self.qbuf is not None:
                newq = np.empty(cap, np.int32)
                newq[:live] = self.qbuf[self.head:self.tail]
                self.qbuf = newq
        elif live:
            # Slide live region to the front (copy src first if overlapping).
            src = self.buf[self.head:self.tail]
            self.buf[:live] = src.copy() if self.head < live else src
            if self.qbuf is not None:
                qsrc = self.qbuf[self.head:self.tail]
                self.qbuf[:live] = qsrc.copy() if self.head < live else qsrc
        self.head = 0
        self.tail = live

    def pop(self, k: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        k = min(k, self.tail - self.head)
        i = self.head
        self.head += k
        costs = self.buf[i:i + k]
        qids = self.qbuf[i:i + k] if self.qbuf is not None else None
        return costs, qids


def _transfer_delay(c: ClusterConfig, src_worker: int, dst_worker: int,
                    nbytes: float, nrows: int) -> float:
    """Contention-free transfer latency (NIC occupancy handled by the
    caller when model_contention is on).  Shared by the single-query and
    multi-tenant engines so the network model cannot diverge."""
    ser = nrows * c.per_row_serialize
    if c.node_of(src_worker) == c.node_of(dst_worker):
        if src_worker == dst_worker:
            return ser  # stays in-process pipeline; serialization only
        return c.ipc_latency + nbytes / c.ipc_bandwidth + ser
    return c.network_latency + nbytes / c.network_bandwidth + ser


def _group_by_dest(
    dests: np.ndarray, costs: np.ndarray, sizes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group a batch's rows by destination with ONE stable sort.

    Returns (sorted_dests, starts, ends, costs_sorted, sizes_sorted);
    group j covers rows [starts[j], ends[j]) of the sorted arrays and all
    go to destination sorted_dests[starts[j]].  Destinations come out
    ascending and rows keep their in-batch order within a group — the
    same grouping the legacy per-destination boolean masks produced.
    """
    order = np.argsort(dests, kind="stable")
    sd = dests[order]
    bounds = np.flatnonzero(sd[1:] != sd[:-1]) + 1
    starts = np.concatenate(([0], bounds))
    ends = np.concatenate((bounds, [len(sd)]))
    return sd, starts, ends, costs[order], sizes[order]


# --------------------------------------------------------------------- #
# The simulator
# --------------------------------------------------------------------- #

_TICK, _ARRIVAL, _ENQUEUE, _DONE = 0, 1, 2, 3

#: Rows per service burst (completion-ack granularity).
_SERVICE_CHUNK = 16


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    kind: str = "dyskew"              # none | static_rr | dyskew
    dyskew: DySkewConfig = dataclasses.field(
        default_factory=lambda: DySkewConfig(policy=Policy.EAGER_SNOWPARK)
    )
    # Metrics-subsystem cadence: state machines tick every `tick_interval`
    # seconds of virtual time.
    tick_interval: float = 50e-3
    # Adaptive-decision CPU overhead charged per routed batch (metrics
    # sampling + state machine + waterfill in the VW worker thread). The
    # legacy static strategy pays none.
    decision_overhead: float = 200e-6
    # EMA horizon for the opaque per-row cost estimate.
    cost_ema: float = 0.2
    # Disable the per-batch admission guards (ablations).
    enable_density_guard: bool = True
    enable_cost_gate: bool = True

    def admission(self) -> BatchAdmission:
        """The shared `repro.core` admission planner for this strategy."""
        return BatchAdmission(
            self.dyskew,
            enable_density_guard=self.enable_density_guard,
            enable_cost_gate=self.enable_cost_gate,
        )


class Simulator:
    def __init__(
        self,
        cluster: ClusterConfig,
        strategy: StrategyConfig,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.strategy = strategy
        self.rng = np.random.default_rng(seed)

    # -- helpers -------------------------------------------------------- #

    def _transfer_delay(self, src_worker: int, dst_worker: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src_worker, dst_worker,
                               nbytes, nrows)

    # -- main entry ------------------------------------------------------ #

    def run_query(
        self,
        batches_per_producer: List[List[Batch]],
        arrival_gap: float = 1e-4,
    ) -> QueryResult:
        """Execute one query.

        ``batches_per_producer[i]`` is the (possibly skewed) input stream of
        producer link instance i; batches arrive back-to-back separated by
        ``arrival_gap`` (the scan feeding the UDF operator).
        """
        c = self.cluster
        st = self.strategy
        cfg = st.dyskew
        admission = st.admission()
        n = c.num_workers
        # Hot-loop locals: node lookup table, flat network constants, and
        # plain-Python scalar accumulators (single-element numpy indexing
        # is ~10x a list index at this event grain).  Vector math converts
        # the lists once per tick / per routed batch instead.
        node = [w // c.interpreters_per_node for w in range(n)]
        net_bw, net_lat = c.network_bandwidth, c.network_latency
        ipc_bw, ipc_lat = c.ipc_bandwidth, c.ipc_latency
        ser = c.per_row_serialize
        contention = c.model_contention
        flow_window = c.flow_window_rows
        static_rr = st.kind == "static_rr"
        cost_ema = st.cost_ema
        heappush, heappop = heapq.heappush, heapq.heappop

        # Worker state: queued row costs in contiguous per-worker rings.
        rings = [_RowRing() for _ in range(n)]
        busy_time = [0.0] * n
        rows_done = [0] * n
        worker_running = [False] * n

        # Metric accumulators between state-machine ticks.
        recv_in_tick = [0.0] * n          # rows received by each consumer
        sync_in_tick = [0.0] * n          # sync time per consumer
        rows_arr_in_tick = [0.0] * n      # rows arrived at each producer
        batches_arr_in_tick = [0.0] * n
        bytes_arr_in_tick = [0.0] * n

        # Opaque-cost estimator (global EMA of observed per-row time).
        est_row_cost = 1e-3
        # Observable backlog: rows sent to each consumer minus rows acked
        # complete (the producer sees its own sends and completion acks; it
        # never sees the hidden per-row costs).
        outstanding_rows = [0.0] * n

        link: Optional[AdaptiveLinkSim] = None
        distribute_mask = [False] * n
        if st.kind == "dyskew":
            link = AdaptiveLinkSim(cfg, n)

        bytes_moved = 0.0
        rows_redist = 0
        decision_overhead_total = 0.0
        rr_counter = 0
        num_ticks = 0
        # Per-node egress NIC occupancy (heavy-row saturation, §III.B).
        nic_free_at = [0.0] * c.num_nodes

        remaining_arrivals = sum(len(s) for s in batches_per_producer)
        in_flight = 0
        queued_rows_total = 0

        events: List[Tuple[float, int, int, int, object]] = []
        seq = 0

        # Seed the first tick BEFORE any arrival (same timestamp, lower
        # seq): eager links redistribute from the operator's first row.
        if link is not None:
            heappush(events, (0.0, seq, _TICK, 0, None))
            seq += 1
        # Arrivals are chained per producer: batch k+1 is scheduled only
        # after batch k is routed, delayed by scan production time plus
        # credit-based backpressure against the destination backlog.
        streams = batches_per_producer
        for p, stream in enumerate(streams):
            if stream:
                heappush(events, (0.0, seq, _ARRIVAL, p, 0))
                seq += 1

        def start_worker(w: int, now: float):
            nonlocal queued_rows_total, seq
            if worker_running[w]:
                return
            ring = rings[w]
            if ring.tail == ring.head:
                return
            chunk, _ = ring.pop(_SERVICE_CHUNK)
            queued_rows_total -= len(chunk)
            # Sequential Python-float sum: bit-identical to the legacy
            # engine's per-tuple accumulation, so the two engines stay on
            # the same event trajectory (tiny rounding differences amplify
            # chaotically through routing decisions).
            total = sum(chunk.tolist())
            worker_running[w] = True
            heappush(events, (now + total, seq, _DONE, w, (total, len(chunk))))
            seq += 1

        def siblings_idle_frac(p: int) -> float:
            idle = 0
            for w in range(n):
                if w != p and not worker_running[w] and rings[w].tail == rings[w].head:
                    idle += 1
            return idle / max(n - 1, 1)

        def route_batch(p: int, b: Batch, now: float) -> None:
            nonlocal rr_counter, bytes_moved, rows_redist, in_flight, seq
            dests: Optional[np.ndarray] = None
            if static_rr:
                dests = (rr_counter + np.arange(b.num_rows)) % n
                rr_counter += b.num_rows
            elif distribute_mask[p]:
                # Row Size Model admission guard (§III.B): low batch density
                # + no skew benefit visible → keep the heavy rows local.
                bpr = b.total_bytes / max(b.num_rows, 1)
                if not admission.density_guard_blocks(
                    b.num_rows, bpr, lambda: siblings_idle_frac(p)
                ):
                    bl = np.asarray(outstanding_rows) * est_row_cost
                    if cfg.self_skip:
                        # Forced-remote ablation (§III.B): the producer must
                        # bypass its own node's interpreters entirely
                        # (Fig. 1 — redistribution targets interpreters on
                        # *other* VW nodes), leaving local CPU idle.
                        bl = np.where(
                            admission.eligible_destinations(n, p, c.node_of),
                            bl, np.inf,
                        )
                    counts = waterfill_counts(
                        bl, b.num_rows, max(est_row_cost, 1e-9)
                    )
                    dests = np.repeat(np.arange(n), counts)
                    if st.enable_cost_gate:
                        # Cost gate (§I goal 3): refuse when estimated
                        # movement time exceeds estimated straggler savings.
                        moving = dests != p
                        dec = admission.admit_move(
                            float(b.sizes[moving].sum()), int(moving.sum()),
                            est_row_cost, n,
                            net_bw, ser,
                        )
                        if not dec.admit:
                            dests = None

            if dests is None:
                # All-local fast path (no redistribution this batch):
                # in-process pipeline, serialization delay only.
                nrows = b.num_rows
                in_flight += 1
                heappush(events, (now + nrows * ser, seq, _ENQUEUE, p, b.costs))
                seq += 1
                outstanding_rows[p] += nrows
                return
            sd, starts, ends, costs_s, sizes_s = _group_by_dest(
                dests, b.costs, b.sizes
            )
            # Per-group pairwise .sum() matches the legacy masked sums
            # bit-for-bit (same elements, same order, same algorithm).
            src_node = node[p]
            for j in range(len(starts)):
                lo, hi = starts[j], ends[j]
                d = int(sd[lo])
                nrows = hi - lo
                nbytes = float(sizes_s[lo:hi].sum())
                if node[d] != src_node:
                    rows_redist += nrows
                    bytes_moved += nbytes
                    if contention:
                        # Serialize on the source node's uplink.
                        nf = nic_free_at[src_node]
                        start = now if now > nf else nf
                        occupy = nbytes / net_bw
                        nic_free_at[src_node] = start + occupy
                        arrive = start + occupy + net_lat + nrows * ser
                    else:
                        arrive = now + net_lat + nbytes / net_bw + nrows * ser
                elif d == p:
                    arrive = now + nrows * ser
                else:
                    rows_redist += nrows
                    arrive = now + ipc_lat + nbytes / ipc_bw + nrows * ser
                in_flight += 1
                heappush(events, (arrive, seq, _ENQUEUE, d, costs_s[lo:hi]))
                seq += 1
                outstanding_rows[d] += nrows

        now = 0.0
        last_work_done = 0.0
        while events:
            now, _, kind, who, payload = heappop(events)
            if kind == _ENQUEUE:
                w = who
                in_flight -= 1
                k = len(payload)
                rings[w].push(payload)
                queued_rows_total += k
                recv_in_tick[w] += k
                if not worker_running[w]:
                    start_worker(w, now)
            elif kind == _DONE:
                w = who
                total, nrows = payload
                busy_time[w] += total
                rows_done[w] += nrows
                sync_in_tick[w] += total
                avg = total / nrows if nrows else 0.0
                est_row_cost = (1 - cost_ema) * est_row_cost + cost_ema * avg
                left = outstanding_rows[w] - nrows
                outstanding_rows[w] = left if left > 0.0 else 0.0
                worker_running[w] = False
                last_work_done = now
                start_worker(w, now)
            elif kind == _ARRIVAL:
                p, k = who, payload
                b = streams[p][k]
                remaining_arrivals -= 1
                rows_arr_in_tick[p] += b.num_rows
                batches_arr_in_tick[p] += 1
                bytes_arr_in_tick[p] += b.total_bytes
                if link is not None:
                    decision_overhead_total += st.decision_overhead
                    now += st.decision_overhead
                route_batch(p, b, now)
                if k + 1 < len(streams[p]):
                    # Flow control: pace against the least-backlogged valid
                    # destination (own consumer when routing locally).
                    if static_rr or distribute_mask[p]:
                        bl = min(outstanding_rows)
                    else:
                        bl = outstanding_rows[p]
                    backpressure = max(0.0, bl - flow_window) * est_row_cost
                    heappush(events, (now + arrival_gap + backpressure,
                                      seq, _ARRIVAL, p, k + 1))
                    seq += 1
            else:  # _TICK
                num_ticks += 1
                rows_arr = np.asarray(rows_arr_in_tick)
                batches_arr = np.asarray(batches_arr_in_tick)
                density = np.where(
                    batches_arr > 0,
                    rows_arr / np.maximum(batches_arr, 1),
                    0.0,
                )
                bpr = np.where(
                    rows_arr > 0,
                    np.asarray(bytes_arr_in_tick) / np.maximum(rows_arr, 1),
                    0.0,
                )
                distribute_mask = link.tick(
                    np.asarray(recv_in_tick), np.asarray(sync_in_tick),
                    density, bpr, np.asarray(worker_running, bool),
                ).tolist()
                recv_in_tick[:] = [0.0] * n
                sync_in_tick[:] = [0.0] * n
                rows_arr_in_tick[:] = [0.0] * n
                batches_arr_in_tick[:] = [0.0] * n
                bytes_arr_in_tick[:] = [0.0] * n
                if (
                    remaining_arrivals > 0 or in_flight > 0
                    or queued_rows_total > 0 or any(worker_running)
                ):
                    heappush(events, (now + st.tick_interval, seq, _TICK, 0, None))
                    seq += 1

        makespan = max(last_work_done, 1e-12)
        busy_time = np.asarray(busy_time)
        util = float(busy_time.sum() / (makespan * n))
        total_rows = int(sum(rows_done))
        applied = rows_redist > 0.01 * max(total_rows, 1)
        return QueryResult(
            latency=makespan,
            utilization=util,
            bytes_moved_remote=bytes_moved,
            rows_redistributed=rows_redist,
            redistribution_applied=applied,
            per_worker_busy=busy_time,
            decision_overhead=decision_overhead_total,
            num_ticks=num_ticks,
        )


# --------------------------------------------------------------------- #
# Multi-tenant simulation (concurrent query streams, shared cluster)
# --------------------------------------------------------------------- #


@dataclasses.dataclass
class TenantQuery:
    """One tenant of a multi-query run: its input streams, its strategy,
    and when it arrives on the shared cluster (virtual seconds)."""

    name: str
    streams: List[List[Batch]]
    strategy: StrategyConfig
    arrival: float = 0.0
    arrival_gap: float = 1e-4


class MultiQuerySimulator:
    """Interleaves N concurrent queries over ONE shared cluster.

    Workers (interpreter pools) and per-node NIC uplinks are shared across
    tenants — a straggler pipeline of one query delays everyone behind it
    in the same ring, which is exactly the contention the paper's
    production setting implies.  Each tenant keeps private link state
    machines, cost estimator, backlog counters and tick cadence, so
    redistribution decisions stay per-query.
    """

    def __init__(self, cluster: ClusterConfig):
        # Fully deterministic given the tenants (streams/arrivals carry
        # their own seeds), so no RNG state is held here.
        self.cluster = cluster

    def _transfer_delay(self, src: int, dst: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src, dst, nbytes, nrows)

    def run(self, tenants: List[TenantQuery]) -> List[QueryResult]:
        c = self.cluster
        n = c.num_workers
        nq = len(tenants)

        rings = [_RowRing(track_qids=True) for _ in range(n)]
        worker_running = np.zeros(n, bool)
        nic_free_at = np.zeros(c.num_nodes)

        # Per-tenant state (axis 0 = tenant).
        admissions = [t.strategy.admission() for t in tenants]
        links: List[Optional[AdaptiveLinkSim]] = [
            AdaptiveLinkSim(t.strategy.dyskew, n)
            if t.strategy.kind == "dyskew" else None
            for t in tenants
        ]
        distribute_mask = np.zeros((nq, n), bool)
        est_row_cost = np.full(nq, 1e-3)
        outstanding = np.zeros((nq, n))
        recv_in_tick = np.zeros((nq, n))
        sync_in_tick = np.zeros((nq, n))
        rows_arr_in_tick = np.zeros((nq, n))
        batches_arr_in_tick = np.zeros((nq, n))
        bytes_arr_in_tick = np.zeros((nq, n))
        busy = np.zeros((nq, n))
        rows_done = np.zeros((nq, n))
        rr_counter = np.zeros(nq, np.int64)
        bytes_moved = np.zeros(nq)
        rows_redist = np.zeros(nq, np.int64)
        dec_overhead = np.zeros(nq)
        num_ticks = np.zeros(nq, np.int64)
        remaining_arrivals = np.array(
            [sum(len(s) for s in t.streams) for t in tenants], np.int64
        )
        rows_total = np.array(
            [sum(b.num_rows for s in t.streams for b in s) for t in tenants],
            np.int64,
        )
        rows_completed = np.zeros(nq, np.int64)
        last_done = np.array([t.arrival for t in tenants])

        events: List[Tuple[float, int, int, int, int, object]] = []
        seq = 0

        def push(t: float, kind: int, qid: int, who: int, payload: object):
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, qid, who, payload))
            seq += 1

        for q, t in enumerate(tenants):
            # Tick first (lower seq) so eager links distribute from row one.
            if links[q] is not None:
                push(t.arrival, _TICK, q, 0, None)
            for p, stream in enumerate(t.streams):
                if stream:
                    push(t.arrival, _ARRIVAL, q, p, 0)

        def tenant_active(q: int) -> bool:
            return (
                remaining_arrivals[q] > 0
                or rows_completed[q] < rows_total[q]
            )

        def start_worker(w: int, now: float):
            ring = rings[w]
            if worker_running[w] or not len(ring):
                return
            chunk, qids = ring.pop(_SERVICE_CHUNK)
            total = float(chunk.sum())
            counts = np.bincount(qids, minlength=nq)
            totals = np.bincount(qids, weights=chunk, minlength=nq)
            worker_running[w] = True
            push(now + total, _DONE, 0, w, (counts, totals))

        def siblings_idle_frac(p: int) -> float:
            idle = 0
            for w in range(n):
                if w != p and not worker_running[w] and not len(rings[w]):
                    idle += 1
            return idle / max(n - 1, 1)

        def emit(q: int, p: int, d: int, seg_costs: np.ndarray,
                 nbytes: float, now: float) -> None:
            nrows = len(seg_costs)
            cross_node = c.node_of(d) != c.node_of(p)
            if d != p:
                rows_redist[q] += nrows
                if cross_node:
                    bytes_moved[q] += nbytes
            arrive = now + self._transfer_delay(p, d, nbytes, nrows)
            if cross_node and c.model_contention:
                src_node = c.node_of(p)
                start = max(now, nic_free_at[src_node])
                occupy = nbytes / c.network_bandwidth
                nic_free_at[src_node] = start + occupy
                arrive = start + occupy + c.network_latency \
                    + nrows * c.per_row_serialize
            push(arrive, _ENQUEUE, q, d, seg_costs)
            outstanding[q, d] += nrows

        def route_batch(q: int, p: int, b: Batch, now: float) -> None:
            st = tenants[q].strategy
            cfg = st.dyskew
            admission = admissions[q]
            dests: Optional[np.ndarray] = None
            if st.kind == "static_rr":
                dests = (rr_counter[q] + np.arange(b.num_rows)) % n
                rr_counter[q] += b.num_rows
            elif distribute_mask[q, p]:
                bpr = b.total_bytes / max(b.num_rows, 1)
                if not admission.density_guard_blocks(
                    b.num_rows, bpr, lambda: siblings_idle_frac(p)
                ):
                    bl = outstanding[q] * est_row_cost[q]
                    if cfg.self_skip:
                        bl = np.where(
                            admission.eligible_destinations(n, p, c.node_of),
                            bl, np.inf,
                        )
                    counts = waterfill_counts(
                        bl, b.num_rows, max(est_row_cost[q], 1e-9)
                    )
                    dests = np.repeat(np.arange(n), counts)
                    if st.enable_cost_gate:
                        moving = dests != p
                        dec = admission.admit_move(
                            float(b.sizes[moving].sum()), int(moving.sum()),
                            float(est_row_cost[q]), n,
                            c.network_bandwidth, c.per_row_serialize,
                        )
                        if not dec.admit:
                            dests = None
            if dests is None:
                emit(q, p, p, b.costs, b.total_bytes, now)
                return
            sd, starts, ends, costs_s, sizes_s = _group_by_dest(
                dests, b.costs, b.sizes
            )
            byte_sums = np.add.reduceat(sizes_s, starts)
            for j in range(len(starts)):
                lo, hi = starts[j], ends[j]
                emit(q, p, int(sd[lo]), costs_s[lo:hi],
                     float(byte_sums[j]), now)

        now = 0.0
        while events:
            now, _, kind, qid, who, payload = heapq.heappop(events)
            if kind == _TICK:
                q = qid
                num_ticks[q] += 1
                density = np.where(
                    batches_arr_in_tick[q] > 0,
                    rows_arr_in_tick[q] / np.maximum(batches_arr_in_tick[q], 1),
                    0.0,
                )
                bpr = np.where(
                    rows_arr_in_tick[q] > 0,
                    bytes_arr_in_tick[q] / np.maximum(rows_arr_in_tick[q], 1),
                    0.0,
                )
                distribute_mask[q] = links[q].tick(
                    recv_in_tick[q], sync_in_tick[q], density, bpr,
                    worker_running,
                )
                recv_in_tick[q] = 0.0
                sync_in_tick[q] = 0.0
                rows_arr_in_tick[q] = 0.0
                batches_arr_in_tick[q] = 0.0
                bytes_arr_in_tick[q] = 0.0
                if tenant_active(q):
                    push(now + tenants[q].strategy.tick_interval,
                         _TICK, q, 0, None)
            elif kind == _ARRIVAL:
                q, p, k = qid, who, payload
                st = tenants[q].strategy
                b = tenants[q].streams[p][k]
                remaining_arrivals[q] -= 1
                rows_arr_in_tick[q, p] += b.num_rows
                batches_arr_in_tick[q, p] += 1
                bytes_arr_in_tick[q, p] += b.total_bytes
                if links[q] is not None:
                    dec_overhead[q] += st.decision_overhead
                    now += st.decision_overhead
                route_batch(q, p, b, now)
                if k + 1 < len(tenants[q].streams[p]):
                    if st.kind == "static_rr" or distribute_mask[q, p]:
                        bl = float(outstanding[q].min())
                    else:
                        bl = float(outstanding[q, p])
                    backpressure = (
                        max(0.0, bl - c.flow_window_rows) * est_row_cost[q]
                    )
                    push(now + tenants[q].arrival_gap + backpressure,
                         _ARRIVAL, q, p, k + 1)
            elif kind == _ENQUEUE:
                q, w = qid, who
                rings[w].push(payload, qid=q)
                recv_in_tick[q, w] += len(payload)
                start_worker(w, now)
            else:  # _DONE
                w = who
                counts, totals = payload
                busy[:, w] += totals
                rows_done[:, w] += counts
                for q in np.flatnonzero(counts):
                    cnt, tot = int(counts[q]), float(totals[q])
                    sync_in_tick[q, w] += tot
                    ema = tenants[q].strategy.cost_ema
                    est_row_cost[q] = (
                        (1 - ema) * est_row_cost[q] + ema * tot / cnt
                    )
                    outstanding[q, w] = max(outstanding[q, w] - cnt, 0.0)
                    rows_completed[q] += cnt
                    last_done[q] = now
                worker_running[w] = False
                start_worker(w, now)

        results: List[QueryResult] = []
        for q, t in enumerate(tenants):
            latency = max(last_done[q] - t.arrival, 1e-12)
            total_rows = int(rows_done[q].sum())
            applied = rows_redist[q] > 0.01 * max(total_rows, 1)
            results.append(QueryResult(
                latency=float(latency),
                utilization=float(busy[q].sum() / (latency * n)),
                bytes_moved_remote=float(bytes_moved[q]),
                rows_redistributed=int(rows_redist[q]),
                redistribution_applied=bool(applied),
                per_worker_busy=busy[q].copy(),
                decision_overhead=float(dec_overhead[q]),
                num_ticks=int(num_ticks[q]),
            ))
        return results
