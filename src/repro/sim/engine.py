"""Discrete-event simulator of Snowpark-style UDF execution.

This is the *paper-faithful* layer: it models the asynchronous engine the
paper describes — virtual-warehouse nodes hosting pools of Python
interpreter processes (workers), producer link instances 1:1 with workers,
batches of rows flowing through adaptive data links, and a network that
charges for cross-node row movement.  Per-row UDF cost is OPAQUE to the
link (the defining difficulty in §I): routing decisions use only observed
backlog and the sibling-observable metrics of §III.A.

The state machines/skew models are the exact `repro.core` implementations,
jitted once per configuration and driven with host numpy arrays, so the
simulator and the SPMD training/serving paths share one algorithm.  State
machines tick on a fixed virtual-time cadence (`tick_interval`), modelling
the engine's metrics subsystem; batch routing consults the latest
distribute mask plus the shared per-batch admission planner
(`repro.core.admission`: Row Size Model density guard, cost gate,
self-skip eligibility — the same planner the serving engine and the data
pipeline call).

Strategies:
  none       — default 1:1 link (no redistribution)
  static_rr  — the legacy Snowpark solution: per-row round-robin across all
               interpreters from the start (paper §II.B, Fig. 1)
  dyskew     — the paper's adaptive link (configurable policy/models)

ONE event loop.  ``MultiQuerySimulator.run`` is the only event loop in
this module; ``Simulator.run_query`` is its N=1 specialization (one
tenant, arrival at t=0).  `MultiQuerySimulator` interleaves N concurrent
queries (tenants) over ONE shared cluster — shared interpreter pools and
shared per-node NIC occupancy — while each tenant keeps its own
`AdaptiveLinkSim`, cost estimator, flow-control window and strategy, as in
the paper's production setting where many Snowpark queries contend for the
same virtual warehouse.  Tenants carry priority weights; passing a
`FairShareConfig` turns on the weighted deficit-round-robin admission
layer (`repro.core.admission.FairShareAdmission`), which paces each
tenant's batches into the shared pool/NIC and parks over-share arrivals
until completed service earns them credit.  The result is one
`QueryResult` per tenant (latency measured from the tenant's arrival),
which `sim/replay.py` and `benchmarks/bench_multi_tenant.py` aggregate
into per-tenant percentiles and Jain's fairness index.

Engine invariants (the bars `tests/test_sim_equivalence.py` enforces):

  * Array-backed core.  Queued rows live in contiguous per-worker ring
    buffers (`_RowRing`): ``buf[head:tail]`` is the FIFO of pending row
    costs, pushes are single vectorized segment copies (a push may
    compact/grow, so popped views must be consumed before the next
    push), and a parallel int32 ``qbuf`` lane records each row's owning
    tenant whenever more than one tenant shares the cluster.  Batch
    routing groups rows per destination with ONE stable sort
    (`_group_by_dest`), and event payloads are numpy segments, never
    per-row Python tuples.
  * Bit-exactness bar.  The seed list-of-tuples engine is preserved in
    `repro.sim.legacy`, and the unified loop must reproduce its
    `QueryResult` to rtol=1e-9 for single-tenant runs (and for
    multi-tenant runs that are provably non-interacting).  The
    trajectories are chaotic — one ulp of rounding difference amplifies
    through routing decisions — so the loop keeps the legacy engine's
    float operations in the legacy order: service-burst totals are
    sequential sums (``np.bincount`` weight accumulation, which adds in
    index order), per-destination byte totals use numpy's pairwise
    ``.sum()`` on the same element order the legacy masks produced, and
    the EMA update is ``(1-a)*est + a*(total/rows)``.  Do not "simplify"
    these expressions.
  * Determinism.  Given the same tenants the engine is bit-reproducible:
    no RNG is consulted inside the loop, heap ties break on a
    monotonically increasing sequence number, and the fair-share planner
    is deterministic.  This is what lets `sim/replay.py` fan suites out
    across a process pool (``REPRO_BENCH_WORKERS`` pins the worker
    count; 0/1 = serial) with results identical to the serial run.

Scaling to hundreds of tenants.  Two fast paths keep the loop cheap at
large N, both governed by explicit flags on `MultiQuerySimulator` whose
``None`` default enables them only where they are provably equivalent to
the reference trajectory:

  * Batched ticks (``batch_ticks``).  Per-tenant `AdaptiveLinkSim`
    dispatch is replaced by ONE `repro.sim.batched_link.BatchedLinkSim`
    call per shared tick: tenants with the same (DySkewConfig,
    tick_interval) form a group whose (T, n) stacked link state advances
    through a single jitted `tick_many`, driven by one coalesced heap
    event per group cadence with inactive tenants masked.  A tenant
    arriving off-grid gets a one-off masked join tick at its arrival (so
    eager links distribute from row one) and then rides the shared grid.
    ``None`` (auto) batches only when at most one tenant carries a link
    — there the batched trajectory is bit-identical to the per-tenant
    path (T=1 vmap rows are bit-exact; the equivalence pin runs through
    it).  With many link tenants the shared grid quantizes tick times, a
    deliberate semantic change, so multi-link batching is opt-in
    (``batch_ticks=True`` — the bench's ``--many`` mode).
  * Closed-form 'none' strategy (``none_closed_form``).  A tenant that
    never redistributes keeps every producer's rows on its own worker,
    so per-worker completion times collapse to a prefix sum over
    service-chunk totals — no event loop needed.  ``None`` (auto) takes
    the closed form only in the proven-exact regime (all tenants 'none',
    no fair share, disjoint producers, single-batch streams);
    ``True`` extends it to multi-batch streams, where it is exact while
    workers stay backlogged and a lower bound otherwise.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import state_machine
from repro.core.admission import BatchAdmission, FairShareAdmission, FairShareConfig
from repro.core.types import DySkewConfig, Policy
from repro.sim.batched_link import BatchedLinkSim


# --------------------------------------------------------------------- #
# Cluster / workload dataclasses
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    num_nodes: int = 4
    interpreters_per_node: int = 8
    # Cross-node NIC bandwidth and per-batch latency.
    network_bandwidth: float = 1.25e9      # bytes/s (10 GbE)
    network_latency: float = 200e-6        # s per cross-node batch hop
    # Same-node IPC (VW thread → interpreter) costs.
    ipc_bandwidth: float = 8e9
    ipc_latency: float = 20e-6
    # Fixed serialization overhead per row crossing a process boundary
    # (§III.B: 'mandatory data serialization across process boundaries').
    per_row_serialize: float = 2e-6
    # Model per-node egress NIC occupancy (transfers from one node
    # serialize on its uplink — what saturates on 100 GB+ heavy rows).
    model_contention: bool = True
    # Credit-based flow control: a producer pauses once its destination's
    # outstanding (sent-unacked) rows exceed this window. Link-level
    # redistribution relieves exactly this backpressure — the mechanism by
    # which DySkew unblocks straggler pipelines.
    flow_window_rows: int = 32

    @property
    def num_workers(self) -> int:
        return self.num_nodes * self.interpreters_per_node

    def node_of(self, worker: int) -> int:
        return worker // self.interpreters_per_node


@dataclasses.dataclass
class Batch:
    """A rowset batch: costs are the TRUE (hidden) per-row UDF seconds."""

    costs: np.ndarray   # (rows,) float64
    sizes: np.ndarray   # (rows,) float64 bytes

    @property
    def num_rows(self) -> int:
        return len(self.costs)

    @property
    def total_bytes(self) -> float:
        # Cached: batches are immutable in practice and re-routed often
        # (once per strategy under comparison).
        tb = self.__dict__.get("_total_bytes")
        if tb is None:
            tb = self.__dict__["_total_bytes"] = float(self.sizes.sum())
        return tb


@dataclasses.dataclass
class QueryResult:
    latency: float
    utilization: float
    bytes_moved_remote: float
    rows_redistributed: int
    redistribution_applied: bool
    per_worker_busy: np.ndarray
    decision_overhead: float
    num_ticks: int = 0


# --------------------------------------------------------------------- #
# Adaptive link driver (jitted core state machine on host arrays)
# --------------------------------------------------------------------- #


class _JittedMachine:
    """Caches one jitted `state_machine.tick` per (config, n_instances)."""

    _cache: Dict[Tuple, Callable] = {}

    @classmethod
    def get(cls, cfg: DySkewConfig, n: int) -> Callable:
        key = (cfg, n)
        fn = cls._cache.get(key)
        if fn is None:
            fn = jax.jit(partial(_tick_impl, cfg=cfg))
            cls._cache[key] = fn
        return fn


def _tick_impl(link, rows, sync, density, bpr, signal, *, cfg):
    return state_machine.tick(
        link,
        cfg,
        rows_this_tick=rows,
        sync_time_this_tick=sync,
        batch_density=density,
        bytes_per_row=bpr,
        signal_this_tick=signal,
    )


def _host_link_state(n: int, cfg: DySkewConfig) -> Dict[str, np.ndarray]:
    """Host-numpy mirror of `types.link_state_init` (same tree/dtypes, no
    device round-trip — the simulator creates one link per query)."""
    return {
        "state": np.zeros((n,), np.int32),  # LinkState.INIT == 0
        "strikes": np.zeros((n,), np.int32),
        "metrics": {
            "rows": np.zeros((n,), np.float32),
            "idle_ticks": np.zeros((n,), np.float32),
            "sync_window": np.zeros((n, cfg.slope_window), np.float32),
            "batch_density": np.zeros((n,), np.float32),
            "bytes_per_row": np.zeros((n,), np.float32),
        },
        "transitions": np.zeros((n,), np.int32),
        "tick": np.zeros((), np.int32),
    }


class AdaptiveLinkSim:
    """Host-side wrapper around the core state machines for all producer
    link instances of one query (they are siblings of each other)."""

    def __init__(self, cfg: DySkewConfig, n: int):
        self.cfg = cfg
        self.n = n
        # State lives on-device between ticks; only the distribute mask is
        # pulled back each tick (the state tree round-trip dominated the
        # metrics-subsystem cost in the seed implementation).
        self.state = _host_link_state(n, cfg)
        self._tick = _JittedMachine.get(cfg, n)

    def tick(self, rows, sync, density, bpr, signal) -> np.ndarray:
        self.state, distribute = self._tick(
            self.state,
            rows.astype(np.float32),
            sync.astype(np.float32),
            density.astype(np.float32),
            bpr.astype(np.float32),
            signal.astype(bool),
        )
        return np.asarray(distribute)

    @property
    def states(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["state"]))

    @property
    def transitions(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["transitions"]))


# --------------------------------------------------------------------- #
# Routing helpers
# --------------------------------------------------------------------- #


def waterfill_counts(backlog: np.ndarray, k: int, unit: float) -> np.ndarray:
    """Assign ``k`` unit-cost rows to bins so resulting loads are as level
    as possible (vectorized least-backlog greedy for identical costs).

    The continuous water level is solved in closed form (with the j lowest
    backlogs submerged, level_j = (k*unit + sum of those backlogs) / j; the
    true level is the largest j consistent with its own submerged set) and
    the integer counts are floored from it, so no bisection loop is needed;
    the trim/top-up passes below repair the floor rounding exactly.
    """
    n = len(backlog)
    finite = np.isfinite(backlog)
    out = np.zeros(n, np.int64)
    if k == 0:
        return out
    if not finite.any():
        out[0] = k
        return out
    bl = backlog.copy()
    blf = np.sort(bl[finite])
    levels = (k * unit + np.cumsum(blf)) / np.arange(1, len(blf) + 1)
    j = int(np.nonzero(levels >= blf)[0][-1])  # always valid at j=0
    counts = np.floor(np.maximum(levels[j] - bl, 0.0) / unit)
    counts[~finite] = 0
    counts = counts.astype(np.int64)
    diff = int(counts.sum()) - k
    while diff > 0:
        # Trim one item at a time from the currently most-loaded bin —
        # bulk-trimming a single bin un-levels the fill (hypothesis-found).
        loads = np.where(counts > 0, bl + counts * unit, -np.inf)
        d = int(np.argmax(loads))
        counts[d] -= 1
        diff -= 1
    if diff < 0:
        order = np.argsort(np.where(finite, bl + counts * unit, np.inf))
        ne = int(finite.sum())
        i = 0
        while diff < 0:
            counts[order[i % ne]] += 1
            diff += 1
            i += 1
    return counts


class _RowRing:
    """Contiguous FIFO ring of queued row costs for ONE worker.

    Segments are appended with a single vectorized copy; service bursts
    pop a contiguous view.  Popped views must be consumed before the next
    push (a push may compact the buffer).  When ``track_qids`` is set a
    parallel int32 lane records the owning tenant of each row (used by
    the multi-tenant event loop for per-query accounting in shared
    pools; the N=1 loop skips the lane entirely).
    """

    __slots__ = ("buf", "qbuf", "head", "tail")

    def __init__(self, cap: int = 256, track_qids: bool = False):
        self.buf = np.empty(cap, np.float64)
        self.qbuf = np.empty(cap, np.int32) if track_qids else None
        self.head = 0
        self.tail = 0

    def __len__(self) -> int:
        return self.tail - self.head

    def push(self, costs: np.ndarray, qid: int = 0) -> None:
        k = len(costs)
        if self.tail + k > self.buf.size:
            self._compact_grow(k)
        self.buf[self.tail:self.tail + k] = costs
        if self.qbuf is not None:
            self.qbuf[self.tail:self.tail + k] = qid
        self.tail += k

    def _compact_grow(self, k: int) -> None:
        live = self.tail - self.head
        cap = self.buf.size
        while cap < live + k:
            cap *= 2
        if cap > self.buf.size:
            new = np.empty(cap, np.float64)
            new[:live] = self.buf[self.head:self.tail]
            self.buf = new
            if self.qbuf is not None:
                newq = np.empty(cap, np.int32)
                newq[:live] = self.qbuf[self.head:self.tail]
                self.qbuf = newq
        elif live:
            # Slide live region to the front (copy src first if overlapping).
            src = self.buf[self.head:self.tail]
            self.buf[:live] = src.copy() if self.head < live else src
            if self.qbuf is not None:
                qsrc = self.qbuf[self.head:self.tail]
                self.qbuf[:live] = qsrc.copy() if self.head < live else qsrc
        self.head = 0
        self.tail = live

    def pop(self, k: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        k = min(k, self.tail - self.head)
        i = self.head
        self.head += k
        costs = self.buf[i:i + k]
        qids = self.qbuf[i:i + k] if self.qbuf is not None else None
        return costs, qids


def _transfer_delay(c: ClusterConfig, src_worker: int, dst_worker: int,
                    nbytes: float, nrows: int) -> float:
    """Contention-free transfer latency (NIC occupancy handled by the
    caller when model_contention is on)."""
    ser = nrows * c.per_row_serialize
    if c.node_of(src_worker) == c.node_of(dst_worker):
        if src_worker == dst_worker:
            return ser  # stays in-process pipeline; serialization only
        return c.ipc_latency + nbytes / c.ipc_bandwidth + ser
    return c.network_latency + nbytes / c.network_bandwidth + ser


def _group_by_dest(
    dests: np.ndarray, costs: np.ndarray, sizes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group a batch's rows by destination with ONE stable sort.

    Returns (sorted_dests, starts, ends, costs_sorted, sizes_sorted);
    group j covers rows [starts[j], ends[j]) of the sorted arrays and all
    go to destination sorted_dests[starts[j]].  Destinations come out
    ascending and rows keep their in-batch order within a group — the
    same grouping the legacy per-destination boolean masks produced.
    """
    order = np.argsort(dests, kind="stable")
    sd = dests[order]
    bounds = np.flatnonzero(sd[1:] != sd[:-1]) + 1
    starts = np.concatenate(([0], bounds))
    ends = np.concatenate((bounds, [len(sd)]))
    return sd, starts, ends, costs[order], sizes[order]


def closed_form_none_result(
    tenant: "TenantQuery", cluster: ClusterConfig
) -> QueryResult:
    """Vectorized closed form for a 'none'-strategy tenant.

    Without redistribution every producer's rows stay on its own worker,
    so each worker is an independent FIFO server: its completion time is
    the prefix sum of its service-chunk totals starting from the first
    enqueue (arrival + first-batch serialization).  The float operations
    mirror the event loop exactly — within-chunk ``cumsum`` reproduces the
    loop's sequential python-float chunk sums, and the outer ``cumsum``
    reproduces the heap's ``now + total`` accumulation — so the result is
    bit-identical to the event loop whenever no worker idles mid-stream
    and every service pop finds a full chunk queued.  Both hold trivially
    for single-batch streams (the proven regime the engine auto-selects);
    for multi-batch backlogged streams the result is exact up to chunk-
    boundary rounding, and a lower bound if a worker would have idled.
    """
    c = cluster
    n = c.num_workers
    ser = c.per_row_serialize
    busy = np.zeros(n)
    last_done = tenant.arrival
    for p, stream in enumerate(tenant.streams):
        if not stream:
            continue
        costs = (
            stream[0].costs if len(stream) == 1
            else np.concatenate([b.costs for b in stream])
        )
        m = len(costs)
        nchunks = -(-m // _SERVICE_CHUNK)
        padded = np.zeros(nchunks * _SERVICE_CHUNK)
        padded[:m] = costs
        # Sequential within-chunk accumulation (the event loop's python
        # sum), then sequential across chunks (the loop's now += total).
        totals = np.cumsum(
            padded.reshape(nchunks, _SERVICE_CHUNK), axis=1
        )[:, -1]
        first_enqueue = tenant.arrival + len(stream[0].costs) * ser
        walk = np.cumsum(np.concatenate(([first_enqueue], totals)))
        busy[p] = float(np.cumsum(totals)[-1])
        completion = float(walk[-1])
        if completion > last_done:
            last_done = completion
    latency = max(last_done - tenant.arrival, 1e-12)
    return QueryResult(
        latency=float(latency),
        utilization=float(busy.sum() / (latency * n)),
        bytes_moved_remote=0.0,
        rows_redistributed=0,
        redistribution_applied=False,
        per_worker_busy=busy,
        decision_overhead=0.0,
        num_ticks=0,
    )


# --------------------------------------------------------------------- #
# The simulator
# --------------------------------------------------------------------- #

_TICK, _ARRIVAL, _ENQUEUE, _DONE, _ADMITTED, _GTICK = 0, 1, 2, 3, 4, 5

#: Rows per service burst (completion-ack granularity).
_SERVICE_CHUNK = 16


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    kind: str = "dyskew"              # none | static_rr | dyskew
    dyskew: DySkewConfig = dataclasses.field(
        default_factory=lambda: DySkewConfig(policy=Policy.EAGER_SNOWPARK)
    )
    # Metrics-subsystem cadence: state machines tick every `tick_interval`
    # seconds of virtual time.
    tick_interval: float = 50e-3
    # Adaptive-decision CPU overhead charged per routed batch (metrics
    # sampling + state machine + waterfill in the VW worker thread). The
    # legacy static strategy pays none.
    decision_overhead: float = 200e-6
    # EMA horizon for the opaque per-row cost estimate.
    cost_ema: float = 0.2
    # Disable the per-batch admission guards (ablations).
    enable_density_guard: bool = True
    enable_cost_gate: bool = True

    def admission(self) -> BatchAdmission:
        """The shared `repro.core` admission planner for this strategy."""
        return BatchAdmission(
            self.dyskew,
            enable_density_guard=self.enable_density_guard,
            enable_cost_gate=self.enable_cost_gate,
        )


@dataclasses.dataclass
class TenantQuery:
    """One tenant of a multi-query run: its input streams, its strategy,
    when it arrives on the shared cluster (virtual seconds), and its
    fair-share priority weight (only consulted when the engine runs with
    a `FairShareConfig`; higher weight = larger share)."""

    name: str
    streams: List[List[Batch]]
    strategy: StrategyConfig
    arrival: float = 0.0
    arrival_gap: float = 1e-4
    weight: float = 1.0


class MultiQuerySimulator:
    """THE event loop: N concurrent queries over ONE shared cluster.

    Workers (interpreter pools) and per-node NIC uplinks are shared across
    tenants — a straggler pipeline of one query delays everyone behind it
    in the same ring, which is exactly the contention the paper's
    production setting implies.  Each tenant keeps private link state
    machines, cost estimator, backlog counters and tick cadence, so
    redistribution decisions stay per-query.  ``Simulator`` (the
    single-query API) is the N=1 case of this loop.

    ``fair_share`` enables the weighted deficit-round-robin admission
    layer: each batch arrival must clear the tenant's pool/NIC deficit
    before it is routed; over-share arrivals are parked and re-offered in
    round-robin order as completed service earns the tenant credit.

    ``batch_ticks`` selects the tick driver: ``True`` stacks all link
    tenants into shared `BatchedLinkSim` groups advanced by ONE jitted
    call per coalesced tick event (the path that scales to hundreds of
    tenants), ``False`` keeps one `AdaptiveLinkSim` per tenant on its own
    cadence, and ``None`` (default) auto-selects batching only where it
    is provably bit-identical (at most one link tenant).

    ``none_closed_form`` selects the no-event-loop closed form for runs
    whose tenants all use the 'none' strategy on disjoint producers:
    ``None`` (default) applies it only in the proven-exact single-batch
    regime, ``True`` forces it (exact while backlogged, else a lower
    bound), ``False`` always runs the event loop.  See the module
    docstring for the equivalence argument.
    """

    def __init__(
        self,
        cluster: ClusterConfig,
        fair_share: Optional[FairShareConfig] = None,
        batch_ticks: Optional[bool] = None,
        none_closed_form: Optional[bool] = None,
    ):
        # Fully deterministic given the tenants (streams/arrivals carry
        # their own seeds), so no RNG state is held here.
        self.cluster = cluster
        self.fair_share = fair_share
        self.batch_ticks = batch_ticks
        self.none_closed_form = none_closed_form

    def _none_fast_path_ok(self, tenants: List[TenantQuery]) -> bool:
        """True when the closed-form 'none' path may replace the loop."""
        if self.none_closed_form is False or self.fair_share is not None:
            return False
        if not tenants:
            return False
        if any(t.strategy.kind != "none" for t in tenants):
            return False
        # Producers must be disjoint: a worker fed by two tenants serves
        # an interleaved FIFO the per-tenant closed form cannot see.
        seen = set()
        for t in tenants:
            for p, stream in enumerate(t.streams):
                if stream:
                    if p in seen:
                        return False
                    seen.add(p)
        if self.none_closed_form:
            return True
        # Auto: only the regime where the closed form is provably
        # bit-identical to the event loop (single-batch streams — no
        # arrival pacing, no idle gaps, whole-stream chunk boundaries).
        return all(len(s) <= 1 for t in tenants for s in t.streams)

    def _transfer_delay(self, src: int, dst: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src, dst, nbytes, nrows)

    def run(self, tenants: List[TenantQuery]) -> List[QueryResult]:
        c = self.cluster
        n = c.num_workers
        nq = len(tenants)

        if self._none_fast_path_ok(tenants):
            # No redistribution, disjoint producers: per-worker completion
            # times are a prefix sum — skip the event loop entirely.
            return [closed_form_none_result(t, c) for t in tenants]

        # Hot-loop locals: node lookup table, flat network constants, and
        # plain-Python scalar state (single-element numpy indexing is ~10x
        # a list index at this event grain).  Vector math converts the
        # lists once per tick / per routed batch instead.
        node = [w // c.interpreters_per_node for w in range(n)]
        net_bw, net_lat = c.network_bandwidth, c.network_latency
        ipc_bw, ipc_lat = c.ipc_bandwidth, c.ipc_latency
        ser = c.per_row_serialize
        contention = c.model_contention
        flow_window = c.flow_window_rows
        heappush, heappop = heapq.heappush, heapq.heappop

        rings = [_RowRing(track_qids=nq > 1) for _ in range(n)]
        worker_running = [False] * n
        nic_free_at = [0.0] * c.num_nodes

        # Per-tenant state (outer index = tenant).
        strategies = [t.strategy for t in tenants]
        admissions = [t.strategy.admission() for t in tenants]
        streams = [t.streams for t in tenants]
        has_link = [t.strategy.kind == "dyskew" for t in tenants]
        use_batched = self.batch_ticks
        if use_batched is None:
            # Auto: batch only where provably bit-identical to the
            # per-tenant cadence — at most one tenant carries a link.
            use_batched = sum(has_link) <= 1
        links: List[Optional[AdaptiveLinkSim]] = [None] * nq
        # Batched-tick groups: tenants sharing (DySkewConfig,
        # tick_interval) ride one BatchedLinkSim and ONE coalesced grid
        # tick event; entries are (sim, member qids, interval, origin).
        groups: List[Tuple[BatchedLinkSim, List[int], float, float]] = []
        group_of: Dict[int, int] = {}
        if use_batched:
            by_key: Dict[Tuple, List[int]] = {}
            for q in range(nq):
                if has_link[q]:
                    key = (strategies[q].dyskew, strategies[q].tick_interval)
                    by_key.setdefault(key, []).append(q)
            for (cfg_g, interval), members in by_key.items():
                origin = min(tenants[q].arrival for q in members)
                for q in members:
                    group_of[q] = len(groups)
                groups.append((
                    BatchedLinkSim(cfg_g, n, len(members)),
                    members, interval, origin,
                ))
        else:
            for q in range(nq):
                if has_link[q]:
                    links[q] = AdaptiveLinkSim(strategies[q].dyskew, n)
        last_tick: List[Optional[float]] = [None] * nq
        final_tick_done = [False] * nq
        distribute_mask = [[False] * n for _ in range(nq)]
        est_row_cost = [1e-3] * nq
        # Observable backlog: rows sent to each consumer minus rows acked
        # complete (the producer sees its own sends and completion acks;
        # it never sees the hidden per-row costs).
        outstanding = [[0.0] * n for _ in range(nq)]
        recv_in_tick = [[0.0] * n for _ in range(nq)]
        sync_in_tick = [[0.0] * n for _ in range(nq)]
        rows_arr_in_tick = [[0.0] * n for _ in range(nq)]
        batches_arr_in_tick = [[0.0] * n for _ in range(nq)]
        bytes_arr_in_tick = [[0.0] * n for _ in range(nq)]
        # Batched groups keep their per-tick metric accumulators as rows
        # of ONE contiguous (T, n) float64 array per group, so a grid
        # tick consumes them with zero list→array conversion (the
        # conversion dominated the coalesced tick at T≳128).  Event
        # handlers mutate the same views through the per-tenant aliases;
        # scalar `row[w] += x` is the identical IEEE float64 add the
        # list path performs.
        group_acc: List[Dict[str, np.ndarray]] = []
        for sim_g, members, _, _ in groups:
            acc = {
                k: np.zeros((len(members), n))
                for k in ("recv", "sync", "rows", "batches", "bytes")
            }
            group_acc.append(acc)
            for i, q in enumerate(members):
                recv_in_tick[q] = acc["recv"][i]
                sync_in_tick[q] = acc["sync"][i]
                rows_arr_in_tick[q] = acc["rows"][i]
                batches_arr_in_tick[q] = acc["batches"][i]
                bytes_arr_in_tick[q] = acc["bytes"][i]
        busy = [[0.0] * n for _ in range(nq)]
        rows_done = [[0] * n for _ in range(nq)]
        rr_counter = [0] * nq
        bytes_moved = [0.0] * nq
        rows_redist = [0] * nq
        dec_overhead = [0.0] * nq
        num_ticks = [0] * nq
        remaining_arrivals = [sum(len(s) for s in t.streams) for t in tenants]
        rows_total = [
            sum(b.num_rows for s in t.streams for b in s) for t in tenants
        ]
        rows_completed = [0] * nq
        last_done = [t.arrival for t in tenants]

        planner: Optional[FairShareAdmission] = None
        parked: List[Deque[Tuple[int, int]]] = [deque() for _ in range(nq)]
        if self.fair_share is not None and nq > 0:
            planner = FairShareAdmission(
                [t.weight for t in tenants], self.fair_share
            )

        events: List[Tuple[float, int, int, int, int, object]] = []
        seq = 0

        def push(t: float, kind: int, qid: int, who: int, payload: object):
            nonlocal seq
            heappush(events, (t, seq, kind, qid, who, payload))
            seq += 1

        for g, (_, _, _, origin) in enumerate(groups):
            # Grid tick first (lowest seq) so eager links distribute from
            # row one for members arriving at the grid origin.
            push(origin, _GTICK, g, 0, None)
        for q, t in enumerate(tenants):
            # Tick first (lower seq) so eager links distribute from row one.
            if links[q] is not None:
                push(t.arrival, _TICK, q, 0, None)
            elif use_batched and has_link[q]:
                g = group_of[q]
                if t.arrival > groups[g][3]:
                    # Off-grid arrival: one-off masked join tick so this
                    # tenant's eager link engages at arrival instead of
                    # waiting for the next shared grid point.
                    push(t.arrival, _GTICK, g, 0, q)
            for p, stream in enumerate(t.streams):
                if stream:
                    push(t.arrival, _ARRIVAL, q, p, 0)

        def tenant_active(q: int) -> bool:
            return (
                remaining_arrivals[q] > 0
                or rows_completed[q] < rows_total[q]
            )

        def start_worker(w: int, now: float):
            if worker_running[w]:
                return
            ring = rings[w]
            if ring.tail == ring.head:
                return
            chunk, qids = ring.pop(_SERVICE_CHUNK)
            # Sequential Python-float sum: bit-identical to the legacy
            # engine's per-tuple accumulation, so the engines stay on the
            # same event trajectory (tiny rounding differences amplify
            # chaotically through routing decisions).
            total = sum(chunk.tolist())
            if qids is None:
                payload = (total, len(chunk), None, None)
            else:
                counts = np.bincount(qids, minlength=nq)
                # bincount accumulates weights in index order — the same
                # sequential float additions as the single-tenant sum.
                totals = np.bincount(qids, weights=chunk, minlength=nq)
                payload = (total, len(chunk), counts, totals)
            worker_running[w] = True
            push(now + total, _DONE, 0, w, payload)

        def siblings_idle_frac(p: int) -> float:
            idle = 0
            for w in range(n):
                if w != p and not worker_running[w] and rings[w].tail == rings[w].head:
                    idle += 1
            return idle / max(n - 1, 1)

        def route_batch(q: int, p: int, b: Batch, now: float) -> None:
            st = strategies[q]
            cfg = st.dyskew
            admission = admissions[q]
            out_q = outstanding[q]
            dests: Optional[np.ndarray] = None
            if st.kind == "static_rr":
                dests = (rr_counter[q] + np.arange(b.num_rows)) % n
                rr_counter[q] += b.num_rows
            elif distribute_mask[q][p]:
                # Row Size Model admission guard (§III.B): low batch density
                # + no skew benefit visible → keep the heavy rows local.
                bpr = b.total_bytes / max(b.num_rows, 1)
                if not admission.density_guard_blocks(
                    b.num_rows, bpr, lambda: siblings_idle_frac(p)
                ):
                    bl = np.asarray(out_q) * est_row_cost[q]
                    if cfg.self_skip:
                        # Forced-remote ablation (§III.B): the producer must
                        # bypass its own node's interpreters entirely
                        # (Fig. 1 — redistribution targets interpreters on
                        # *other* VW nodes), leaving local CPU idle.
                        bl = np.where(
                            admission.eligible_destinations(n, p, c.node_of),
                            bl, np.inf,
                        )
                    counts = waterfill_counts(
                        bl, b.num_rows, max(est_row_cost[q], 1e-9)
                    )
                    dests = np.repeat(np.arange(n), counts)
                    if st.enable_cost_gate:
                        # Cost gate (§I goal 3): refuse when estimated
                        # movement time exceeds estimated straggler savings.
                        moving = dests != p
                        dec = admission.admit_move(
                            float(b.sizes[moving].sum()), int(moving.sum()),
                            est_row_cost[q], n,
                            net_bw, ser,
                        )
                        if not dec.admit:
                            dests = None

            if dests is None:
                # All-local fast path (no redistribution this batch):
                # in-process pipeline, serialization delay only.
                nrows = b.num_rows
                push(now + nrows * ser, _ENQUEUE, q, p, b.costs)
                out_q[p] += nrows
                return
            sd, starts, ends, costs_s, sizes_s = _group_by_dest(
                dests, b.costs, b.sizes
            )
            # Per-group pairwise .sum() matches the legacy masked sums
            # bit-for-bit (same elements, same order, same algorithm).
            src_node = node[p]
            for j in range(len(starts)):
                lo, hi = starts[j], ends[j]
                d = int(sd[lo])
                nrows = hi - lo
                nbytes = float(sizes_s[lo:hi].sum())
                if node[d] != src_node:
                    rows_redist[q] += nrows
                    bytes_moved[q] += nbytes
                    if contention:
                        # Serialize on the source node's uplink.
                        nf = nic_free_at[src_node]
                        start = now if now > nf else nf
                        occupy = nbytes / net_bw
                        nic_free_at[src_node] = start + occupy
                        arrive = start + occupy + net_lat + nrows * ser
                    else:
                        arrive = now + net_lat + nbytes / net_bw + nrows * ser
                elif d == p:
                    arrive = now + nrows * ser
                else:
                    rows_redist[q] += nrows
                    arrive = now + ipc_lat + nbytes / ipc_bw + nrows * ser
                push(arrive, _ENQUEUE, q, d, costs_s[lo:hi])
                out_q[d] += nrows

        def release_parked(now: float) -> None:
            """Re-offer parked arrivals (round-robin) after new credit."""
            progress = True
            while progress:
                progress = False
                for q in planner.release_order():
                    dq = parked[q]
                    if not dq:
                        continue
                    p, k = dq[0]
                    b = streams[q][p][k]
                    bpr = b.total_bytes / max(b.num_rows, 1)
                    if planner.try_admit(q, b.num_rows, b.total_bytes, bpr):
                        dq.popleft()
                        push(now, _ADMITTED, q, p, k)
                        progress = True

        now = 0.0
        while events:
            now, _, kind, qid, who, payload = heappop(events)
            if kind == _ENQUEUE:
                q, w = qid, who
                rings[w].push(payload, qid=q)
                recv_in_tick[q][w] += len(payload)
                if not worker_running[w]:
                    start_worker(w, now)
            elif kind == _DONE:
                w = who
                total, nrows, counts, totals = payload
                if counts is None:
                    # N=1 specialization: no per-tenant split needed.
                    busy[0][w] += total
                    rows_done[0][w] += nrows
                    sync_in_tick[0][w] += total
                    avg = total / nrows if nrows else 0.0
                    ema = strategies[0].cost_ema
                    est_row_cost[0] = (1 - ema) * est_row_cost[0] + ema * avg
                    left = outstanding[0][w] - nrows
                    outstanding[0][w] = left if left > 0.0 else 0.0
                    rows_completed[0] += nrows
                    last_done[0] = now
                    done_tenants = ((0, nrows),)
                else:
                    done_tenants = []
                    for q in np.flatnonzero(counts):
                        q = int(q)
                        cnt, tot = int(counts[q]), float(totals[q])
                        busy[q][w] += tot
                        rows_done[q][w] += cnt
                        sync_in_tick[q][w] += tot
                        avg = tot / cnt
                        ema = strategies[q].cost_ema
                        est_row_cost[q] = (
                            (1 - ema) * est_row_cost[q] + ema * avg
                        )
                        left = outstanding[q][w] - cnt
                        outstanding[q][w] = left if left > 0.0 else 0.0
                        rows_completed[q] += cnt
                        last_done[q] = now
                        done_tenants.append((q, cnt))
                worker_running[w] = False
                start_worker(w, now)
                if planner is not None:
                    for q, cnt in done_tenants:
                        planner.on_complete(q, cnt)
                        if not tenant_active(q):
                            planner.deactivate(q)
                    release_parked(now)
            elif kind == _ARRIVAL or kind == _ADMITTED:
                q, p, k = qid, who, payload
                st = strategies[q]
                b = streams[q][p][k]
                if planner is not None and kind == _ARRIVAL:
                    bpr = b.total_bytes / max(b.num_rows, 1)
                    if not planner.try_admit(q, b.num_rows, b.total_bytes, bpr):
                        parked[q].append((p, k))
                        continue
                remaining_arrivals[q] -= 1
                rows_arr_in_tick[q][p] += b.num_rows
                batches_arr_in_tick[q][p] += 1
                bytes_arr_in_tick[q][p] += b.total_bytes
                if has_link[q]:
                    dec_overhead[q] += st.decision_overhead
                    now += st.decision_overhead
                route_batch(q, p, b, now)
                if k + 1 < len(streams[q][p]):
                    # Flow control: pace against the least-backlogged valid
                    # destination (own consumer when routing locally).
                    if st.kind == "static_rr" or distribute_mask[q][p]:
                        bl = min(outstanding[q])
                    else:
                        bl = outstanding[q][p]
                    backpressure = max(0.0, bl - flow_window) * est_row_cost[q]
                    push(now + tenants[q].arrival_gap + backpressure,
                         _ARRIVAL, q, p, k + 1)
            elif kind == _TICK:
                q = qid
                num_ticks[q] += 1
                rows_arr = np.asarray(rows_arr_in_tick[q])
                batches_arr = np.asarray(batches_arr_in_tick[q])
                density = np.where(
                    batches_arr > 0,
                    rows_arr / np.maximum(batches_arr, 1),
                    0.0,
                )
                bpr = np.where(
                    rows_arr > 0,
                    np.asarray(bytes_arr_in_tick[q]) / np.maximum(rows_arr, 1),
                    0.0,
                )
                distribute_mask[q] = links[q].tick(
                    np.asarray(recv_in_tick[q]), np.asarray(sync_in_tick[q]),
                    density, bpr, np.asarray(worker_running, bool),
                ).tolist()
                recv_in_tick[q] = [0.0] * n
                sync_in_tick[q] = [0.0] * n
                rows_arr_in_tick[q] = [0.0] * n
                batches_arr_in_tick[q] = [0.0] * n
                bytes_arr_in_tick[q] = [0.0] * n
                if tenant_active(q):
                    push(now + strategies[q].tick_interval, _TICK, q, 0, None)
            else:  # _GTICK — ONE coalesced tick drives a whole group
                g = qid
                sim_g, members, interval, _ = groups[g]
                # A member participates while it has arrived, has not
                # already ticked at this instant (join tick colliding with
                # a grid point), and is active — plus exactly one
                # post-drain tick, mirroring the per-tenant cadence where
                # the already-scheduled tick still fires after drain.
                if payload is None:
                    live = [
                        q for q in members
                        if tenants[q].arrival <= now and last_tick[q] != now
                        and (tenant_active(q) or not final_tick_done[q])
                    ]
                else:
                    q = payload
                    live = (
                        [q] if last_tick[q] != now
                        and (tenant_active(q) or not final_tick_done[q])
                        else []
                    )
                if live:
                    live_set = set(live)
                    active = np.fromiter(
                        (q in live_set for q in members), bool, len(members)
                    )
                    acc = group_acc[g]
                    rows_arr = acc["rows"]
                    batches_arr = acc["batches"]
                    # Same elementwise formulas as the per-tenant tick,
                    # lifted to (T, n) — bit-identical per row.
                    density = np.where(
                        batches_arr > 0,
                        rows_arr / np.maximum(batches_arr, 1),
                        0.0,
                    )
                    bpr = np.where(
                        rows_arr > 0,
                        acc["bytes"] / np.maximum(rows_arr, 1),
                        0.0,
                    )
                    dist = sim_g.tick(
                        acc["recv"], acc["sync"], density, bpr,
                        np.asarray(worker_running, bool),
                        active,
                    )
                    for i, q in enumerate(members):
                        if not active[i]:
                            continue
                        num_ticks[q] += 1
                        last_tick[q] = now
                        distribute_mask[q] = dist[i].tolist()
                        # Slice-assign: the per-tenant aliases must keep
                        # viewing the group rows.
                        recv_in_tick[q][:] = 0.0
                        sync_in_tick[q][:] = 0.0
                        rows_arr_in_tick[q][:] = 0.0
                        batches_arr_in_tick[q][:] = 0.0
                        bytes_arr_in_tick[q][:] = 0.0
                        if not tenant_active(q):
                            final_tick_done[q] = True
                if payload is None and any(tenant_active(q) for q in members):
                    push(now + interval, _GTICK, g, 0, None)

        results: List[QueryResult] = []
        for q, t in enumerate(tenants):
            latency = max(last_done[q] - t.arrival, 1e-12)
            busy_q = np.asarray(busy[q])
            total_rows = int(sum(rows_done[q]))
            applied = rows_redist[q] > 0.01 * max(total_rows, 1)
            results.append(QueryResult(
                latency=float(latency),
                utilization=float(busy_q.sum() / (latency * n)),
                bytes_moved_remote=float(bytes_moved[q]),
                rows_redistributed=int(rows_redist[q]),
                redistribution_applied=bool(applied),
                per_worker_busy=busy_q,
                decision_overhead=float(dec_overhead[q]),
                num_ticks=int(num_ticks[q]),
            ))
        return results


class Simulator:
    """Single-query API: the N=1 case of :class:`MultiQuerySimulator`.

    Kept as the stable entry point for the single-query benches/tests;
    since PR 2 it no longer owns an event loop of its own — the unified
    multi-tenant loop runs the query as a lone tenant arriving at t=0,
    which `tests/test_sim_equivalence.py` pins bit-tight against the seed
    engine (`repro.sim.legacy`).
    """

    def __init__(
        self,
        cluster: ClusterConfig,
        strategy: StrategyConfig,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.strategy = strategy
        self.rng = np.random.default_rng(seed)

    def _transfer_delay(self, src_worker: int, dst_worker: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src_worker, dst_worker,
                               nbytes, nrows)

    def run_query(
        self,
        batches_per_producer: List[List[Batch]],
        arrival_gap: float = 1e-4,
    ) -> QueryResult:
        """Execute one query.

        ``batches_per_producer[i]`` is the (possibly skewed) input stream of
        producer link instance i; batches arrive back-to-back separated by
        ``arrival_gap`` (the scan feeding the UDF operator).
        """
        tenant = TenantQuery(
            name="query",
            streams=batches_per_producer,
            strategy=self.strategy,
            arrival=0.0,
            arrival_gap=arrival_gap,
        )
        return MultiQuerySimulator(self.cluster).run([tenant])[0]
