"""Discrete-event simulator of Snowpark-style UDF execution.

This is the *paper-faithful* layer: it models the asynchronous engine the
paper describes — virtual-warehouse nodes hosting pools of Python
interpreter processes (workers), producer link instances 1:1 with workers,
batches of rows flowing through adaptive data links, and a network that
charges for cross-node row movement.  Per-row UDF cost is OPAQUE to the
link (the defining difficulty in §I): routing decisions use only observed
backlog and the sibling-observable metrics of §III.A.

The state machines/skew models are the exact `repro.core` implementations,
jitted once per configuration and driven with host numpy arrays, so the
simulator and the SPMD training/serving paths share one algorithm.  State
machines tick on a fixed virtual-time cadence (`tick_interval`), modelling
the engine's metrics subsystem; batch routing consults the latest
distribute mask plus the shared per-batch admission planner
(`repro.core.admission`: Row Size Model density guard, cost gate,
self-skip eligibility — the same planner the serving engine and the data
pipeline call).

Strategies are pluggable: ``StrategyConfig.kind`` names a policy in the
`repro.core.policy` registry — ``none`` (default 1:1 link), ``static_rr``
(the legacy Snowpark per-row round-robin, paper §II.B Fig. 1) and
``dyskew`` (the paper's adaptive link) are the built-in trio, and new
policies (``p2c``, ``key_affinity``, ``hillclimb``, ...) land as plugins
through the same seam (`repro.core.policy.available_policies()` lists the
roster; an unknown kind raises at `StrategyConfig` construction).  The
engine asks the POLICY CLASS — never a kind string — which fast paths
apply: ``never_redistributes`` licenses the closed-form 'none' path,
``drain_safe`` the closed-form drain, ``uses_link`` the (batched-)tick
machinery and ``batched_waterfill`` the coalesced-run waterfill planner.

ONE event loop.  ``MultiQuerySimulator.run`` is the only event loop in
this module; ``Simulator.run_query`` is its N=1 specialization (one
tenant, arrival at t=0).  `MultiQuerySimulator` interleaves N concurrent
queries (tenants) over ONE shared cluster — shared interpreter pools and
shared per-node NIC occupancy — while each tenant keeps its own
`AdaptiveLinkSim`, cost estimator, flow-control window and strategy, as in
the paper's production setting where many Snowpark queries contend for the
same virtual warehouse.  Tenants carry priority weights; passing a
`FairShareConfig` turns on the weighted deficit-round-robin admission
layer (`repro.core.admission.FairShareAdmission`), which paces each
tenant's batches into the shared pool/NIC and parks over-share arrivals
until completed service earns them credit.  The result is one
`QueryResult` per tenant (latency measured from the tenant's arrival),
which `sim/replay.py` and `benchmarks/bench_multi_tenant.py` aggregate
into per-tenant percentiles and Jain's fairness index.

Engine invariants (the bars `tests/test_sim_equivalence.py` enforces):

  * Array-backed core.  Queued rows live in contiguous per-worker ring
    buffers (`_RowRing`): ``buf[head:tail]`` is the FIFO of pending row
    costs, pushes are single vectorized segment copies (a push may
    compact/grow, so popped views must be consumed before the next
    push), and a parallel int32 ``qbuf`` lane records each row's owning
    tenant whenever more than one tenant shares the cluster.  Batch
    routing groups rows per destination with ONE stable sort
    (`_group_by_dest`), and event payloads are numpy segments, never
    per-row Python tuples.
  * Bit-exactness bar.  The seed list-of-tuples engine is preserved in
    `repro.sim.legacy`, and the unified loop must reproduce its
    `QueryResult` to rtol=1e-9 for single-tenant runs (and for
    multi-tenant runs that are provably non-interacting).  The
    trajectories are chaotic — one ulp of rounding difference amplifies
    through routing decisions — so the loop keeps the legacy engine's
    float operations in the legacy order: service-burst totals are
    sequential sums (``np.bincount`` weight accumulation, which adds in
    index order), per-destination byte totals use numpy's pairwise
    ``.sum()`` on the same element order the legacy masks produced, and
    the EMA update is ``(1-a)*est + a*(total/rows)``.  Do not "simplify"
    these expressions.
  * Determinism.  Given the same tenants the engine is bit-reproducible:
    no RNG is consulted inside the loop, heap ties break on a
    monotonically increasing sequence number, and the fair-share planner
    is deterministic.  This is what lets `sim/replay.py` fan suites out
    across a process pool (``REPRO_BENCH_WORKERS`` pins the worker
    count; 0/1 = serial) with results identical to the serial run.

Scaling to hundreds of tenants.  Several fast paths keep the loop cheap
at large N, governed by flags on `MultiQuerySimulator` whose ``None``
default enables them only where they are provably equivalent to the
reference trajectory:

  * Batched ticks (``batch_ticks``).  Per-tenant `AdaptiveLinkSim`
    dispatch is replaced by ONE `repro.sim.batched_link.BatchedLinkSim`
    call per shared tick: tenants with the same (DySkewConfig,
    tick_interval) form a group whose (T, n) stacked link state advances
    through a single jitted `tick_many`, driven by one coalesced heap
    event per group cadence with inactive tenants masked.  A tenant
    arriving off-grid gets a one-off masked join tick at its arrival (so
    eager links distribute from row one) and then rides the shared grid.
    ``None`` (auto) decides PER GROUP, batching exactly the proven
    envelope: a single-member group (its grid IS its cadence), or a
    multi-link group whose every member arrives exactly on the group's
    chained tick grid (`_arrivals_on_grid`; identical arrivals are the
    trivial case) — then each member ticks at precisely its per-tenant
    instants and the vmap rows are bit-exact, so the trajectory is
    bit-identical to the per-tenant path.  Off-grid multi-link groups
    fall back to per-tenant links under auto, because the shared grid
    would quantize their tick times; ``batch_ticks=True`` forces them.
    `sim/replay.py::open_loop_tenants(grid_align=...)` snaps open-loop
    arrivals onto the grid so whole suites batch by default.
  * Batched same-instant routing.  A maximal run of arrival events at
    one timestamp is routed through ONE `waterfill_counts_many` call
    per cascade level: different tenants' same-instant batches are
    independent (backlog, estimate and masks are per-tenant), while
    same-tenant batches cascade through its own ``outstanding`` backlog
    and form sequential levels.  All side effects (fair-share
    admission, NIC occupancy, pushes, pacing) apply in heap pop order,
    and same-(time, destination) _ENQUEUE pushes coalesce into one heap
    event whose segments replay individually at pop — bit-identical to
    uncoalesced events.
  * Closed-form drain (``closed_form_drain``).  Once every arrival has
    been routed (checked conservatively: the per-tenant remaining
    counters, which also cover fair-share-parked work, all hit zero),
    no state-machine transition can change the result — routing is the
    only consumer of distribute masks and cost estimates — and workers
    become independent FIFO servers.  The loop exits the heap and
    finishes each worker exactly: a short per-event replay while
    transfers are in flight, then one prefix-sum walk over the loaded
    ring (generalizing `closed_form_none_result`'s bit-order-exact
    accumulation to the mixed-strategy endgame); pending tick cadences
    reduce to closed-form counting (exact up to the constructed-only
    case of a tick time EXACTLY equalling a completion time in float,
    where the closed form's documented tie convention can differ from
    the heap's seq tie-break by one num_ticks — telemetry only).
    ``False`` replays the heap to exhaustion instead (the A/B the bench
    reports).  While any arrival is pending — i.e. while a link
    transition could still affect a routing decision — the heap always
    runs.
  * Closed-form 'none' strategy (``none_closed_form``).  A tenant that
    never redistributes keeps every producer's rows on its own worker,
    so per-worker completion times collapse to a prefix sum over
    service-chunk totals — no event loop needed.  ``None`` (auto) takes
    the closed form only in the proven-exact regime (all tenants 'none',
    no fair share, disjoint producers, single-batch streams);
    ``True`` extends it to multi-batch streams, where it is exact while
    workers stay backlogged and a lower bound otherwise.

SLO layer (all OFF by default — with the defaults the loop takes no new
branches, so the equivalence pins are untouched):

  * Deadline-aware admission (``deadline_aware``).  Tenants carry
    ``slo_target`` (seconds from arrival); the fair-share planner is
    upgraded to `repro.core.admission.DeadlineAwareAdmission`, whose EDF
    credit boost relaxes the admission threshold as slack runs out
    (charging in full — debt — so weighted shares still hold) and whose
    release order re-offers parked work earliest-deadline-first.
  * Preemption (``preemption``).  An urgent tenant whose batch was
    parked may displace admitted-but-unstarted rows of over-share
    tenants: `_RowRing.extract` pulls the victim's rows from the tail
    of the worker rings, they re-enter through fair share
    (`release_parked`) and return to their ORIGINAL worker (transfer
    already paid; only the rows lane is re-charged).  The closed-form
    drain's conservative detector counts preempt-parked rows as pending
    work, so the drain cannot fire while any displaced row awaits
    re-injection.
  * Autoscaling (``autoscale``).  A recurring RESIZE heap event feeds
    `AutoscalePolicy` the queued-row backlog and running SLO attainment
    and resizes the active pool in whole workers.  Decommissioned
    workers drain gracefully but are ineligible destinations (+inf
    waterfill backlog; static_rr cycles the active set; a
    decommissioned producer's scan re-targets the least-backlogged
    active worker and pays the transfer).  Post-drain RESIZE events are
    inert.

Per-event hygiene: the density guard's idle-sibling fraction comes from
an incrementally-maintained idle-worker census (not an O(n) scan per
batch), and every run records per-kind event counters in
``MultiQuerySimulator.last_event_counts`` (heap pops by kind, arrivals
coalesced, enqueues coalesced, batched waterfill rows, drain stats) —
the bench surfaces them so event-count reductions are directly visible.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from functools import partial
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import state_machine
from repro.core.admission import (
    AutoscaleConfig,
    AutoscalePolicy,
    DeadlineAwareAdmission,
    DeadlineConfig,
    FairShareAdmission,
    FairShareConfig,
)
# The policy seam lives in repro.core.policy since the registry refactor;
# StrategyConfig and the waterfill trio are re-exported here because the
# legacy oracle (`repro.sim.legacy`) and the test suite import them from
# this module.  noqa: F401 on the re-exports.
from repro.core.policy import (  # noqa: F401
    PolicyContext,
    RedistributionPolicy,
    StrategyConfig,
    _waterfill_repair,
    available_policies,
    register_policy,
    resolve_policy,
    waterfill_counts,
    waterfill_counts_many,
)
from repro.core.types import DySkewConfig, Policy
from repro.runtime.fault_tolerance import FaultConfig, FaultTolerantRuntime
from repro.sim.batched_link import BatchedLinkSim
from repro.sim.faults import (
    NIC_DEGRADE,
    PREEMPT,
    SLOWDOWN,
    FaultSchedule,
    default_sim_fault_config,
)


# --------------------------------------------------------------------- #
# Cluster / workload dataclasses
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    num_nodes: int = 4
    interpreters_per_node: int = 8
    # Cross-node NIC bandwidth and per-batch latency.
    network_bandwidth: float = 1.25e9      # bytes/s (10 GbE)
    network_latency: float = 200e-6        # s per cross-node batch hop
    # Same-node IPC (VW thread → interpreter) costs.
    ipc_bandwidth: float = 8e9
    ipc_latency: float = 20e-6
    # Fixed serialization overhead per row crossing a process boundary
    # (§III.B: 'mandatory data serialization across process boundaries').
    per_row_serialize: float = 2e-6
    # Model per-node egress NIC occupancy (transfers from one node
    # serialize on its uplink — what saturates on 100 GB+ heavy rows).
    model_contention: bool = True
    # Credit-based flow control: a producer pauses once its destination's
    # outstanding (sent-unacked) rows exceed this window. Link-level
    # redistribution relieves exactly this backpressure — the mechanism by
    # which DySkew unblocks straggler pipelines.
    flow_window_rows: int = 32

    @property
    def num_workers(self) -> int:
        return self.num_nodes * self.interpreters_per_node

    def node_of(self, worker: int) -> int:
        return worker // self.interpreters_per_node


@dataclasses.dataclass
class Batch:
    """A rowset batch: costs are the TRUE (hidden) per-row UDF seconds.

    ``ids`` is an optional per-row lineage lane (tenant-local row
    indices in ``[0, total rows of the tenant)``): when present AND the
    engine runs with ``trace_placement=True``, the final worker of each
    row is recorded in ``MultiQuerySimulator.last_placement`` — the hook
    the pipeline layer (`repro.sim.pipeline`) uses to propagate skew
    across chained stages.  The lane is never read on the hot path
    otherwise, and tracing itself performs no float arithmetic, so it
    cannot perturb the legacy-equivalence trajectory.
    """

    costs: np.ndarray   # (rows,) float64
    sizes: np.ndarray   # (rows,) float64 bytes
    ids: Optional[np.ndarray] = None   # (rows,) int64 lineage ids

    @property
    def num_rows(self) -> int:
        return len(self.costs)

    @property
    def total_bytes(self) -> float:
        # Cached: batches are immutable in practice and re-routed often
        # (once per strategy under comparison).
        tb = self.__dict__.get("_total_bytes")
        if tb is None:
            tb = self.__dict__["_total_bytes"] = float(self.sizes.sum())
        return tb


@dataclasses.dataclass
class QueryResult:
    latency: float
    utilization: float
    bytes_moved_remote: float
    rows_redistributed: int
    redistribution_applied: bool
    per_worker_busy: np.ndarray
    decision_overhead: float
    num_ticks: int = 0
    #: Rows of this tenant displaced back through fair share by the SLO
    #: preemption path (0 unless the engine ran with ``preemption=True``).
    preempted_rows: int = 0


# --------------------------------------------------------------------- #
# Adaptive link driver (jitted core state machine on host arrays)
# --------------------------------------------------------------------- #


class _JittedMachine:
    """Caches one jitted `state_machine.tick` per (config, n_instances)."""

    _cache: Dict[Tuple, Callable] = {}

    @classmethod
    def get(cls, cfg: DySkewConfig, n: int) -> Callable:
        key = (cfg, n)
        fn = cls._cache.get(key)
        if fn is None:
            fn = jax.jit(partial(_tick_impl, cfg=cfg))
            cls._cache[key] = fn
        return fn


def _tick_impl(link, rows, sync, density, bpr, signal, *, cfg):
    return state_machine.tick(
        link,
        cfg,
        rows_this_tick=rows,
        sync_time_this_tick=sync,
        batch_density=density,
        bytes_per_row=bpr,
        signal_this_tick=signal,
    )


def _host_link_state(n: int, cfg: DySkewConfig) -> Dict[str, np.ndarray]:
    """Host-numpy mirror of `types.link_state_init` (same tree/dtypes, no
    device round-trip — the simulator creates one link per query)."""
    return {
        "state": np.zeros((n,), np.int32),  # LinkState.INIT == 0
        "strikes": np.zeros((n,), np.int32),
        "metrics": {
            "rows": np.zeros((n,), np.float32),
            "idle_ticks": np.zeros((n,), np.float32),
            "sync_window": np.zeros((n, cfg.slope_window), np.float32),
            "batch_density": np.zeros((n,), np.float32),
            "bytes_per_row": np.zeros((n,), np.float32),
        },
        "transitions": np.zeros((n,), np.int32),
        "tick": np.zeros((), np.int32),
    }


class AdaptiveLinkSim:
    """Host-side wrapper around the core state machines for all producer
    link instances of one query (they are siblings of each other)."""

    def __init__(self, cfg: DySkewConfig, n: int):
        self.cfg = cfg
        self.n = n
        # State lives on-device between ticks; only the distribute mask is
        # pulled back each tick (the state tree round-trip dominated the
        # metrics-subsystem cost in the seed implementation).
        self.state = _host_link_state(n, cfg)
        self._tick = _JittedMachine.get(cfg, n)

    def tick(self, rows, sync, density, bpr, signal) -> np.ndarray:
        self.state, distribute = self._tick(
            self.state,
            rows.astype(np.float32),
            sync.astype(np.float32),
            density.astype(np.float32),
            bpr.astype(np.float32),
            signal.astype(bool),
        )
        return np.asarray(distribute)

    @property
    def states(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["state"]))

    @property
    def transitions(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["transitions"]))


# --------------------------------------------------------------------- #
# Routing helpers
# --------------------------------------------------------------------- #

# (`_waterfill_repair` / `waterfill_counts` / `waterfill_counts_many`
# moved verbatim to `repro.core.policy` with the registry refactor and
# are re-exported above.)


class _RowRing:
    """Contiguous FIFO ring of queued row costs for ONE worker.

    Segments are appended with a single vectorized copy; service bursts
    pop a contiguous view.  Popped views must be consumed before the next
    push (a push may compact the buffer).  When ``track_qids`` is set a
    parallel int32 lane records the owning tenant of each row (used by
    the multi-tenant event loop for per-query accounting in shared
    pools; the N=1 loop skips the lane entirely).
    """

    __slots__ = ("buf", "qbuf", "head", "tail")

    def __init__(self, cap: int = 256, track_qids: bool = False):
        self.buf = np.empty(cap, np.float64)
        self.qbuf = np.empty(cap, np.int32) if track_qids else None
        self.head = 0
        self.tail = 0

    def __len__(self) -> int:
        return self.tail - self.head

    def push(self, costs: np.ndarray, qid: int = 0) -> None:
        k = len(costs)
        if self.tail + k > self.buf.size:
            self._compact_grow(k)
        self.buf[self.tail:self.tail + k] = costs
        if self.qbuf is not None:
            self.qbuf[self.tail:self.tail + k] = qid
        self.tail += k

    def _compact_grow(self, k: int) -> None:
        live = self.tail - self.head
        cap = self.buf.size
        while cap < live + k:
            cap *= 2
        if cap > self.buf.size:
            new = np.empty(cap, np.float64)
            new[:live] = self.buf[self.head:self.tail]
            self.buf = new
            if self.qbuf is not None:
                newq = np.empty(cap, np.int32)
                newq[:live] = self.qbuf[self.head:self.tail]
                self.qbuf = newq
        elif live:
            # Slide live region to the front (copy src first if overlapping).
            src = self.buf[self.head:self.tail]
            self.buf[:live] = src.copy() if self.head < live else src
            if self.qbuf is not None:
                qsrc = self.qbuf[self.head:self.tail]
                self.qbuf[:live] = qsrc.copy() if self.head < live else qsrc
        self.head = 0
        self.tail = live

    def pop(self, k: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        k = min(k, self.tail - self.head)
        i = self.head
        self.head += k
        costs = self.buf[i:i + k]
        qids = self.qbuf[i:i + k] if self.qbuf is not None else None
        return costs, qids

    def extract(self, qid: int, max_rows: int) -> np.ndarray:
        """Remove up to ``max_rows`` rows owned by ``qid`` from the queued
        region (taken from the TAIL end — the rows that would have been
        served last), compacting the survivors in FIFO order.  Returns
        the extracted costs.  Requires the tenant lane (``track_qids``);
        used by the SLO preemption path to re-park admitted-but-unstarted
        service of an over-share tenant."""
        if self.qbuf is None or self.tail == self.head or max_rows <= 0:
            return np.empty(0, np.float64)
        seg_q = self.qbuf[self.head:self.tail]
        idx = np.flatnonzero(seg_q == qid)
        if not len(idx):
            return np.empty(0, np.float64)
        if len(idx) > max_rows:
            idx = idx[-max_rows:]
        seg_c = self.buf[self.head:self.tail]
        costs = seg_c[idx].copy()
        keep = np.ones(len(seg_q), bool)
        keep[idx] = False
        live_c = seg_c[keep]      # fancy indexing copies — safe to write back
        live_q = seg_q[keep]
        m = len(live_c)
        self.buf[self.head:self.head + m] = live_c
        self.qbuf[self.head:self.head + m] = live_q
        self.tail = self.head + m
        return costs


def _transfer_delay(c: ClusterConfig, src_worker: int, dst_worker: int,
                    nbytes: float, nrows: int) -> float:
    """Contention-free transfer latency (NIC occupancy handled by the
    caller when model_contention is on)."""
    ser = nrows * c.per_row_serialize
    if c.node_of(src_worker) == c.node_of(dst_worker):
        if src_worker == dst_worker:
            return ser  # stays in-process pipeline; serialization only
        return c.ipc_latency + nbytes / c.ipc_bandwidth + ser
    return c.network_latency + nbytes / c.network_bandwidth + ser


def _group_by_dest(
    dests: np.ndarray, costs: np.ndarray, sizes: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Group a batch's rows by destination with ONE stable sort.

    Returns (sorted_dests, starts, ends, costs_sorted, sizes_sorted);
    group j covers rows [starts[j], ends[j]) of the sorted arrays and all
    go to destination sorted_dests[starts[j]].  Destinations come out
    ascending and rows keep their in-batch order within a group — the
    same grouping the legacy per-destination boolean masks produced.
    """
    order = np.argsort(dests, kind="stable")
    sd = dests[order]
    bounds = np.flatnonzero(sd[1:] != sd[:-1]) + 1
    starts = np.concatenate(([0], bounds))
    ends = np.concatenate((bounds, [len(sd)]))
    return sd, starts, ends, costs[order], sizes[order]


def _producer_placement(tenant: "TenantQuery") -> Optional[np.ndarray]:
    """Placement of a 'none'-strategy tenant in closed form: every
    lineage-tagged row stays on its producing worker (the exact property
    `closed_form_none_result` relies on).  None when no batch carries an
    ids lane."""
    hi = -1
    for stream in tenant.streams:
        for b in stream:
            if b.ids is not None and len(b.ids):
                hi = max(hi, int(b.ids.max()))
    if hi < 0:
        return None
    place = np.full(hi + 1, -1, np.int64)
    for p, stream in enumerate(tenant.streams):
        for b in stream:
            if b.ids is not None:
                place[b.ids] = p
    return place


def closed_form_none_result(
    tenant: "TenantQuery", cluster: ClusterConfig
) -> QueryResult:
    """Vectorized closed form for a 'none'-strategy tenant.

    Without redistribution every producer's rows stay on its own worker,
    so each worker is an independent FIFO server: its completion time is
    the prefix sum of its service-chunk totals starting from the first
    enqueue (arrival + first-batch serialization).  The float operations
    mirror the event loop exactly — within-chunk ``cumsum`` reproduces the
    loop's sequential python-float chunk sums, and the outer ``cumsum``
    reproduces the heap's ``now + total`` accumulation — so the result is
    bit-identical to the event loop whenever no worker idles mid-stream
    and every service pop finds a full chunk queued.  Both hold trivially
    for single-batch streams (the proven regime the engine auto-selects);
    for multi-batch backlogged streams the result is exact up to chunk-
    boundary rounding, and a lower bound if a worker would have idled.
    """
    c = cluster
    n = c.num_workers
    ser = c.per_row_serialize
    busy = np.zeros(n)
    last_done = tenant.arrival
    for p, stream in enumerate(tenant.streams):
        if not stream:
            continue
        costs = (
            stream[0].costs if len(stream) == 1
            else np.concatenate([b.costs for b in stream])
        )
        m = len(costs)
        nchunks = -(-m // _SERVICE_CHUNK)
        padded = np.zeros(nchunks * _SERVICE_CHUNK)
        padded[:m] = costs
        # Sequential within-chunk accumulation (the event loop's python
        # sum), then sequential across chunks (the loop's now += total).
        totals = np.cumsum(
            padded.reshape(nchunks, _SERVICE_CHUNK), axis=1
        )[:, -1]
        first_enqueue = tenant.arrival + len(stream[0].costs) * ser
        walk = np.cumsum(np.concatenate(([first_enqueue], totals)))
        busy[p] = float(np.cumsum(totals)[-1])
        completion = float(walk[-1])
        if completion > last_done:
            last_done = completion
    latency = max(last_done - tenant.arrival, 1e-12)
    return QueryResult(
        latency=float(latency),
        utilization=float(busy.sum() / (latency * n)),
        bytes_moved_remote=0.0,
        rows_redistributed=0,
        redistribution_applied=False,
        per_worker_busy=busy,
        decision_overhead=0.0,
        num_ticks=0,
    )


# --------------------------------------------------------------------- #
# The simulator
# --------------------------------------------------------------------- #

_TICK, _ARRIVAL, _ENQUEUE, _DONE, _ADMITTED, _GTICK, _RESIZE = (
    0, 1, 2, 3, 4, 5, 6
)
# Fault layer: FAIL pulls a worker (crash / end of spot drain) or opens a
# slowdown/NIC window; PREEMPT_NOTICE starts a spot drain (routing stops,
# service continues); RECOVER closes a transient window or rejoins a
# replaced worker; HBEAT drives virtual-time heartbeats + detection.
# None of these is ever pushed when the fault schedule is empty.
_FAIL, _PREEMPT_NOTICE, _RECOVER, _HBEAT = 7, 8, 9, 10

_KIND_NAMES = (
    "tick", "arrival", "enqueue", "done", "admitted", "gtick", "resize",
    "fail", "preempt_notice", "recover", "hbeat",
)

#: Rows per service burst (completion-ack granularity).
_SERVICE_CHUNK = 16

#: Sentinel: route_batch computes the destinations itself (no precomputed
#: waterfill plan from a coalesced same-time arrival run).
_RB_INLINE = object()


def _arrivals_on_grid(
    arrivals: List[float], interval: float, max_steps: int = 1 << 20
) -> bool:
    """True when every arrival lies exactly on the chained float grid
    ``origin, origin+I, (origin+I)+I, ...`` that the engine's coalesced
    group tick walks (``push(now + interval)`` from the earliest arrival).

    This is the provable batched-tick equivalence condition for a
    multi-link tenant group: a member arriving at a chained grid value
    ticks at exactly the instants its per-tenant cadence would (both
    chains advance by single float additions of ``interval`` from equal
    values), so the shared grid quantizes nothing.  Identical arrivals
    are the trivial case (every arrival IS the origin).  The check is
    exact float equality — conservative by construction.
    """
    uniq = sorted(set(arrivals))
    t = uniq[0]
    steps = 0
    for a in uniq[1:]:
        while t < a:
            t += interval
            steps += 1
            if steps > max_steps:
                return False
        if t != a:
            return False
    return True


# (`StrategyConfig` moved to `repro.core.policy` with the registry
# refactor — it now validates `kind` against the registry at construction
# — and is re-exported above for the legacy oracle and existing callers.)


@dataclasses.dataclass
class TenantQuery:
    """One tenant of a multi-query run: its input streams, its strategy,
    when it arrives on the shared cluster (virtual seconds), and its
    fair-share priority weight (only consulted when the engine runs with
    a `FairShareConfig`; higher weight = larger share)."""

    name: str
    streams: List[List[Batch]]
    strategy: StrategyConfig
    arrival: float = 0.0
    arrival_gap: float = 1e-4
    weight: float = 1.0
    #: SLO target: seconds from arrival to last-row completion.  None =
    #: no deadline.  Consulted only when the engine runs with
    #: ``deadline_aware=True`` (and by the replay harness's attainment
    #: metrics); otherwise inert.
    slo_target: Optional[float] = None


class MultiQuerySimulator:
    """THE event loop: N concurrent queries over ONE shared cluster.

    Workers (interpreter pools) and per-node NIC uplinks are shared across
    tenants — a straggler pipeline of one query delays everyone behind it
    in the same ring, which is exactly the contention the paper's
    production setting implies.  Each tenant keeps private link state
    machines, cost estimator, backlog counters and tick cadence, so
    redistribution decisions stay per-query.  ``Simulator`` (the
    single-query API) is the N=1 case of this loop.

    ``fair_share`` enables the weighted deficit-round-robin admission
    layer: each batch arrival must clear the tenant's pool/NIC deficit
    before it is routed; over-share arrivals are parked and re-offered in
    round-robin order as completed service earns the tenant credit.

    ``batch_ticks`` selects the tick driver: ``True`` stacks all link
    tenants into shared `BatchedLinkSim` groups advanced by ONE jitted
    call per coalesced tick event (the path that scales to hundreds of
    tenants), ``False`` keeps one `AdaptiveLinkSim` per tenant on its own
    cadence, and ``None`` (default) auto-selects batching PER GROUP
    where it is provably bit-identical: single-member groups and
    multi-link groups whose members all arrive exactly on the group's
    chained tick grid (identical arrivals included — see
    `_arrivals_on_grid`).

    ``closed_form_drain`` (default on; ``False`` disables) exits the
    heap once every arrival has been routed and finishes each worker by
    bit-order-exact prefix sums, recovering the remaining tick counts in
    closed form — the endgame of every run stops paying per-event cost.

    ``none_closed_form`` selects the no-event-loop closed form for runs
    whose tenants all use the 'none' strategy on disjoint producers:
    ``None`` (default) applies it only in the proven-exact single-batch
    regime, ``True`` forces it (exact while backlogged, else a lower
    bound), ``False`` always runs the event loop.  See the module
    docstring for the equivalence arguments.
    """

    def __init__(
        self,
        cluster: ClusterConfig,
        fair_share: Optional[FairShareConfig] = None,
        batch_ticks: Optional[bool] = None,
        none_closed_form: Optional[bool] = None,
        closed_form_drain: Optional[bool] = None,
        deadline_aware: bool = False,
        deadline_cfg: Optional[DeadlineConfig] = None,
        preemption: bool = False,
        autoscale: Optional[AutoscaleConfig] = None,
        faults: Optional[FaultSchedule] = None,
        fault_cfg: Optional[FaultConfig] = None,
        trace_placement: bool = False,
        seed: int = 0,
    ):
        # Fully deterministic given (tenants, seed): the streams/arrivals
        # carry their own seeds, and `seed` only feeds the per-tenant
        # policy RNG streams (child streams [seed, q]) — the
        # deterministic built-in policies never consult theirs, so the
        # legacy no-RNG-in-the-loop invariant still holds for them.
        self.seed = seed
        self.cluster = cluster
        self.fair_share = fair_share
        self.batch_ticks = batch_ticks
        self.none_closed_form = none_closed_form
        self.closed_form_drain = closed_form_drain
        # SLO layer (all default OFF — with the defaults the loop takes
        # not a single new branch, so the legacy equivalence pin is
        # untouched).  ``deadline_aware`` upgrades the fair-share planner
        # to `DeadlineAwareAdmission` (tenants' `slo_target` become
        # admission deadlines with an EDF credit boost); ``preemption``
        # lets an urgent tenant displace admitted-but-unstarted rows of
        # over-share tenants back through fair share; ``autoscale``
        # schedules a recurring RESIZE event that grows/shrinks the
        # active interpreter pool per `AutoscalePolicy`.
        if deadline_aware and fair_share is None:
            raise ValueError(
                "deadline_aware requires fair_share (the deadline-aware "
                "planner is an upgrade of the fair-share layer)"
            )
        if preemption and not deadline_aware:
            raise ValueError(
                "preemption requires deadline_aware (victims are picked "
                "by the deadline-aware planner)"
            )
        self.deadline_aware = deadline_aware
        self.deadline_cfg = deadline_cfg
        self.preemption = preemption
        self.autoscale = autoscale
        # Fault layer (default OFF like the SLO layer above: with
        # ``faults=None`` or an empty schedule no fault event is pushed
        # and no fault branch is taken).  ``fault_cfg`` tunes detection
        # (`FaultConfig`); None means `default_sim_fault_config()`.
        if faults is not None:
            faults.validate(cluster.num_workers, cluster.num_nodes)
        self.faults = faults
        self.fault_cfg = fault_cfg
        #: Fault/recovery telemetry of the most recent `run` (always set;
        #: ``{'enabled': False}``-shaped when no schedule was active).
        self.last_fault_stats: Dict[str, object] = {}
        #: Record the final worker of every lineage-tagged row (requires
        #: ``Batch.ids``).  Purely observational: the tracing branch does
        #: no float arithmetic and no RNG draws, so a traced run is
        #: bit-identical to an untraced one (pinned by
        #: tests/test_pipeline.py's differential test).
        self.trace_placement = trace_placement
        #: Per-tenant (rows,) int64 final-worker arrays of the most
        #: recent traced `run` (None for tenants without an ids lane).
        self.last_placement: List[Optional[np.ndarray]] = []
        #: Per-kind event counters of the most recent `run` (heap events
        #: popped by kind, coalescing stats, drain stats).  Telemetry
        #: only — reported by `benchmarks/bench_multi_tenant.py`.
        self.last_event_counts: Dict[str, int] = {}
        #: (time, old, new) resize log of the most recent autoscaled run.
        self.last_resizes: List[Tuple[float, int, int]] = []

    def _none_fast_path_ok(self, tenants: List[TenantQuery]) -> bool:
        """True when the closed-form 'none' path may replace the loop."""
        if self.none_closed_form is False or self.fair_share is not None:
            return False
        if self.autoscale is not None:
            return False
        if self.faults is not None and len(self.faults.events) > 0:
            return False
        if not tenants:
            return False
        if any(
            not resolve_policy(t.strategy.kind).never_redistributes
            for t in tenants
        ):
            return False
        # Producers must be disjoint: a worker fed by two tenants serves
        # an interleaved FIFO the per-tenant closed form cannot see.
        seen = set()
        for t in tenants:
            for p, stream in enumerate(t.streams):
                if stream:
                    if p in seen:
                        return False
                    seen.add(p)
        if self.none_closed_form:
            return True
        # Auto: only the regime where the closed form is provably
        # bit-identical to the event loop (single-batch streams — no
        # arrival pacing, no idle gaps, whole-stream chunk boundaries).
        return all(len(s) <= 1 for t in tenants for s in t.streams)

    def _transfer_delay(self, src: int, dst: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src, dst, nbytes, nrows)

    def run(self, tenants: List[TenantQuery]) -> List[QueryResult]:
        c = self.cluster
        n = c.num_workers
        nq = len(tenants)

        if self._none_fast_path_ok(tenants):
            # No redistribution, disjoint producers: per-worker completion
            # times are a prefix sum — skip the event loop entirely.
            self.last_event_counts = {"none_closed_form_tenants": nq}
            self.last_fault_stats = {"enabled": False}
            if self.trace_placement:
                self.last_placement = [
                    _producer_placement(t) for t in tenants
                ]
            return [closed_form_none_result(t, c) for t in tenants]

        # Hot-loop locals: node lookup table, flat network constants, and
        # plain-Python scalar state (single-element numpy indexing is ~10x
        # a list index at this event grain).  Vector math converts the
        # lists once per tick / per routed batch instead.
        node = [w // c.interpreters_per_node for w in range(n)]
        net_bw, net_lat = c.network_bandwidth, c.network_latency
        ipc_bw, ipc_lat = c.ipc_bandwidth, c.ipc_latency
        ser = c.per_row_serialize
        contention = c.model_contention
        flow_window = c.flow_window_rows
        heappush, heappop = heapq.heappush, heapq.heappop

        rings = [_RowRing(track_qids=nq > 1) for _ in range(n)]
        worker_running = [False] * n
        nic_free_at = [0.0] * c.num_nodes
        # Incrementally-maintained idle-worker census (a worker is idle
        # iff it is not running and its ring is empty).  Replaces the
        # per-batch O(n) sibling scan the density guard used to pay.
        worker_idle = [True] * n
        idle_count = n

        # Per-tenant state (outer index = tenant).
        strategies = [t.strategy for t in tenants]
        streams = [t.streams for t in tenants]
        # Capability flags come from the POLICY CLASS, not a kind string:
        # the registry is the single source of truth for which engine
        # machinery (links, overhead billing, batched planning) applies.
        pol_cls = [resolve_policy(t.strategy.kind) for t in tenants]
        has_link = [cls.uses_link for cls in pol_cls]
        pays_overhead = [cls.pays_decision_overhead for cls in pol_cls]
        batched_wf = [cls.batched_waterfill for cls in pol_cls]
        links: List[Optional[AdaptiveLinkSim]] = [None] * nq
        # Batched-tick groups: tenants sharing (DySkewConfig,
        # tick_interval) ride one BatchedLinkSim and ONE coalesced grid
        # tick event; entries are (sim, member qids, interval, origin).
        # ``batch_ticks=None`` (auto) decides PER GROUP: a group batches
        # when it is provably bit-identical to the per-tenant cadence —
        # a single member (its grid IS its cadence), or every member
        # arriving exactly on the group's chained tick grid (see
        # `_arrivals_on_grid`; identical arrivals are the trivial case).
        # Groups failing the check fall back to per-tenant links.
        groups: List[Tuple[BatchedLinkSim, List[int], float, float]] = []
        group_of: Dict[int, int] = {}
        by_key: Dict[Tuple, List[int]] = {}
        for q in range(nq):
            if has_link[q]:
                key = (strategies[q].dyskew, strategies[q].tick_interval)
                by_key.setdefault(key, []).append(q)
        for (cfg_g, interval), members in by_key.items():
            if self.batch_ticks is None:
                batch_group = len(members) == 1 or _arrivals_on_grid(
                    [tenants[q].arrival for q in members], interval
                )
            else:
                batch_group = self.batch_ticks
            if batch_group:
                origin = min(tenants[q].arrival for q in members)
                for q in members:
                    group_of[q] = len(groups)
                groups.append((
                    BatchedLinkSim(cfg_g, n, len(members)),
                    members, interval, origin,
                ))
            else:
                for q in members:
                    links[q] = AdaptiveLinkSim(strategies[q].dyskew, n)
        # Per-group member state as contiguous arrays (the per-tick live
        # scan used to rebuild python lists per event — at T≳128 that
        # dominated the coalesced tick's host cost).
        member_slot: Dict[int, Tuple[int, int]] = {}
        grp_members_arr: List[np.ndarray] = []
        grp_arrival: List[np.ndarray] = []
        grp_last_tick: List[np.ndarray] = []
        grp_active: List[np.ndarray] = []
        grp_final: List[np.ndarray] = []
        for g, (_, members, _, _) in enumerate(groups):
            for i, q in enumerate(members):
                member_slot[q] = (g, i)
            grp_members_arr.append(np.asarray(members, np.int64))
            grp_arrival.append(
                np.asarray([tenants[q].arrival for q in members])
            )
            grp_last_tick.append(np.full(len(members), np.nan))
            grp_active.append(np.ones(len(members), bool))
            grp_final.append(np.zeros(len(members), bool))
        est_row_cost = [1e-3] * nq
        # Observable backlog: rows sent to each consumer minus rows acked
        # complete (the producer sees its own sends and completion acks;
        # it never sees the hidden per-row costs).
        outstanding = [[0.0] * n for _ in range(nq)]
        recv_in_tick = [[0.0] * n for _ in range(nq)]
        sync_in_tick = [[0.0] * n for _ in range(nq)]
        rows_arr_in_tick = [[0.0] * n for _ in range(nq)]
        batches_arr_in_tick = [[0.0] * n for _ in range(nq)]
        bytes_arr_in_tick = [[0.0] * n for _ in range(nq)]
        # Batched groups keep their per-tick metric accumulators as rows
        # of ONE contiguous (T, n) float64 array per group, so a grid
        # tick consumes them with zero list→array conversion (the
        # conversion dominated the coalesced tick at T≳128).  Event
        # handlers mutate the same views through the per-tenant aliases;
        # scalar `row[w] += x` is the identical IEEE float64 add the
        # list path performs.
        group_acc: List[Dict[str, np.ndarray]] = []
        for sim_g, members, _, _ in groups:
            acc = {
                k: np.zeros((len(members), n))
                for k in ("recv", "sync", "rows", "batches", "bytes")
            }
            group_acc.append(acc)
            for i, q in enumerate(members):
                recv_in_tick[q] = acc["recv"][i]
                sync_in_tick[q] = acc["sync"][i]
                rows_arr_in_tick[q] = acc["rows"][i]
                batches_arr_in_tick[q] = acc["batches"][i]
                bytes_arr_in_tick[q] = acc["bytes"][i]
        busy = [[0.0] * n for _ in range(nq)]
        rows_done = [[0] * n for _ in range(nq)]
        bytes_moved = [0.0] * nq
        rows_redist = [0] * nq
        dec_overhead = [0.0] * nq
        num_ticks = np.zeros(nq, np.int64)
        remaining_arrivals = [sum(len(s) for s in t.streams) for t in tenants]
        total_remaining = sum(remaining_arrivals)
        rows_total = [
            sum(b.num_rows for s in t.streams for b in s) for t in tenants
        ]
        # Lineage tracing (observational only — see __init__): per-tenant
        # final-worker arrays, written where routing fixes a row's home.
        # Preemption re-parks rows to their ORIGINAL worker, so a row's
        # placement never changes after its batch is routed.
        trace: Optional[List[Optional[np.ndarray]]] = None
        if self.trace_placement:
            trace = [None] * nq
            self.last_placement = trace
        rows_completed = [0] * nq
        last_done = [t.arrival for t in tenants]
        # tenant_active(q), maintained incrementally: flips False exactly
        # once — at the _DONE event completing the tenant's last row
        # after its arrivals are exhausted, or at the tenant's last
        # arrival when there is no row left to complete (zero-row
        # batches), matching the old live recomputation at both
        # observation points.
        active_flag = [
            remaining_arrivals[q] > 0 or rows_completed[q] < rows_total[q]
            for q in range(nq)
        ]
        for q, slot in member_slot.items():
            grp_active[slot[0]][slot[1]] = active_flag[q]
        # Closed-form drain: once every arrival has been routed, nothing
        # a state machine does can change the result (routing is the only
        # consumer of distribute masks / cost estimates), so the heap can
        # be exited and each worker finished by prefix sums.  Gated on
        # every policy CLASS declaring itself drain-safe (state changes
        # only inside `route`) — a policy that mutates observable state
        # on another trigger forces the heap to run to exhaustion.
        # ---- Fault layer gate (inert with no schedule) ---------------- #
        # With ``faults=None`` or an empty schedule, ``faults_on`` is
        # False: no FAIL/HBEAT event is ever pushed and every fault
        # branch below is dead, so the trajectory is bit-identical to a
        # pre-fault-layer run (the legacy rtol-1e-9 pin and the policy
        # digest pins stay green).
        faults_on = self.faults is not None and len(self.faults.events) > 0
        fcfg: Optional[FaultConfig] = None
        if faults_on:
            fcfg = (
                self.fault_cfg if self.fault_cfg is not None
                else default_sim_fault_config()
            )
        # Faults disable the closed-form drain: a crash after the last
        # arrival invalidates the prefix-sum finish.
        drain_on = self.closed_form_drain is not False and not faults_on \
            and all(cls.drain_safe for cls in pol_cls)
        drained = False
        # Event telemetry (self.last_event_counts).
        tick_n = gtick_n = arrival_n = admitted_n = enq_n = done_n = 0
        resize_n = 0
        arrival_runs = arrivals_in_runs = enq_coalesced = 0
        wf_calls = wf_rows = 0
        drained_events = drained_chunks = drained_ticks = 0

        planner: Optional[FairShareAdmission] = None
        dl_planner: Optional[DeadlineAwareAdmission] = None
        parked: List[Deque[Tuple[int, int]]] = [deque() for _ in range(nq)]
        if self.fair_share is not None and nq > 0:
            if self.deadline_aware:
                planner = dl_planner = DeadlineAwareAdmission(
                    [t.weight for t in tenants],
                    [t.slo_target for t in tenants],
                    self.fair_share,
                    self.deadline_cfg or DeadlineConfig(),
                )
            else:
                planner = FairShareAdmission(
                    [t.weight for t in tenants], self.fair_share
                )
        # ---- SLO layer state (inert with the default flags) ----------- #
        # Absolute per-tenant deadlines (inf = no SLO target).
        deadlines = [
            t.arrival + t.slo_target if t.slo_target is not None
            else float("inf")
            for t in tenants
        ]
        # Preemption re-parks ring rows (worker, costs) per victim; they
        # re-enter through fair share in `release_parked` and return to
        # the SAME worker (their transfer was already paid, so only the
        # rows lane is re-charged).
        preempt_on = self.preemption and dl_planner is not None and nq > 1
        preempt_parked: List[Deque[Tuple[int, np.ndarray]]] = [
            deque() for _ in range(nq)
        ]
        preempt_pending = 0           # re-parked rows not yet re-injected
        parked_rows_total = 0         # rows in fair-share-parked batches
        preempted_rows = [0] * nq     # per-tenant telemetry
        slo_done = slo_met = 0        # running attainment (autoscale input)
        # Autoscale: the active pool is a prefix-biased subset of the
        # physical workers; inactive workers drain their queues but
        # receive no new rows (waterfill sees them as +inf backlog).
        autoscale_on = self.autoscale is not None
        as_policy: Optional[AutoscalePolicy] = None
        worker_active = [True] * n
        active_count = n
        if autoscale_on:
            floor_w = max(self.autoscale.min_workers, 1)
            if faults_on:
                # Autoscale × failure guard: the commissioned pool may
                # never be targeted below the fault layer's min_hosts
                # (the _RESIZE handler additionally refuses to shrink
                # the LIVE pool below it, and to decommission a worker
                # that recovery traffic is in flight to).
                floor_w = max(floor_w, fcfg.min_hosts)
            as_cfg = dataclasses.replace(
                self.autoscale,
                min_workers=min(floor_w, n),
                max_workers=min(self.autoscale.max_workers, n),
            )
            as_policy = AutoscalePolicy(as_cfg)
            active_count = as_cfg.min_workers
            for w in range(active_count, n):
                worker_active[w] = False
        worker_active_np = np.asarray(worker_active)
        active_ids = np.flatnonzero(worker_active_np)
        # Idle census restricted to the ACTIVE pool (the density guard's
        # sibling signal under autoscale) — maintained incrementally at
        # the same flip points as the global census, never scanned.
        active_idle_count = active_count
        self.last_resizes = []

        # ---- Fault-layer state (all inert when ``faults_on`` False) --- #
        # Ground truth vs detection: ``worker_alive`` is physics (a dead
        # interpreter serves nothing and its in-flight chunk is void);
        # ``routable`` is what routing SEES — it flips at detection (the
        # heartbeat/idle-time path), at a spot notice, or at straggler
        # exclusion, never at the failure instant itself (no oracle).
        worker_alive = [True] * n
        routable = [True] * n
        detected = [False] * n      # dead AND noticed (recovery ran)
        excluded_str = [False] * n  # excluded as straggler (still alive)
        speed_factor = [1.0] * n
        nic_factor = [1.0] * c.num_nodes
        # Generation counter: bumped when a worker dies so the _DONE its
        # in-flight chunk already scheduled is recognized as a ghost.
        worker_gen = [0] * n
        # (service_start, costs, qids) of each worker's in-flight chunk —
        # the rows a crash voids (recovered via re-execution, charged).
        inflight: List[Optional[Tuple[float, np.ndarray,
                                      Optional[np.ndarray]]]] = [None] * n
        # Rows that died with a not-yet-detected worker, per worker:
        # (tenant, costs) stashes awaiting detection or early rejoin.
        dead_rows: List[List[Tuple[int, np.ndarray]]] = [
            [] for _ in range(n)
        ]
        # Recovery lane: per-tenant queues of cost arrays pulled off dead
        # /draining workers, re-admitted through fair share (charged).
        fault_parked: List[Deque[np.ndarray]] = [deque() for _ in range(nq)]
        fault_pending = 0
        recovered_rows = [0] * nq    # ring-resident rows re-routed
        reexecuted_rows = [0] * nq   # in-flight rows lost + re-executed
        migrated_rows = [0] * nq     # straggler-drain migrations
        wasted_service = 0.0         # partial service voided by deaths
        transfer_retries = 0
        retry_backoff_total = 0.0
        retry_attempts = [0] * n     # per failed DESTINATION (backoff)
        recovery_until = [0.0] * n   # recovery traffic in flight until t
        shrink_blocked = 0           # satellite-1 guard trips (telemetry)
        hb_busy = [0.0] * n          # service seconds since last HBEAT
        hb_rows = [0] * n            # rows completed since last HBEAT
        detections = straggler_excl = ghost_dones = 0
        fail_n = notice_n = recover_n = hbeat_n = 0
        mesh_log: List[Tuple[float, Tuple[int, int]]] = []
        rt: Optional[FaultTolerantRuntime] = None
        if faults_on:
            rt = FaultTolerantRuntime(n, fcfg)
        fs_retry_base = self.faults.retry_base if faults_on else 1e-3
        fs_retry_cap = self.faults.retry_cap if faults_on else 1e-3
        # Composed routing view: routable ∧ commissioned.  Only consulted
        # when faults_on (policy closures hand it out late-bound).
        routable_np = np.asarray(routable)
        eligible_np = worker_active_np
        eligible_ids = active_ids

        def refresh_eligible() -> None:
            nonlocal routable_np, eligible_np, eligible_ids
            routable_np = np.asarray(routable)
            eligible_np = routable_np & worker_active_np
            ids = np.flatnonzero(eligible_np)
            if not len(ids):
                # Degenerate case (every commissioned worker is dead or
                # draining): fall back to the commissioned pool — the
                # transfers bounce with backoff until someone recovers.
                eligible_np = worker_active_np
                ids = np.flatnonzero(eligible_np)
            eligible_ids = ids

        events: List[Tuple[float, int, int, int, int, object]] = []
        seq = 0

        def push(t: float, kind: int, qid: int, who: int, payload: object):
            nonlocal seq
            heappush(events, (t, seq, kind, qid, who, payload))
            seq += 1

        for g, (_, _, _, origin) in enumerate(groups):
            # Grid tick first (lowest seq) so eager links distribute from
            # row one for members arriving at the grid origin.
            push(origin, _GTICK, g, 0, None)
        for q, t in enumerate(tenants):
            # Tick first (lower seq) so eager links distribute from row one.
            if links[q] is not None:
                push(t.arrival, _TICK, q, 0, None)
            elif q in group_of:
                g = group_of[q]
                if t.arrival > groups[g][3]:
                    # Off-grid arrival: one-off masked join tick so this
                    # tenant's eager link engages at arrival instead of
                    # waiting for the next shared grid point.
                    push(t.arrival, _GTICK, g, 0, q)
            for p, stream in enumerate(t.streams):
                if stream:
                    push(t.arrival, _ARRIVAL, q, p, 0)
        if autoscale_on and tenants:
            # First decision at the earliest arrival; the chain then
            # recurs every `interval` while any tenant is active.
            push(min(t.arrival for t in tenants), _RESIZE, 0, 0, None)
        if faults_on and tenants:
            # The whole schedule is data, pushed up front: the loop never
            # draws a fault, so same schedule ⇒ same trajectory.
            for fe in self.faults.events:
                if fe.kind == PREEMPT:
                    push(fe.time, _PREEMPT_NOTICE, 0, fe.worker, fe)
                else:
                    push(fe.time, _FAIL, 0, fe.worker, fe)
            # Heartbeat chain (detection cadence); recurs while any
            # tenant is active or recovery rows are pending.
            push(
                min(t.arrival for t in tenants) + fcfg.heartbeat_interval,
                _HBEAT, 0, 0, None,
            )

        def start_worker(w: int, now: float):
            if worker_running[w]:
                return
            if faults_on and not worker_alive[w]:
                # A dead worker's ring freezes where it stands; recovery
                # (detection or early rejoin) decides what happens to it.
                return
            ring = rings[w]
            if ring.tail == ring.head:
                return
            chunk, qids = ring.pop(_SERVICE_CHUNK)
            # Sequential Python-float sum: bit-identical to the legacy
            # engine's per-tuple accumulation, so the engines stay on the
            # same event trajectory (tiny rounding differences amplify
            # chaotically through routing decisions).
            total = sum(chunk.tolist())
            if qids is None:
                counts = totals = None
            else:
                counts = np.bincount(qids, minlength=nq)
                # bincount accumulates weights in index order — the same
                # sequential float additions as the single-tenant sum.
                totals = np.bincount(qids, weights=chunk, minlength=nq)
            if faults_on:
                fac = speed_factor[w]
                if fac != 1.0:
                    # Transient slowdown: the chunk serves fac× slower;
                    # the stretch is billed as real busy time (it is
                    # spend) and is what the sync-slope detector sees.
                    total = total * fac
                    if totals is not None:
                        totals = totals * fac
                # pop() hands out views into the ring buffer; the stash
                # must survive later pushes (compaction), so copy.
                inflight[w] = (
                    now, chunk.copy(),
                    None if qids is None else qids.copy(),
                )
                payload = (total, len(chunk), counts, totals, worker_gen[w])
            else:
                payload = (total, len(chunk), counts, totals)
            worker_running[w] = True
            push(now + total, _DONE, 0, w, payload)

        def siblings_idle_frac(p: int) -> float:
            # Incremental census: same value the O(n) scan produced.
            if autoscale_on:
                # Decommissioned-but-draining workers must not count as
                # idle siblings (they are not eligible destinations).
                idle = active_idle_count - (
                    1 if worker_active[p] and worker_idle[p] else 0
                )
                return idle / max(
                    active_count - (1 if worker_active[p] else 0), 1
                )
            idle = idle_count - (1 if worker_idle[p] else 0)
            return idle / max(n - 1, 1)

        # One policy instance per tenant, observing the live engine state
        # through `PolicyContext` closures (est_row_cost / outstanding /
        # autoscale masks are run() locals that get REASSIGNED, so the
        # views must read them late).  The per-batch guard pipeline —
        # density guard, backlog masking, cost gate — lives on the policy
        # (`RedistributionPolicy`, one copy), consulted by both the
        # scalar `route_batch` path and the coalesced run's phase-1
        # planner, so guard ordering and gate inputs cannot drift.  Each
        # tenant gets an independent child RNG stream of the simulator
        # seed; the deterministic built-ins never consult it, preserving
        # the no-RNG-in-the-loop invariant.

        def _make_policy(q: int) -> RedistributionPolicy:
            ctx = PolicyContext(
                num_workers=n,
                rng=np.random.default_rng([self.seed, q]),
                node_of=c.node_of,
                network_bandwidth=net_bw,
                per_row_serialize=ser,
                est_row_cost=lambda: est_row_cost[q],
                outstanding=lambda: outstanding[q],
                idle_sibling_frac=siblings_idle_frac,
                # Under faults the composed view (commissioned ∧ routable)
                # replaces the plain autoscale mask, so every mask-aware
                # policy routes around dead/draining workers for free.
                active_mask=(
                    (lambda: eligible_np) if faults_on
                    else (lambda: worker_active_np) if autoscale_on
                    else (lambda: None)
                ),
                active_ids=(
                    (lambda: eligible_ids) if faults_on
                    else (lambda: active_ids) if autoscale_on
                    else (lambda: None)
                ),
                live_mask=(
                    (lambda: routable_np) if faults_on
                    else (lambda: None)
                ),
            )
            return strategies[q].make_policy(ctx)

        policies = [_make_policy(q) for q in range(nq)]

        def route_batch(
            q: int, p: int, b: Batch, now: float,
            dests_pre: object = _RB_INLINE,
            emit: Optional[Callable] = None,
        ) -> None:
            """Route one batch at virtual time ``now``.

            ``dests_pre`` is either the `_RB_INLINE` sentinel (compute the
            destinations here — the scalar path) or a precomputed plan
            from a coalesced same-time arrival run (None = keep local, an
            array = the batched-waterfill destinations, guards already
            applied).  ``emit`` redirects the _ENQUEUE pushes into the
            run's coalescing buffer instead of the heap.
            """
            out_q = outstanding[q]
            if dests_pre is not _RB_INLINE:
                dests = dests_pre
            else:
                # The policy seam: per-row destinations or None (keep
                # local).  The base `RedistributionPolicy.route` wraps
                # the proposal with the shared guard pipeline (density
                # guard → proposal over the masked backlog → cost gate).
                dests = policies[q].route(p, b, now)

            if dests is None and faults_on and not (
                routable[p] and worker_active[p]
            ):
                # Dead/draining/excluded (or decommissioned) producer:
                # its scan re-targets the least-backlogged ELIGIBLE
                # worker — one grouped transfer, priced like any
                # redistribution.  Subsumes the autoscale redirect below
                # when the fault layer is active.
                d = int(eligible_ids[
                    int(np.argmin(np.asarray(out_q)[eligible_ids]))
                ])
                dests = np.full(b.num_rows, d, np.int64)
            elif dests is None and autoscale_on and not worker_active[p]:
                # Decommissioned producer worker: its scan re-targets the
                # least-backlogged active worker (one grouped transfer, so
                # the IPC/NIC cost below is priced like any redistribution).
                d = int(active_ids[
                    int(np.argmin(np.asarray(out_q)[active_ids]))
                ])
                dests = np.full(b.num_rows, d, np.int64)

            if trace is not None and b.ids is not None:
                tr = trace[q]
                if tr is None:
                    tr = trace[q] = np.full(rows_total[q], -1, np.int64)
                tr[b.ids] = p if dests is None else dests

            if dests is None:
                # All-local fast path (no redistribution this batch):
                # in-process pipeline, serialization delay only.
                nrows = b.num_rows
                if emit is None:
                    push(now + nrows * ser, _ENQUEUE, q, p, b.costs)
                else:
                    emit(now + nrows * ser, q, p, b.costs)
                out_q[p] += nrows
                return
            sd, starts, ends, costs_s, sizes_s = _group_by_dest(
                dests, b.costs, b.sizes
            )
            # Per-group pairwise .sum() matches the legacy masked sums
            # bit-for-bit (same elements, same order, same algorithm).
            src_node = node[p]
            for j in range(len(starts)):
                lo, hi = starts[j], ends[j]
                d = int(sd[lo])
                nrows = hi - lo
                nbytes = float(sizes_s[lo:hi].sum())
                if node[d] != src_node:
                    rows_redist[q] += nrows
                    bytes_moved[q] += nbytes
                    if contention:
                        # Serialize on the source node's uplink.
                        nf = nic_free_at[src_node]
                        start = now if now > nf else nf
                        occupy = nbytes / net_bw
                        if faults_on and nic_factor[src_node] != 1.0:
                            # Degraded uplink: occupancy stretches.
                            occupy = occupy * nic_factor[src_node]
                        nic_free_at[src_node] = start + occupy
                        arrive = start + occupy + net_lat + nrows * ser
                    else:
                        bw_t = nbytes / net_bw
                        if faults_on and nic_factor[src_node] != 1.0:
                            bw_t = bw_t * nic_factor[src_node]
                        arrive = now + net_lat + bw_t + nrows * ser
                elif d == p:
                    arrive = now + nrows * ser
                else:
                    rows_redist[q] += nrows
                    arrive = now + ipc_lat + nbytes / ipc_bw + nrows * ser
                if emit is None:
                    push(arrive, _ENQUEUE, q, d, costs_s[lo:hi])
                else:
                    emit(arrive, q, d, costs_s[lo:hi])
                out_q[d] += nrows

        def try_admit(q: int, rows: int, nbytes: float, bpr: float,
                      now: float) -> bool:
            """The one planner-admission call: plain fair share, or the
            deadline-aware variant fed the tenant's absolute deadline."""
            if dl_planner is None:
                return planner.try_admit(q, rows, nbytes, bpr)
            return dl_planner.try_admit(
                q, rows, nbytes, bpr, deadline=deadlines[q], now=now
            )

        def preempt_for(uq: int, need: int, now: float) -> bool:
            """Displace up to ``need`` admitted-but-unstarted rows of
            over-share tenants (never ones at least as urgent as ``uq``)
            out of the worker rings, re-parking them for fair-share
            re-injection; the planner advances ``uq``'s credit by the
            freed amount.  Returns True if anything was preempted."""
            nonlocal preempt_pending, idle_count, active_idle_count
            freed = 0
            for victim, excess in dl_planner.preempt_candidates(
                protect=(uq,)
            ):
                if deadlines[victim] <= deadlines[uq]:
                    continue
                want = int(min(excess, need - freed))
                for w in range(n):
                    if want <= 0:
                        break
                    costs = rings[w].extract(victim, want)
                    kk = len(costs)
                    if not kk:
                        continue
                    want -= kk
                    freed += kk
                    left = outstanding[victim][w] - kk
                    outstanding[victim][w] = left if left > 0.0 else 0.0
                    preempt_parked[victim].append((w, costs))
                    preempt_pending += kk
                    preempted_rows[victim] += kk
                    dl_planner.preempt_transfer(victim, uq, kk)
                    if (
                        not worker_running[w] and not worker_idle[w]
                        and rings[w].tail == rings[w].head
                    ):
                        worker_idle[w] = True
                        idle_count += 1
                        if autoscale_on and worker_active[w]:
                            active_idle_count += 1
                if freed >= need:
                    break
            return freed > 0

        def fair_share_parks(kind: int, q: int, p: int, k: int,
                             b: Batch, now: float) -> bool:
            """Fair-share gate at an _ARRIVAL (re-offered _ADMITTED work
            was already charged): True → the batch was parked.  The ONE
            copy of the park-or-admit policy — both the singleton path
            and the coalesced-run path go through it."""
            nonlocal parked_rows_total
            if planner is None or kind != _ARRIVAL:
                return False
            bpr = b.total_bytes / max(b.num_rows, 1)
            if try_admit(q, b.num_rows, b.total_bytes, bpr, now):
                return False
            if (
                preempt_on
                # Urgency gate (same policy as the serving engine): only
                # a tenant whose slack has run inside the horizon may
                # displace others' work — and only when the admission
                # WOULD succeed given the credit a full preemption could
                # transfer (dry-run probe; displacing victims for a
                # doomed retry would delay them for nothing).
                and deadlines[q] - now < dl_planner.dcfg.urgency_horizon
                and dl_planner.would_admit(
                    q, b.num_rows, b.total_bytes, bpr,
                    deadline=deadlines[q], now=now,
                    rows_advance=float(b.num_rows),
                )
                and preempt_for(q, b.num_rows, now)
                and try_admit(q, b.num_rows, b.total_bytes, bpr, now)
            ):
                return False
            parked[q].append((p, k))
            parked_rows_total += b.num_rows
            return True

        def handle_arrival(
            kind: int, q: int, p: int, k: int, now: float,
            dests_pre: object = _RB_INLINE,
            emit: Optional[Callable] = None,
        ) -> bool:
            """The _ARRIVAL/_ADMITTED bookkeeping around `route_batch`.
            Returns False when the batch was parked by fair share."""
            nonlocal total_remaining
            st = strategies[q]
            b = streams[q][p][k]
            if fair_share_parks(kind, q, p, k, b, now):
                return False
            remaining_arrivals[q] -= 1
            total_remaining -= 1
            # The last arrival can retire a tenant whose rows are already
            # all complete (zero-row batches) — without this check its
            # tick chain would reschedule forever.
            tenant_done_check(q)
            rows_arr_in_tick[q][p] += b.num_rows
            batches_arr_in_tick[q][p] += 1
            bytes_arr_in_tick[q][p] += b.total_bytes
            if pays_overhead[q]:
                dec_overhead[q] += st.decision_overhead
                now += st.decision_overhead
            route_batch(q, p, b, now, dests_pre, emit)
            if k + 1 < len(streams[q][p]):
                # Flow control: pace against the least-backlogged valid
                # destination (own consumer when routing locally).
                if policies[q].paces_spread(p):
                    if faults_on:
                        # Dead/draining workers' frozen backlogs must not
                        # release the window (pace on eligible only).
                        bl = min(outstanding[q][w] for w in eligible_ids)
                    elif autoscale_on:
                        bl = min(outstanding[q][w] for w in active_ids)
                    else:
                        bl = min(outstanding[q])
                else:
                    bl = outstanding[q][p]
                backpressure = max(0.0, bl - flow_window) * est_row_cost[q]
                push(now + tenants[q].arrival_gap + backpressure,
                     _ARRIVAL, q, p, k + 1)
            return True

        def route_arrival_run(now: float, run_ev: List[Tuple]) -> None:
            """Route a maximal run of same-instant arrival events.

            The run is routed through ONE batched waterfill per cascade
            level: same-instant batches of DIFFERENT tenants are provably
            independent (backlog, cost estimate and masks are per-tenant,
            and nothing that routing mutates is read by another tenant's
            waterfill), while consecutive batches of the SAME tenant
            cascade through its own `outstanding` backlog and therefore
            form sequential levels.  Every side effect (fair-share
            admission, NIC occupancy, ring pushes, flow-control pacing)
            is applied strictly in heap pop order, so the trajectory is
            bit-identical to routing the events one at a time.

            Tie caveat (same class as the drain's documented tick tie):
            the buffered _ENQUEUE events are pushed after the run's
            flow-control _ARRIVAL pushes, so their heap seqs trail
            those arrivals'.  Seq order is only observable when two
            event timestamps are EXACTLY equal in float — here a
            ``now + gap + backpressure`` arrival colliding with a
            ``now + nrows*ser``-style delivery, quantities with no
            algebraic relation — which no generic workload produces.
            """
            nonlocal wf_calls, wf_rows, enq_coalesced
            # Phase 0 (pop order): fair-share admission; park or admit.
            admitted: List[Tuple[int, int, int, int, Batch]] = []
            for kind_e, q, p, k in run_ev:
                b = streams[q][p][k]
                if not fair_share_parks(kind_e, q, p, k, b, now):
                    admitted.append((kind_e, q, p, k, b))
            if not admitted:
                return
            # Phase 1: precompute every dyskew batch's routing plan.
            # plans[i] is _RB_INLINE (none/static_rr — computed inline in
            # pop order), None (stays local) or the waterfill dests.
            plans: List[object] = [_RB_INLINE] * len(admitted)
            chains: Dict[int, List[int]] = {}
            for i, (_, q, p, k, b) in enumerate(admitted):
                # Only policies whose proposal IS a waterfill over
                # `spread_backlog` (class flag) may be planned through
                # the batched call; everything else routes inline in pop
                # order, which is always correct.
                if batched_wf[q]:
                    chains.setdefault(q, []).append(i)
            shadow = {
                q: np.asarray(outstanding[q], np.float64) for q in chains
            }
            cursor = {q: 0 for q in chains}
            while chains:
                level: List[int] = []
                for q in list(chains):
                    lst = chains[q]
                    cur = cursor[q]
                    while cur < len(lst):
                        i = lst[cur]
                        _, _, p, k, b = admitted[i]
                        if policies[q].wants_spread(p, b):
                            break  # needs a waterfill at this level
                        plans[i] = None
                        shadow[q][p] += b.num_rows
                        cur += 1
                    if cur >= len(lst):
                        del chains[q]
                        continue
                    level.append(lst[cur])
                    cursor[q] = cur + 1
                if not level:
                    continue
                bls = np.empty((len(level), n))
                ks = np.empty(len(level), np.int64)
                units = np.empty(len(level))
                for r, i in enumerate(level):
                    _, q, p, k, b = admitted[i]
                    bls[r] = policies[q].spread_backlog(p, shadow[q])
                    ks[r] = b.num_rows
                    units[r] = policies[q].spread_unit()
                counts_lvl = waterfill_counts_many(bls, ks, units)
                wf_calls += 1
                wf_rows += len(level)
                for r, i in enumerate(level):
                    _, q, p, k, b = admitted[i]
                    counts = counts_lvl[r]
                    dests = np.repeat(np.arange(n), counts)
                    if not policies[q].admits(p, b, dests):
                        plans[i] = None
                        shadow[q][p] += b.num_rows
                        continue
                    plans[i] = dests
                    shadow[q] += counts
            # Phase 2 (pop order): apply everything — admission already
            # done in phase 0, so pass kind=_ADMITTED to skip it — with
            # same-(time, destination) _ENQUEUE pushes coalesced into one
            # heap event carrying the concatenated segments.
            pending_enq: Dict[Tuple[float, int], List] = {}

            def emit(t: float, q: int, d: int, seg: np.ndarray) -> None:
                lst = pending_enq.get((t, d))
                if lst is None:
                    pending_enq[(t, d)] = [(q, seg)]
                else:
                    lst.append((q, seg))

            for i, (_, q, p, k, b) in enumerate(admitted):
                handle_arrival(_ADMITTED, q, p, k, now, plans[i], emit)
            # dyslint: disable=DY402 -- insertion order IS heap pop order (pinned by the coalesced-run contract); the accumulator is an integer event counter
            for (t, d), segs in pending_enq.items():
                if len(segs) == 1:
                    q, seg = segs[0]
                    push(t, _ENQUEUE, q, d, seg)
                else:
                    push(t, _ENQUEUE, -1, d, segs)
                    enq_coalesced += len(segs) - 1

        def release_parked(now: float) -> None:
            """Re-offer parked arrivals after new credit (round-robin
            order; EDF-first under the deadline-aware planner).
            Preemption-parked rows are re-offered ahead of a tenant's
            parked batches — they already paid their transfer and return
            straight to their original worker's ring."""
            nonlocal preempt_pending, parked_rows_total
            progress = True
            while progress:
                progress = False
                for q in planner.release_order():
                    pq = preempt_parked[q]
                    while pq:
                        w, costs = pq[0]
                        if not try_admit(q, len(costs), 0.0, 0.0, now):
                            break
                        pq.popleft()
                        preempt_pending -= len(costs)
                        outstanding[q][w] += len(costs)
                        push(now, _ENQUEUE, q, w, costs)
                        progress = True
                    dq = parked[q]
                    if not dq:
                        continue
                    p, k = dq[0]
                    b = streams[q][p][k]
                    bpr = b.total_bytes / max(b.num_rows, 1)
                    if try_admit(q, b.num_rows, b.total_bytes, bpr, now):
                        dq.popleft()
                        parked_rows_total -= b.num_rows
                        push(now, _ADMITTED, q, p, k)
                        progress = True

        def tenant_done_check(q: int) -> None:
            """Flip the incrementally-maintained tenant_active flag (and
            its group mirror) when the tenant's last row completes."""
            nonlocal slo_done, slo_met
            if (
                active_flag[q]
                and remaining_arrivals[q] == 0
                and rows_completed[q] >= rows_total[q]
            ):
                active_flag[q] = False
                slot = member_slot.get(q)
                if slot is not None:
                    grp_active[slot[0]][slot[1]] = False
                if tenants[q].slo_target is not None:
                    # Running attainment — the autoscaler's SLO signal.
                    slo_done += 1
                    if last_done[q] <= deadlines[q]:
                        slo_met += 1

        # ---- Fault-layer recovery helpers (faults_on only) ------------ #

        def park_recovery(q: int, costs: np.ndarray,
                          bucket: List[int]) -> None:
            nonlocal fault_pending
            k = len(costs)
            if not k:
                return
            fault_parked[q].append(costs)
            fault_pending += k
            bucket[q] += k

        def drain_ring(w: int, bucket: List[int], refund: bool) -> None:
            """Pull every queued row off worker ``w``'s ring into the
            recovery lane.  The ring IS the row-level lineage here: its
            FIFO segments are exactly the rows the lineage lane last
            placed on ``w`` (per-row tenant ids in the qid lane), so
            recovery re-reads them instead of re-running the query.  The
            producer-visible backlog rolls back and the planner retires
            the rows from its in-service ledger (``on_lost``; refunded
            only when the SYSTEM displaced them — straggler migration)."""
            ring = rings[w]
            if ring.tail == ring.head:
                return
            costs = ring.buf[ring.head:ring.tail].copy()
            qarr = (
                ring.qbuf[ring.head:ring.tail].copy()
                if ring.qbuf is not None else None
            )
            ring.head = ring.tail
            if qarr is None:
                groups_r = ((0, costs),)
            else:
                groups_r = tuple(
                    (int(q2), costs[qarr == q2]) for q2 in np.unique(qarr)
                )
            for q2, cq in groups_r:
                kk = len(cq)
                left = outstanding[q2][w] - kk
                outstanding[q2][w] = left if left > 0.0 else 0.0
                if planner is not None:
                    planner.on_lost(q2, kk, refund=refund)
                park_recovery(q2, cq, bucket)

        def void_dead_rows(w: int) -> None:
            """Recover the stashes that died with worker ``w`` (its lost
            in-flight chunk): retire from the ledger WITHOUT refund — the
            spend happened — and park for charged re-execution."""
            for q2, cq in dead_rows[w]:
                kk = len(cq)
                left = outstanding[q2][w] - kk
                outstanding[q2][w] = left if left > 0.0 else 0.0
                if planner is not None:
                    planner.on_lost(q2, kk, refund=False)
                park_recovery(q2, cq, reexecuted_rows)
            dead_rows[w].clear()

        def inject_recovered(now: float) -> None:
            """Re-admit fault-parked rows through fair share — charged,
            not free (the retry debt) — and route each granted segment to
            the least-backlogged eligible worker, paying the lineage
            re-fetch as a normal transfer."""
            nonlocal fault_pending
            progress = True
            while progress and fault_pending:
                progress = False
                order = (
                    planner.release_order() if planner is not None
                    else range(nq)
                )
                for q in order:
                    fq = fault_parked[q]
                    while fq:
                        costs = fq[0]
                        kk = len(costs)
                        if planner is not None and not planner.try_readmit(
                            q, kk, deadline=deadlines[q], now=now
                        ):
                            break
                        fq.popleft()
                        fault_pending -= kk
                        d = int(eligible_ids[int(np.argmin(
                            np.asarray(outstanding[q])[eligible_ids]
                        ))])
                        outstanding[q][d] += kk
                        arrive = now + net_lat + kk * ser
                        if arrive > recovery_until[d]:
                            # Satellite-1 guard input: autoscale must not
                            # decommission ``d`` while this is in flight.
                            recovery_until[d] = arrive
                        push(arrive, _ENQUEUE, q, d, costs)
                        progress = True

        def census_idle_if_empty(w: int) -> None:
            """Restore the idle-census invariant for ``w`` after a
            recovery/migration emptied its ring (a dead worker is never
            counted idle; see the _FAIL handler)."""
            nonlocal idle_count, active_idle_count
            if (
                not worker_running[w] and not worker_idle[w]
                and rings[w].tail == rings[w].head
            ):
                worker_idle[w] = True
                idle_count += 1
                if autoscale_on and worker_active[w]:
                    active_idle_count += 1

        def detect_dead(w: int, now: float) -> None:
            """The detection moment for a dead worker: exclude it from
            routing, drain its frozen ring and its voided in-flight rows
            through the recovery lane, remesh the survivors."""
            nonlocal detections
            detected[w] = True
            routable[w] = False
            detections += 1
            rt.exclude([w])
            mesh_log.append((now, rt.mesh_shape()))
            refresh_eligible()
            drain_ring(w, recovered_rows, refund=False)
            void_dead_rows(w)
            inject_recovered(now)

        now = 0.0
        while events:
            now, _, kind, qid, who, payload = heappop(events)
            if kind == _ENQUEUE:
                enq_n += 1
                w = who
                # A coalesced event replays each segment's push and the
                # worker-start check it would have performed as its own
                # heap event — identical trajectory, one pop; a classic
                # event is the one-segment case of the same body.
                segs = payload if type(payload) is list else ((qid, payload),)
                if faults_on and not routable[w]:
                    # Transfer landed on a dead/draining/excluded
                    # destination: the sender retries against the
                    # least-backlogged eligible worker after a capped
                    # exponential backoff (attempts per failed dest).
                    att = retry_attempts[w]
                    retry_attempts[w] = att + 1
                    delay = min(
                        fs_retry_base * (2.0 ** min(att, 20)),
                        fs_retry_cap,
                    )
                    for q, seg in segs:
                        kk = len(seg)
                        if not kk:
                            continue
                        d = int(eligible_ids[int(np.argmin(
                            np.asarray(outstanding[q])[eligible_ids]
                        ))])
                        left = outstanding[q][w] - kk
                        outstanding[q][w] = left if left > 0.0 else 0.0
                        outstanding[q][d] += kk
                        transfer_retries += 1
                        retry_backoff_total += delay
                        push(now + delay, _ENQUEUE, q, d, seg)
                    continue
                for q, seg in segs:
                    # A zero-row segment leaves (ring, running) — and
                    # hence idleness — unchanged.
                    if len(seg) and worker_idle[w]:
                        worker_idle[w] = False
                        idle_count -= 1
                        if autoscale_on and worker_active[w]:
                            active_idle_count -= 1
                    rings[w].push(seg, qid=q)
                    recv_in_tick[q][w] += len(seg)
                    if not worker_running[w]:
                        start_worker(w, now)
            elif kind == _DONE:
                w = who
                if faults_on:
                    total, nrows, counts, totals, gen = payload
                    if gen != worker_gen[w]:
                        # Ghost completion: the chunk died with its
                        # worker before this _DONE fired.  Nothing is
                        # billed — the rows recover via the dead-row
                        # stash, never here.
                        ghost_dones += 1
                        continue
                    inflight[w] = None
                    hb_busy[w] += total
                    hb_rows[w] += nrows
                else:
                    total, nrows, counts, totals = payload
                done_n += 1
                if counts is None:
                    # N=1 specialization: no per-tenant split needed.
                    busy[0][w] += total
                    rows_done[0][w] += nrows
                    sync_in_tick[0][w] += total
                    avg = total / nrows if nrows else 0.0
                    ema = strategies[0].cost_ema
                    est_row_cost[0] = (1 - ema) * est_row_cost[0] + ema * avg
                    left = outstanding[0][w] - nrows
                    outstanding[0][w] = left if left > 0.0 else 0.0
                    rows_completed[0] += nrows
                    last_done[0] = now
                    tenant_done_check(0)
                    done_tenants = ((0, nrows),)
                else:
                    done_tenants = []
                    for q in np.flatnonzero(counts):
                        q = int(q)
                        cnt, tot = int(counts[q]), float(totals[q])
                        busy[q][w] += tot
                        rows_done[q][w] += cnt
                        sync_in_tick[q][w] += tot
                        avg = tot / cnt
                        ema = strategies[q].cost_ema
                        est_row_cost[q] = (
                            (1 - ema) * est_row_cost[q] + ema * avg
                        )
                        left = outstanding[q][w] - cnt
                        outstanding[q][w] = left if left > 0.0 else 0.0
                        rows_completed[q] += cnt
                        last_done[q] = now
                        tenant_done_check(q)
                        done_tenants.append((q, cnt))
                worker_running[w] = False
                start_worker(w, now)
                if not worker_running[w]:
                    worker_idle[w] = True
                    idle_count += 1
                    if autoscale_on and worker_active[w]:
                        active_idle_count += 1
                if planner is not None:
                    for q, cnt in done_tenants:
                        planner.on_complete(q, cnt)
                        if not active_flag[q]:
                            planner.deactivate(q)
                    if faults_on and fault_pending:
                        # Fresh credit: recovery rows re-enter ahead of
                        # parked batches (they were already in service).
                        inject_recovered(now)
                    release_parked(now)
                elif faults_on and fault_pending:
                    inject_recovered(now)
            elif kind == _ARRIVAL or kind == _ADMITTED:
                # Under autoscale (and under faults, same reason with the
                # dead-producer redirect), arrivals route strictly one at
                # a time: the coalesced run's phase-1 shadow cannot see
                # the decommissioned-producer redirect (it credits
                # kept-local rows to the inactive worker), so the batched
                # plan would diverge from pop-order routing.
                if not autoscale_on and not faults_on and events and \
                        events[0][0] == now and (
                    events[0][2] in (_ARRIVAL, _ADMITTED)
                ):
                    # A maximal run of same-instant arrivals: route them
                    # through the batched waterfill path.
                    run_ev = [(kind, qid, who, payload)]
                    if kind == _ARRIVAL:
                        arrival_n += 1
                    else:
                        admitted_n += 1
                    while events and events[0][0] == now and events[0][2] in (
                        _ARRIVAL, _ADMITTED
                    ):
                        _, _, k2, q2, w2, pl2 = heappop(events)
                        run_ev.append((k2, q2, w2, pl2))
                        if k2 == _ARRIVAL:
                            arrival_n += 1
                        else:
                            admitted_n += 1
                    arrival_runs += 1
                    arrivals_in_runs += len(run_ev)
                    route_arrival_run(now, run_ev)
                else:
                    if kind == _ARRIVAL:
                        arrival_n += 1
                    else:
                        admitted_n += 1
                    handle_arrival(kind, qid, who, payload, now)
                if (
                    drain_on and total_remaining == 0
                    and preempt_pending == 0 and events
                ):
                    drained = True
                    break
            elif kind == _RESIZE:
                resize_n += 1
                if any(active_flag):
                    # Backlog = everything queued for service: ring rows,
                    # preempt-parked rows, AND fair-share-parked batches
                    # — under admission-paced overload the parked queues
                    # are the dominant backlog, and an autoscaler blind
                    # to them would never grow.  (Parked rows are an
                    # incrementally-maintained counter, like the idle
                    # census — no per-decision scan.)
                    backlog = float(
                        sum(len(r) for r in rings)
                        + preempt_pending + parked_rows_total
                    )
                    att = (slo_met / slo_done) if slo_done else None
                    target = as_policy.decide(now, active_count, backlog, att)
                    if target != active_count:
                        # (De)commission whole workers: lowest-index
                        # inactive first on grow, highest-index active
                        # first on shrink.  A decommissioned worker keeps
                        # serving its ring (graceful drain) but receives
                        # no new rows.
                        if target > active_count:
                            for w in range(n):
                                if active_count >= target:
                                    break
                                if not worker_active[w]:
                                    worker_active[w] = True
                                    active_count += 1
                                    if worker_idle[w]:
                                        active_idle_count += 1
                        else:
                            if faults_on:
                                live_active = sum(
                                    1 for w2 in range(n)
                                    if worker_active[w2]
                                    and worker_alive[w2] and routable[w2]
                                )
                            for w in range(n - 1, -1, -1):
                                if active_count <= target:
                                    break
                                if worker_active[w]:
                                    if faults_on:
                                        live_w = (
                                            worker_alive[w] and routable[w]
                                        )
                                        if now < recovery_until[w] or (
                                            live_w and
                                            live_active <= fcfg.min_hosts
                                        ):
                                            # Scale-down × failure guard:
                                            # never decommission a worker
                                            # mid-recovery (rows in
                                            # flight to it) and never
                                            # shrink the LIVE pool below
                                            # min_hosts — crashes may
                                            # have already eaten into it.
                                            shrink_blocked += 1
                                            continue
                                        if live_w:
                                            live_active -= 1
                                    worker_active[w] = False
                                    active_count -= 1
                                    if worker_idle[w]:
                                        active_idle_count -= 1
                        worker_active_np = np.asarray(worker_active)
                        active_ids = np.flatnonzero(worker_active_np)
                        if faults_on:
                            refresh_eligible()
                    push(now + as_policy.cfg.interval, _RESIZE, 0, 0, None)
            elif kind == _FAIL:
                fail_n += 1
                fe = payload
                w = who
                if fe.kind == NIC_DEGRADE:
                    # ``worker`` names a NODE for NIC events.
                    nic_factor[w] = fe.factor
                    if fe.duration < float("inf"):
                        push(now + fe.duration, _RECOVER, 0, w, fe)
                elif fe.kind == SLOWDOWN:
                    if worker_alive[w]:
                        speed_factor[w] = fe.factor
                        if fe.duration < float("inf"):
                            push(now + fe.duration, _RECOVER, 0, w, fe)
                elif worker_alive[w]:
                    # Crash, or the announced end of a spot drain: the
                    # worker is gone.  Its in-flight chunk is void (the
                    # already-scheduled _DONE becomes a ghost via the
                    # generation bump, the partial service is wasted
                    # spend) and its queue freezes until detection.
                    worker_alive[w] = False
                    if worker_running[w]:
                        t_start, chunk, qarr = inflight[w]
                        wasted_service += now - t_start
                        worker_gen[w] += 1
                        worker_running[w] = False
                        inflight[w] = None
                        if qarr is None:
                            dead_rows[w].append((0, chunk))
                        else:
                            for q2 in np.unique(qarr):
                                q2 = int(q2)
                                dead_rows[w].append((q2, chunk[qarr == q2]))
                    if worker_idle[w]:
                        # Dead ⇒ not idle: it must not count as an idle
                        # sibling nor as spare capacity.
                        worker_idle[w] = False
                        idle_count -= 1
                        if autoscale_on and worker_active[w]:
                            active_idle_count -= 1
                    if fe.kind == PREEMPT:
                        # The drain was ANNOUNCED — no heartbeat wait:
                        # whatever the instance could not finish inside
                        # the notice window recovers right now.
                        detect_dead(w, now)
                    if fe.duration < float("inf"):
                        push(now + fe.duration, _RECOVER, 0, w, fe)
            elif kind == _PREEMPT_NOTICE:
                notice_n += 1
                fe = payload
                w = who
                if worker_alive[w] and routable[w]:
                    # Spot notice: no new rows from this instant; the
                    # instance keeps draining its queue until the pull.
                    routable[w] = False
                    refresh_eligible()
                push(now + fe.notice, _FAIL, 0, w, fe)
            elif kind == _RECOVER:
                recover_n += 1
                fe = payload
                w = who
                if fe.kind == NIC_DEGRADE:
                    nic_factor[w] = 1.0
                elif fe.kind == SLOWDOWN:
                    speed_factor[w] = 1.0
                    if excluded_str[w]:
                        # The slowdown that got this worker excluded as a
                        # straggler is over: rejoin mesh and routing.
                        excluded_str[w] = False
                        routable[w] = True
                        rt.rejoin(w, now)
                        mesh_log.append((now, rt.mesh_shape()))
                        refresh_eligible()
                elif not worker_alive[w]:
                    # Replacement instance (spot rebalance / restart)
                    # takes the dead worker's slot.
                    worker_alive[w] = True
                    if not detected[w]:
                        # Back BEFORE detection: the frozen queue simply
                        # resumes, but the chunk that died still
                        # re-executes (charged — the spend happened).
                        void_dead_rows(w)
                    else:
                        detected[w] = False
                        routable[w] = True
                        rt.rejoin(w, now)
                        mesh_log.append((now, rt.mesh_shape()))
                        refresh_eligible()
                    start_worker(w, now)
                    census_idle_if_empty(w)
                    if fault_pending:
                        inject_recovered(now)
            elif kind == _HBEAT:
                hbeat_n += 1
                # Virtual-time heartbeats: live workers report their mean
                # per-row service time over the window (idle workers echo
                # the fleet mean — no signal, no skew); dead workers stay
                # silent, so the runtime's idle-time model flags them
                # after ``missed_beats_dead`` quiet windows.  Straggler
                # flags come from the N-strikes sync-slope model — THE
                # detection path; the engine never short-circuits either
                # with ground truth.
                served = [
                    w2 for w2 in range(n)
                    if worker_alive[w2] and hb_rows[w2] > 0
                ]
                fleet = (
                    sum(hb_busy[w2] / hb_rows[w2] for w2 in served)
                    / len(served) if served else 0.0
                )
                for w2 in range(n):
                    if worker_alive[w2]:
                        step = (
                            hb_busy[w2] / hb_rows[w2] if hb_rows[w2] > 0
                            else fleet
                        )
                        rt.heartbeat(w2, now, step)
                    hb_busy[w2] = 0.0
                    hb_rows[w2] = 0
                det = rt.tick(now)
                for h in det["failed"]:
                    if not worker_alive[h] and not detected[h]:
                        detect_dead(h, now)
                for h in det["stragglers"]:
                    if (
                        worker_alive[h] and routable[h]
                        and int(eligible_np.sum()) - 1 >= fcfg.min_hosts
                    ):
                        # N-strikes straggler: exclude from routing,
                        # migrate its queued (unstarted) rows.  Its
                        # in-flight chunk finishes — nothing is lost —
                        # so the planner REFUNDS the migrated rows'
                        # charge (the system chose this displacement;
                        # contrast the crash path's retry debt).
                        excluded_str[h] = True
                        routable[h] = False
                        straggler_excl += 1
                        rt.exclude([h])
                        mesh_log.append((now, rt.mesh_shape()))
                        refresh_eligible()
                        drain_ring(h, migrated_rows, refund=True)
                        census_idle_if_empty(h)
                if fault_pending:
                    inject_recovered(now)
                if any(active_flag) or fault_pending:
                    push(
                        now + fcfg.heartbeat_interval, _HBEAT, 0, 0, None
                    )
            elif kind == _TICK:
                tick_n += 1
                q = qid
                num_ticks[q] += 1
                rows_arr = np.asarray(rows_arr_in_tick[q])
                batches_arr = np.asarray(batches_arr_in_tick[q])
                density = np.where(
                    batches_arr > 0,
                    rows_arr / np.maximum(batches_arr, 1),
                    0.0,
                )
                bpr = np.where(
                    rows_arr > 0,
                    np.asarray(bytes_arr_in_tick[q]) / np.maximum(rows_arr, 1),
                    0.0,
                )
                policies[q].set_link_mask(links[q].tick(
                    np.asarray(recv_in_tick[q]), np.asarray(sync_in_tick[q]),
                    density, bpr, np.asarray(worker_running, bool),
                ).tolist())
                recv_in_tick[q] = [0.0] * n
                sync_in_tick[q] = [0.0] * n
                rows_arr_in_tick[q] = [0.0] * n
                batches_arr_in_tick[q] = [0.0] * n
                bytes_arr_in_tick[q] = [0.0] * n
                if active_flag[q]:
                    push(now + strategies[q].tick_interval, _TICK, q, 0, None)
            else:  # _GTICK — ONE coalesced tick drives a whole group
                gtick_n += 1
                g = qid
                sim_g, members, interval, _ = groups[g]
                # A member participates while it has arrived, has not
                # already ticked at this instant (join tick colliding with
                # a grid point), and is active — plus exactly one
                # post-drain tick, mirroring the per-tenant cadence where
                # the already-scheduled tick still fires after drain.
                gact = grp_active[g]
                if payload is None:
                    elig = (
                        (grp_arrival[g] <= now)
                        & (grp_last_tick[g] != now)
                        & (gact | ~grp_final[g])
                    )
                else:
                    q = payload
                    i = member_slot[q][1]
                    elig = np.zeros(len(members), bool)
                    if grp_last_tick[g][i] != now and (
                        gact[i] or not grp_final[g][i]
                    ):
                        elig[i] = True
                if elig.any():
                    acc = group_acc[g]
                    rows_arr = acc["rows"]
                    batches_arr = acc["batches"]
                    # Same elementwise formulas as the per-tenant tick,
                    # lifted to (T, n) — bit-identical per row.
                    density = np.where(
                        batches_arr > 0,
                        rows_arr / np.maximum(batches_arr, 1),
                        0.0,
                    )
                    bpr = np.where(
                        rows_arr > 0,
                        acc["bytes"] / np.maximum(rows_arr, 1),
                        0.0,
                    )
                    dist = sim_g.tick(
                        acc["recv"], acc["sync"], density, bpr,
                        np.asarray(worker_running, bool),
                        elig,
                    )
                    idxs = np.flatnonzero(elig)
                    num_ticks[grp_members_arr[g][idxs]] += 1
                    grp_last_tick[g][idxs] = now
                    # One bulk tolist (C loop) instead of a python-level
                    # conversion per live member.
                    dist_rows = dist.tolist()
                    for i in idxs:
                        policies[members[int(i)]].set_link_mask(
                            dist_rows[int(i)]
                        )
                    # Fancy-index reset writes through to the same rows
                    # the per-tenant accumulator aliases view.
                    for key in ("recv", "sync", "rows", "batches", "bytes"):
                        acc[key][idxs] = 0.0
                    grp_final[g][idxs[~gact[idxs]]] = True
                if payload is None and gact.any():
                    push(now + interval, _GTICK, g, 0, None)

        if drained:
            # ---- Closed-form drain -------------------------------------
            # Every arrival has been routed (total_remaining == 0, which
            # also implies no parked fair-share work), so the events left
            # in the heap are only in-flight _ENQUEUEs, running workers'
            # _DONEs, and tick cadences.  From here on (a) routing never
            # happens again, so distribute masks, cost estimates and the
            # fair-share planner cannot influence the result, and (b)
            # workers are independent FIFO servers (an _ENQUEUE/_DONE at
            # worker w touches only w).  Each worker is finished exactly:
            # a short per-event replay while transfers are still landing,
            # then one prefix-sum walk over its fully-loaded ring — the
            # same float operations in the same order as the heap (see
            # `closed_form_none_result` for the op-order argument).  Tick
            # cadences reduce to counting: a pending tick chain fires at
            # chained times t, t+I, ... while its tenant is active plus
            # exactly one final fire, so num_ticks is recovered from the
            # completion times without advancing any state machine.
            drained_events = len(events)
            enq_by_w: Dict[int, List[Tuple]] = {}
            done_by_w: Dict[int, Tuple] = {}
            tick_chains: List[Tuple[float, int, int, int, object]] = []
            for t_e, s_e, kind_e, qid_e, who_e, payload_e in events:
                if kind_e == _ENQUEUE:
                    enq_by_w.setdefault(who_e, []).append(
                        (t_e, s_e, qid_e, payload_e)
                    )
                elif kind_e == _DONE:
                    tot_e, nr_e, cnts_e, tots_e = payload_e
                    done_by_w[who_e] = (t_e, s_e, tot_e, nr_e, cnts_e, tots_e)
                elif kind_e == _RESIZE:
                    # Post-drain resizes are inert: routing is over, so
                    # the pool size can no longer affect any result.
                    pass
                else:  # _TICK chains, _GTICK chains AND pending join ticks
                    tick_chains.append((t_e, s_e, kind_e, qid_e, payload_e))
            events.clear()
            # Fire order matters when a pending one-off join tick (a
            # zero-batch member arriving after the fleet's last routed
            # arrival) coexists with its group's recurring chain: the
            # heap delivers whichever comes first, and the member's
            # single post-inactive fire belongs to that event.
            tick_chains.sort(key=lambda e: (e[0], e[1]))
            inf = float("inf")

            def apply_done_stats(w, t_d, tot, nr, cnts, tots):
                if cnts is None:
                    busy[0][w] += tot
                    rows_done[0][w] += nr
                    rows_completed[0] += nr
                    if t_d > last_done[0]:
                        last_done[0] = t_d
                else:
                    for q in np.flatnonzero(cnts):
                        q = int(q)
                        busy[q][w] += float(tots[q])
                        rows_done[q][w] += int(cnts[q])
                        rows_completed[q] += int(cnts[q])
                        if t_d > last_done[q]:
                            last_done[q] = t_d

            def start_chunk(w, t_s):
                ring = rings[w]
                if ring.tail == ring.head:
                    return None
                chunk, qids = ring.pop(_SERVICE_CHUNK)
                tot = sum(chunk.tolist())
                if qids is None:
                    return (t_s + tot, inf, tot, len(chunk), None, None)
                cnts = np.bincount(qids, minlength=nq)
                tots = np.bincount(qids, weights=chunk, minlength=nq)
                return (t_s + tot, inf, tot, len(chunk), cnts, tots)

            for w in range(n):
                pend = done_by_w.get(w)
                enqs = sorted(enq_by_w.get(w, ()))
                # Phase A: replay the in-flight transfers exactly (chunk
                # pops interleave with arrivals in (time, seq) order).
                i = 0
                while i < len(enqs):
                    te, se = enqs[i][0], enqs[i][1]
                    if pend is not None and (pend[0], pend[1]) < (te, se):
                        t_d = pend[0]
                        apply_done_stats(
                            w, t_d, pend[2], pend[3], pend[4], pend[5]
                        )
                        drained_chunks += 1
                        pend = start_chunk(w, t_d)
                    else:
                        _, _, qe, pl = enqs[i]
                        i += 1
                        segs = pl if type(pl) is list else ((qe, pl),)
                        for q, seg in segs:
                            rings[w].push(seg, qid=q)
                            if pend is None:
                                pend = start_chunk(w, te)
                if pend is None:
                    continue
                # Phase B: the ring holds everything this worker will
                # ever serve — finish it with one prefix-sum walk.
                t0 = pend[0]
                apply_done_stats(w, t0, pend[2], pend[3], pend[4], pend[5])
                drained_chunks += 1
                ring = rings[w]
                m = ring.tail - ring.head
                if not m:
                    continue
                costs = ring.buf[ring.head:ring.tail]
                qids = (
                    ring.qbuf[ring.head:ring.tail]
                    if ring.qbuf is not None else None
                )
                nch = -(-m // _SERVICE_CHUNK)
                drained_chunks += nch
                padded = np.zeros(nch * _SERVICE_CHUNK)
                padded[:m] = costs
                # Within-chunk sequential accumulation (the loop's python
                # sum), then sequential across chunks (now += total).
                totals = np.cumsum(
                    padded.reshape(nch, _SERVICE_CHUNK), axis=1
                )[:, -1]
                times = np.cumsum(np.concatenate(([t0], totals)))
                if qids is None:
                    busy[0][w] = float(np.cumsum(
                        np.concatenate(([busy[0][w]], totals))
                    )[-1])
                    rows_done[0][w] += m
                    rows_completed[0] += m
                    tl = float(times[-1])
                    if tl > last_done[0]:
                        last_done[0] = tl
                else:
                    # Per-(chunk, tenant) splits: np.add.at accumulates in
                    # ring order — the same per-cell float addition order
                    # as the loop's per-chunk np.bincount.
                    ci = np.arange(m) // _SERVICE_CHUNK
                    tt = np.zeros((nch, nq))
                    cc = np.zeros((nch, nq), np.int64)
                    np.add.at(tt, (ci, qids), costs)
                    np.add.at(cc, (ci, qids), 1)
                    busy_row = np.asarray([busy[q][w] for q in range(nq)])
                    walk = np.cumsum(
                        np.vstack((busy_row[None, :], tt)), axis=0
                    )[-1]
                    colrows = cc.sum(axis=0)
                    for q in np.flatnonzero(colrows):
                        q = int(q)
                        busy[q][w] = float(walk[q])
                        rows_done[q][w] += int(colrows[q])
                        rows_completed[q] += int(colrows[q])
                        tl = float(times[int(np.flatnonzero(cc[:, q])[-1]) + 1])
                        if tl > last_done[q]:
                            last_done[q] = tl
                ring.head = ring.tail
            # Tick cadences: count the remaining fires in closed form.
            # A chain fires at t0, t0+I, (t0+I)+I, ... (chained float
            # adds, replayed here) while its tenant has uncompleted rows,
            # plus one final fire from the already-scheduled event.
            # Tie convention: a fire at EXACTLY the tenant's completion
            # time counts as the final fire (as if the completing _DONE
            # popped first).  The heap breaks such a tie by push seq and
            # can count one extra tick — but the tie needs a chained
            # tick time to equal a service-sum completion time in exact
            # float, which no generic workload produces; the divergence
            # is deterministic and confined to num_ticks (telemetry),
            # never latencies or busy vectors.
            for t0, _, kind_e, gid, payload_e in tick_chains:
                if kind_e == _TICK:
                    interval = strategies[gid].tick_interval
                    t_c = t0
                    cnt = 0
                    t_q = last_done[gid]
                    while t_c < t_q:
                        cnt += 1
                        t_c += interval
                    num_ticks[gid] += cnt + 1
                    drained_ticks += cnt + 1
                    continue
                _, members, interval, _ = groups[gid]
                gfin = grp_final[gid]
                glt = grp_last_tick[gid]
                if payload_e is not None:
                    # A pending one-off join tick: fires ONCE for its
                    # member at t0 and never reschedules.  Reachable
                    # only for a member with no batches at all (any
                    # batch-carrying member's join tick pops before its
                    # first arrival, hence before the drain).
                    q = payload_e
                    i = member_slot[q][1]
                    if not gfin[i] and glt[i] != t0:
                        num_ticks[q] += 1
                        drained_ticks += 1
                        if not grp_active[gid][i]:
                            gfin[i] = True
                    continue
                for i, q in enumerate(members):
                    if gfin[i]:
                        continue
                    if grp_arrival[gid][i] > t0:
                        # Not yet arrived at this chain instant: the heap
                        # gates eligibility on arrival, and the member's
                        # single post-arrival fire belongs to its pending
                        # one-off join tick (sorted into this loop) — an
                        # active member can never be here, since all its
                        # arrivals routed before the drain began.
                        continue
                    t_c = t0
                    if glt[i] == t0:
                        # The member already ticked at this instant
                        # (join tick colliding with the pending grid
                        # event — the heap's `last_tick != now`
                        # guard); its chain starts one step later.
                        t_c = t0 + interval
                    cnt = 0
                    t_q = last_done[q]
                    while t_c < t_q:
                        cnt += 1
                        t_c += interval
                    num_ticks[q] += cnt + 1
                    drained_ticks += cnt + 1
                    gfin[i] = True

        if as_policy is not None:
            self.last_resizes = list(as_policy.resizes)
        self.last_event_counts = {
            "tick": tick_n,
            "gtick": gtick_n,
            "arrival": arrival_n,
            "admitted": admitted_n,
            "enqueue": enq_n,
            "done": done_n,
            "resize": resize_n,
            "resizes_applied": len(self.last_resizes),
            "preempted_rows": int(sum(preempted_rows)),
            "heap_events": (
                tick_n + gtick_n + arrival_n + admitted_n + enq_n + done_n
                + resize_n + fail_n + notice_n + recover_n + hbeat_n
            ),
            "fail": fail_n,
            "preempt_notice": notice_n,
            "recover": recover_n,
            "hbeat": hbeat_n,
            "ghost_dones": ghost_dones,
            "recovered_rows": int(sum(recovered_rows)),
            "reexecuted_rows": int(sum(reexecuted_rows)),
            "migrated_rows": int(sum(migrated_rows)),
            "transfer_retries": transfer_retries,
            "arrival_runs_coalesced": arrival_runs,
            "arrivals_in_runs": arrivals_in_runs,
            "enqueues_coalesced": enq_coalesced,
            "waterfill_batched_calls": wf_calls,
            "waterfill_batched_rows": wf_rows,
            "drain_entered": int(drained),
            "drained_heap_events": drained_events,
            "drained_chunks": drained_chunks,
            "drained_ticks": drained_ticks,
        }
        self.last_fault_stats = {
            "enabled": faults_on,
            "injected": (
                self.faults.injected_counts() if faults_on else {}
            ),
            "detections": detections,
            "straggler_exclusions": straggler_excl,
            "recovered_rows": list(recovered_rows),
            "reexecuted_rows": list(reexecuted_rows),
            "migrated_rows": list(migrated_rows),
            "unrecovered_rows": int(fault_pending),
            "wasted_service_s": float(wasted_service),
            "transfer_retries": transfer_retries,
            "retry_backoff_s": float(retry_backoff_total),
            "ghost_dones": ghost_dones,
            "shrink_blocked_mid_recovery": shrink_blocked,
            "mesh_log": list(mesh_log),
            "runtime_events": list(rt.events) if rt is not None else [],
        }

        results: List[QueryResult] = []
        for q, t in enumerate(tenants):
            latency = max(last_done[q] - t.arrival, 1e-12)
            busy_q = np.asarray(busy[q])
            total_rows = int(sum(rows_done[q]))
            applied = rows_redist[q] > 0.01 * max(total_rows, 1)
            results.append(QueryResult(
                latency=float(latency),
                utilization=float(busy_q.sum() / (latency * n)),
                bytes_moved_remote=float(bytes_moved[q]),
                rows_redistributed=int(rows_redist[q]),
                redistribution_applied=bool(applied),
                per_worker_busy=busy_q,
                decision_overhead=float(dec_overhead[q]),
                num_ticks=int(num_ticks[q]),
                preempted_rows=int(preempted_rows[q]),
            ))
        return results


class Simulator:
    """Single-query API: the N=1 case of :class:`MultiQuerySimulator`.

    Kept as the stable entry point for the single-query benches/tests;
    since PR 2 it no longer owns an event loop of its own — the unified
    multi-tenant loop runs the query as a lone tenant arriving at t=0,
    which `tests/test_sim_equivalence.py` pins bit-tight against the seed
    engine (`repro.sim.legacy`).
    """

    def __init__(
        self,
        cluster: ClusterConfig,
        strategy: StrategyConfig,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.strategy = strategy
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    def _transfer_delay(self, src_worker: int, dst_worker: int, nbytes: float,
                        nrows: int) -> float:
        return _transfer_delay(self.cluster, src_worker, dst_worker,
                               nbytes, nrows)

    def run_query(
        self,
        batches_per_producer: List[List[Batch]],
        arrival_gap: float = 1e-4,
    ) -> QueryResult:
        """Execute one query.

        ``batches_per_producer[i]`` is the (possibly skewed) input stream of
        producer link instance i; batches arrive back-to-back separated by
        ``arrival_gap`` (the scan feeding the UDF operator).
        """
        tenant = TenantQuery(
            name="query",
            streams=batches_per_producer,
            strategy=self.strategy,
            arrival=0.0,
            arrival_gap=arrival_gap,
        )
        return MultiQuerySimulator(self.cluster, seed=self.seed).run(
            [tenant]
        )[0]
