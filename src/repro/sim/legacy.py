"""The original pure-Python discrete-event simulator, kept verbatim.

This is the seed implementation of :mod:`repro.sim.engine` before the
array-backed rewrite: per-row ``(cost, size)`` Python tuples shuttled
through per-worker lists, one ``heapq`` loop, hand-rolled admission
guards.  It is retained ONLY as the behavioural reference for the
equivalence tests (``tests/test_sim_equivalence.py``) that pin the
array-backed engine's ``QueryResult`` to this one on seeded workloads.
Do not grow features here — new work goes into ``repro.sim.engine``.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import heapq

import numpy as np

from repro.sim.engine import (
    AdaptiveLinkSim,
    Batch,
    ClusterConfig,
    QueryResult,
    StrategyConfig,
    waterfill_counts,
)

_TICK, _ARRIVAL, _ENQUEUE, _DONE = 0, 1, 2, 3


class LegacySimulator:
    def __init__(
        self,
        cluster: ClusterConfig,
        strategy: StrategyConfig,
        seed: int = 0,
    ):
        self.cluster = cluster
        self.strategy = strategy
        self.rng = np.random.default_rng(seed)

    # -- helpers -------------------------------------------------------- #

    def _transfer_delay(self, src_worker: int, dst_worker: int, nbytes: float,
                        nrows: int) -> float:
        """Contention-free transfer latency (NIC occupancy handled by the
        caller when model_contention is on)."""
        c = self.cluster
        ser = nrows * c.per_row_serialize
        if c.node_of(src_worker) == c.node_of(dst_worker):
            if src_worker == dst_worker:
                return ser  # stays in-process pipeline; serialization only
            return c.ipc_latency + nbytes / c.ipc_bandwidth + ser
        return c.network_latency + nbytes / c.network_bandwidth + ser

    # -- main entry ------------------------------------------------------ #

    def run_query(
        self,
        batches_per_producer: List[List[Batch]],
        arrival_gap: float = 1e-4,
    ) -> QueryResult:
        """Execute one query.

        ``batches_per_producer[i]`` is the (possibly skewed) input stream of
        producer link instance i; batches arrive back-to-back separated by
        ``arrival_gap`` (the scan feeding the UDF operator).
        """
        c = self.cluster
        st = self.strategy
        cfg = st.dyskew
        n = c.num_workers

        # Worker state.
        queue_rows: List[List[Tuple[float, float]]] = [[] for _ in range(n)]
        busy_time = np.zeros(n)
        rows_done = np.zeros(n)
        worker_running = [False] * n

        # Metric accumulators between state-machine ticks.
        recv_in_tick = np.zeros(n)        # rows received by each consumer
        sync_in_tick = np.zeros(n)        # sync time per consumer
        rows_arr_in_tick = np.zeros(n)    # rows arrived at each producer
        batches_arr_in_tick = np.zeros(n)
        bytes_arr_in_tick = np.zeros(n)

        # Opaque-cost estimator (global EMA of observed per-row time).
        est_row_cost = 1e-3
        # Observable backlog: rows sent to each consumer minus rows acked
        # complete (the producer sees its own sends and completion acks; it
        # never sees the hidden per-row costs).
        outstanding_rows = np.zeros(n)

        link: Optional[AdaptiveLinkSim] = None
        distribute_mask = np.zeros(n, bool)
        if st.kind == "dyskew":
            link = AdaptiveLinkSim(cfg, n)

        bytes_moved = 0.0
        rows_redist = 0
        decision_overhead_total = 0.0
        rr_counter = 0
        num_ticks = 0
        # Per-node egress NIC occupancy (heavy-row saturation, §III.B).
        nic_free_at = np.zeros(c.num_nodes)

        remaining_arrivals = sum(len(s) for s in batches_per_producer)
        in_flight = 0

        events: List[Tuple[float, int, int, int, object]] = []
        seq = 0

        def push(t: float, kind: int, who: int, payload: object):
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, who, payload))
            seq += 1

        # Seed the first tick BEFORE any arrival (same timestamp, lower
        # seq): eager links redistribute from the operator's first row.
        if link is not None:
            push(0.0, _TICK, 0, None)
        # Arrivals are chained per producer: batch k+1 is scheduled only
        # after batch k is routed, delayed by scan production time plus
        # credit-based backpressure against the destination backlog.
        streams = batches_per_producer
        for p, stream in enumerate(streams):
            if stream:
                push(0.0, _ARRIVAL, p, 0)

        def active() -> bool:
            return (
                remaining_arrivals > 0
                or in_flight > 0
                or any(worker_running)
                or any(queue_rows[w] for w in range(n))
            )

        service_chunk = 16  # rows per service burst (ack granularity)

        def start_worker(w: int, now: float):
            if worker_running[w] or not queue_rows[w]:
                return
            rows = queue_rows[w][:service_chunk]
            queue_rows[w] = queue_rows[w][service_chunk:]
            total = sum(cst for cst, _ in rows)
            worker_running[w] = True
            push(now + total, _DONE, w, rows)

        def siblings_idle_frac(p: int) -> float:
            idle = [
                (not worker_running[w]) and (not queue_rows[w])
                for w in range(n) if w != p
            ]
            return sum(idle) / max(len(idle), 1)

        def route_batch(p: int, b: Batch, now: float) -> None:
            nonlocal bytes_moved, rows_redist, rr_counter, in_flight
            if st.kind == "static_rr":
                dests = (rr_counter + np.arange(b.num_rows)) % n
                rr_counter += b.num_rows
            elif not distribute_mask[p]:
                dests = np.full(b.num_rows, p)
            else:
                dests = None
                # Row Size Model admission guard (§III.B): low batch density
                # + no skew benefit visible → keep the heavy rows local.
                bpr = b.total_bytes / max(b.num_rows, 1)
                if (
                    st.enable_density_guard
                    and b.num_rows < cfg.min_batch_density
                    and bpr >= cfg.heavy_row_bytes
                    and siblings_idle_frac(p) < cfg.idle_sibling_frac
                ):
                    dests = np.full(b.num_rows, p)
                if dests is None:
                    bl = outstanding_rows * est_row_cost
                    if cfg.self_skip:
                        # Forced-remote ablation (§III.B): the producer must
                        # bypass its own node's interpreters entirely
                        # (Fig. 1 — redistribution targets interpreters on
                        # *other* VW nodes), leaving local CPU idle.
                        bl = bl.copy()
                        own = c.node_of(p)
                        for w in range(n):
                            if c.node_of(w) == own:
                                bl[w] = np.inf
                    counts = waterfill_counts(
                        bl, b.num_rows, max(est_row_cost, 1e-9)
                    )
                    dests = np.repeat(np.arange(n), counts)
                    if st.enable_cost_gate:
                        # Cost gate (§I goal 3): refuse when estimated
                        # movement time exceeds estimated straggler savings.
                        moving = dests != p
                        mv_bytes = float(b.sizes[moving].sum())
                        t_move = (
                            mv_bytes / c.network_bandwidth
                            + int(moving.sum()) * c.per_row_serialize
                        )
                        saved = (
                            est_row_cost * float(moving.sum()) * (1.0 - 1.0 / n)
                        )
                        if saved <= cfg.cost_gate * t_move:
                            dests = np.full(b.num_rows, p)

            for d in np.unique(dests):
                d = int(d)
                m = dests == d
                nbytes = float(b.sizes[m].sum())
                nrows = int(m.sum())
                cross_node = c.node_of(d) != c.node_of(p)
                if d != p:
                    rows_redist += nrows
                    if cross_node:
                        bytes_moved += nbytes
                arrive = now + self._transfer_delay(p, d, nbytes, nrows)
                if cross_node and c.model_contention:
                    # Serialize on the source node's uplink.
                    src_node = c.node_of(p)
                    start = max(now, nic_free_at[src_node])
                    occupy = nbytes / c.network_bandwidth
                    nic_free_at[src_node] = start + occupy
                    arrive = start + occupy + c.network_latency \
                        + nrows * c.per_row_serialize
                payload = list(zip(b.costs[m].tolist(), b.sizes[m].tolist()))
                in_flight += 1
                push(arrive, _ENQUEUE, d, payload)
                outstanding_rows[d] += nrows

        now = 0.0
        last_work_done = 0.0
        while events:
            now, _, kind, who, payload = heapq.heappop(events)
            if kind == _TICK:
                num_ticks += 1
                rows_arr = rows_arr_in_tick
                density = np.where(
                    batches_arr_in_tick > 0,
                    rows_arr / np.maximum(batches_arr_in_tick, 1),
                    0.0,
                )
                bpr = np.where(
                    rows_arr > 0, bytes_arr_in_tick / np.maximum(rows_arr, 1), 0.0
                )
                signal = np.array(worker_running, dtype=bool)
                distribute_mask = link.tick(
                    recv_in_tick, sync_in_tick, density, bpr, signal
                )
                recv_in_tick[:] = 0.0
                sync_in_tick[:] = 0.0
                rows_arr_in_tick[:] = 0.0
                batches_arr_in_tick[:] = 0.0
                bytes_arr_in_tick[:] = 0.0
                if active():
                    push(now + st.tick_interval, _TICK, 0, None)
            elif kind == _ARRIVAL:
                p, k = who, payload
                b = streams[p][k]
                remaining_arrivals -= 1
                rows_arr_in_tick[p] += b.num_rows
                batches_arr_in_tick[p] += 1
                bytes_arr_in_tick[p] += b.total_bytes
                if link is not None:
                    decision_overhead_total += st.decision_overhead
                    now += st.decision_overhead
                route_batch(p, b, now)
                if k + 1 < len(streams[p]):
                    # Flow control: pace against the least-backlogged valid
                    # destination (own consumer when routing locally).
                    if st.kind == "static_rr" or distribute_mask[p]:
                        bl = float(outstanding_rows.min())
                    else:
                        bl = float(outstanding_rows[p])
                    backpressure = max(0.0, bl - c.flow_window_rows) * est_row_cost
                    push(now + arrival_gap + backpressure, _ARRIVAL, p, k + 1)
            elif kind == _ENQUEUE:
                w = who
                in_flight -= 1
                queue_rows[w].extend(payload)
                recv_in_tick[w] += len(payload)
                start_worker(w, now)
            else:  # _DONE
                w = who
                rows = payload
                total = sum(cst for cst, _ in rows)
                busy_time[w] += total
                rows_done[w] += len(rows)
                sync_in_tick[w] += total
                avg = total / max(len(rows), 1)
                est_row_cost = (1 - st.cost_ema) * est_row_cost + st.cost_ema * avg
                outstanding_rows[w] = max(outstanding_rows[w] - len(rows), 0.0)
                worker_running[w] = False
                last_work_done = now
                start_worker(w, now)

        makespan = max(last_work_done, 1e-12)
        util = float(busy_time.sum() / (makespan * n))
        total_rows = int(rows_done.sum())
        applied = rows_redist > 0.01 * max(total_rows, 1)
        return QueryResult(
            latency=makespan,
            utilization=util,
            bytes_moved_remote=bytes_moved,
            rows_redistributed=rows_redist,
            redistribution_applied=applied,
            per_worker_busy=busy_time,
            decision_overhead=decision_overhead_total,
            num_ticks=num_ticks,
        )
