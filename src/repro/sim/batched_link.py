"""Batched adaptive-link driver: ONE jitted call ticks every tenant.

The per-tenant `AdaptiveLinkSim` in `repro.sim.engine` pays one jit
dispatch per tenant per metrics tick, so the tick overhead of
`MultiQuerySimulator.run` grows linearly with the number of concurrent
queries and dominates the event loop at N≳64 tenants.  This module holds
the scaling fix: all tenants' link state is stacked into a single
``(T, n)`` array pytree (T tenants × n sibling link instances) and the
whole fleet advances through ONE jitted `state_machine.tick_many` call
per shared virtual-time tick.

Key properties:

  * Fixed-capacity padding.  ``BatchedLinkSim`` rounds its tenant
    capacity up to a power of two and masks the unused rows, so the jit
    cache (keyed on (config, capacity, n)) is hit across suites with
    different tenant counts instead of recompiling per count.
  * Inactive-row masking.  A (T,) ``active`` mask freezes the state of
    tenants that have not arrived yet (or have drained) bit-for-bit and
    forces their distribute mask to False — the event loop keeps ONE
    shared tick cadence and simply masks who participates.
  * Bit-exact rows.  ``jax.vmap`` of the per-tenant tick is bit-identical
    per row to the unbatched `AdaptiveLinkSim` call on the reductions
    involved (sibling sums over n, window sums over W).  Combined with
    on-grid arrivals (every member's arrival an exact value of the
    group's chained tick grid — identical arrivals are the trivial
    case), the whole multi-link group ticks at precisely its per-tenant
    instants, which is why the engine's auto default batches such
    groups without disturbing the `tests/test_sim_equivalence.py` pin;
    see `engine._arrivals_on_grid` for the envelope check.
    `tests/test_batched_link.py` asserts state-for-state equality against
    T independent `AdaptiveLinkSim` instances across mixed cadences.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.core import state_machine
from repro.core.types import DySkewConfig, link_state_init


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def _stacked_host_link_state(
    capacity: int, n: int, cfg: DySkewConfig
) -> Dict[str, np.ndarray]:
    """Host-numpy (T, ...) stack of `types.link_state_init` trees: one
    row per tenant slot, same leaves and dtypes by construction (derived
    from the canonical tree, so a new metric leaf cannot silently desync
    the batched layout), no device round-trip.  Valid because every leaf
    of the initial state is zero (LinkState.INIT == 0)."""
    template = link_state_init(n, cfg)
    return jax.tree_util.tree_map(
        lambda x: np.zeros((capacity,) + np.shape(x), x.dtype), template
    )


def _batched_tick_impl(link, rows, sync, density, bpr, signal, active, *, cfg):
    return state_machine.tick_many(
        link,
        cfg,
        rows_this_tick=rows,
        sync_time_this_tick=sync,
        batch_density=density,
        bytes_per_row=bpr,
        signal_this_tick=signal,
        active=active,
    )


class _JittedBatchedMachine:
    """Caches one jitted `state_machine.tick_many` per (config, T, n)."""

    _cache: Dict[Tuple, Callable] = {}

    @classmethod
    def get(cls, cfg: DySkewConfig, capacity: int, n: int) -> Callable:
        key = (cfg, capacity, n)
        fn = cls._cache.get(key)
        if fn is None:
            fn = jax.jit(partial(_batched_tick_impl, cfg=cfg))
            cls._cache[key] = fn
        return fn


class BatchedLinkSim:
    """Host-side wrapper advancing the link state machines of T tenants
    (each with n sibling producer link instances) in ONE jitted call.

    The drop-in batched counterpart of `engine.AdaptiveLinkSim`: tenant
    row ``i`` of a tick is bit-identical to what an independent
    `AdaptiveLinkSim` fed the same per-tick inputs would produce, and
    rows masked inactive do not advance at all.
    """

    def __init__(self, cfg: DySkewConfig, n: int, num_tenants: int):
        self.cfg = cfg
        self.n = n
        self.num_tenants = num_tenants
        # Pad to a power of two so differently-sized suites share compiles.
        self.capacity = _next_pow2(num_tenants)
        self.state = _stacked_host_link_state(self.capacity, n, cfg)
        self._tick = _JittedBatchedMachine.get(cfg, self.capacity, n)

    def _pad(self, x: np.ndarray, dtype) -> np.ndarray:
        t = len(x)
        if t == self.capacity:
            return np.asarray(x, dtype)
        out = np.zeros((self.capacity,) + np.shape(x)[1:], dtype)
        out[:t] = x
        return out

    def tick(
        self,
        rows: np.ndarray,      # (T, n) float
        sync: np.ndarray,      # (T, n) float
        density: np.ndarray,   # (T, n) float
        bpr: np.ndarray,       # (T, n) float
        signal: np.ndarray,    # (T, n) or (n,) bool
        active: np.ndarray,    # (T,) bool
    ) -> np.ndarray:
        """Advance the active tenants one tick; returns the (T, n) bool
        distribute mask (False rows for inactive tenants)."""
        t = self.num_tenants
        signal = np.asarray(signal, bool)
        if signal.ndim == 1:
            signal = np.broadcast_to(signal, (t, self.n))
        self.state, distribute = self._tick(
            self.state,
            self._pad(rows, np.float32),
            self._pad(sync, np.float32),
            self._pad(density, np.float32),
            self._pad(bpr, np.float32),
            self._pad(signal, bool),
            self._pad(active, bool),
        )
        return np.asarray(distribute)[:t]

    @property
    def states(self) -> np.ndarray:
        return np.asarray(jax.device_get(self.state["state"]))[:self.num_tenants]

    @property
    def transitions(self) -> np.ndarray:
        return np.asarray(
            jax.device_get(self.state["transitions"])
        )[:self.num_tenants]

    @property
    def ticks(self) -> np.ndarray:
        """Per-tenant count of (unmasked) ticks applied."""
        return np.asarray(jax.device_get(self.state["tick"]))[:self.num_tenants]
