"""Synthetic workload generators for the paper's evaluation scenarios.

Snowflake's customer workloads are proprietary; we generate synthetic
workloads with matched *skew characteristics* and verify the paper's
qualitative claims (see DESIGN.md §8).  Two independent skew axes:

  partition skew — rows concentrated on few producers (uneven scan
                   partitioning; the classic case), controlled by a Zipf
                   exponent / hot-partition fraction;
  cost skew      — heavy-tailed per-row UDF cost (lognormal sigma), the
                   'arbitrary user code' effect of §I.

Beyond per-query profiles, this module also generates multi-tenant
*traffic*: open-loop arrival processes (:class:`ArrivalProcess` /
:func:`arrival_times` — Poisson and on/off burst-modulated Poisson,
where query arrival times do NOT react to completions, the regime tail
latency must be measured in), cross-tenant interference scenarios
(:func:`skew_interference_suite`, :func:`priority_class_suite`) for the
fair-share admission studies in `sim/replay.py`, the mixed-SLO-class
overload mix (:func:`slo_suite`) for the deadline-aware admission /
preemption / autoscale studies, and the hundreds-of-tenants scaling mix
(:func:`many_tenants_suite`) that exercises the batched-tick engine
path.

Invariants:

  * Determinism.  Every generator is a pure function of its (profile,
    seed) arguments via a locally constructed ``np.random.default_rng``
    — no global RNG state — so replay comparisons (legacy vs DySkew, fair
    share on vs off) see IDENTICAL streams and arrival schedules, and
    the process-pool fan-out in `sim/replay.py` (``REPRO_BENCH_WORKERS``)
    returns the same results as a serial run.
  * Batches are immutable.  :func:`generate_query_cached` memoizes and
    shares `Batch` objects across strategy arms; nothing may mutate
    ``costs``/``sizes`` (the engine only reads views of them).
  * Batching matches §III.B.  The scan caps batches by rows AND bytes,
    so huge rows collapse observed batch density exactly as the Row Size
    Model expects — keep both caps when adding profiles.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import numpy as np

from repro.core.types import Policy
from repro.sim.engine import Batch


@dataclasses.dataclass(frozen=True)
class QueryProfile:
    name: str
    n_rows: int = 20_000
    mean_row_cost: float = 2e-3       # seconds of UDF compute per row
    cost_sigma: float = 0.5           # lognormal sigma (cost skew)
    partition_alpha: float = 0.0      # Zipf exponent over producers (0 = uniform)
    hot_fraction: float = 0.0         # extra mass pinned to producer 0
    row_bytes: float = 512.0
    row_bytes_sigma: float = 0.3
    batch_rows: int = 128             # scan batching target (row count)
    batch_bytes_target: float = 16e6  # scan batching target (bytes)
    udf: bool = True                  # Snowpark UDF operator present?
    # §II.B: the legacy static round-robin 'cannot be safely applied' where
    # data locality is required for correctness; the legacy system falls
    # back to the default 1:1 link for such queries. DySkew's per-link
    # state machines handle them (Distribute-Late + intermediate states).
    locality_constrained: bool = False
    # Redistribution policy declared by the consumer operator (§III.A).
    policy: Policy = Policy.EAGER_SNOWPARK


def _partition_rows(
    rng: np.random.Generator, n_rows: int, n_producers: int,
    alpha: float, hot_fraction: float,
) -> np.ndarray:
    """Row → producer assignment with the requested skew."""
    if alpha <= 0.0 and hot_fraction <= 0.0:
        return rng.integers(0, n_producers, n_rows)
    probs = np.ones(n_producers)
    if alpha > 0.0:
        probs = 1.0 / np.arange(1, n_producers + 1) ** alpha
    probs = probs / probs.sum()
    if hot_fraction > 0.0:
        probs = (1.0 - hot_fraction) * probs
        probs[0] += hot_fraction
    # Randomize which physical producer is 'hot' to avoid positional bias.
    perm = rng.permutation(n_producers)
    return perm[rng.choice(n_producers, size=n_rows, p=probs)]


def generate_query(
    profile: QueryProfile, n_producers: int, seed: int
) -> List[List[Batch]]:
    """Materialize one query's per-producer batch streams."""
    rng = np.random.default_rng(seed)
    owner = _partition_rows(
        rng, profile.n_rows, n_producers, profile.partition_alpha,
        profile.hot_fraction,
    )
    mu = np.log(profile.mean_row_cost) - 0.5 * profile.cost_sigma**2
    costs = rng.lognormal(mu, profile.cost_sigma, profile.n_rows)
    smu = np.log(profile.row_bytes) - 0.5 * profile.row_bytes_sigma**2
    sizes = rng.lognormal(smu, profile.row_bytes_sigma, profile.n_rows)

    # The scan batches rows per producer, capped by rows AND bytes — huge
    # rows collapse the observed batch density exactly as in §III.B.
    # Batch boundaries are found with one prefix-sum + searchsorted per
    # batch; only genuinely byte-bound batches (heavy rows) fall back to
    # the exact sequential accumulation.
    streams: List[List[Batch]] = []
    target = profile.batch_bytes_target
    batch_rows = profile.batch_rows
    for p in range(n_producers):
        idx = np.nonzero(owner == p)[0]
        cs, sz = costs[idx], sizes[idx]
        m = len(idx)
        csum = np.concatenate(([0.0], np.cumsum(sz)))
        stream: List[Batch] = []
        i = 0
        while i < m:
            limit = min(batch_rows, m - i)
            fit = int(np.searchsorted(csum, csum[i] + target, side="right")) - 1 - i
            if fit >= limit:
                take = limit
            else:
                # Byte cap binds before the row cap: accumulate row by row
                # (few rows — these are §III.B heavy-row batches).
                take, acc = 0, 0.0
                while (
                    take < limit
                    and (take == 0 or acc + sz[i + take] <= target)
                ):
                    acc += sz[i + take]
                    take += 1
            stream.append(Batch(costs=cs[i:i + take].copy(),
                                sizes=sz[i:i + take].copy()))
            i += take
        streams.append(stream)
    return streams


@functools.lru_cache(maxsize=32)
def generate_query_cached(
    profile: QueryProfile, n_producers: int, seed: int
) -> List[List[Batch]]:
    """Memoized :func:`generate_query` for A/B comparisons that replay the
    same streams under several strategies (the batches are treated as
    immutable everywhere).  ``QueryProfile`` is frozen, hence hashable."""
    return generate_query(profile, n_producers, seed)


# --------------------------------------------------------------------- #
# Paper-evaluation workload suites
# --------------------------------------------------------------------- #


def customer_replay_suite(num_queries: int = 150, seed: int = 7) -> List[QueryProfile]:
    """Fig. 3: ~150 replayed customer queries, mixed skew levels.

    Mix: ~1/3 well-balanced, ~1/3 partition-skewed, ~1/3 cost-skewed (with
    overlap), spanning 2 decades of per-row cost.  About 30 % of queries
    are locality-constrained (the legacy static round-robin could not be
    applied to them — §II.B); DySkew runs them with the Distribute-Late
    policy instead.
    """
    rng = np.random.default_rng(seed)
    out = []
    for q in range(num_queries):
        r = rng.random()
        alpha = 0.0
        hot = 0.0
        sigma = 0.4
        constrained = False
        n_rows = int(rng.integers(6_000, 24_000))
        if r < 0.55:
            # Balanced bulk — includes the biggest (P99-setting) queries.
            n_rows = int(rng.integers(12_000, 30_000))
            sigma = float(rng.uniform(0.3, 0.8))
        elif r < 0.80:
            alpha = float(rng.uniform(0.1, 0.3))  # partition skew (mild)
            hot = float(rng.uniform(0.005, 0.02))
            constrained = bool(rng.random() < 0.35)
        else:
            sigma = float(rng.uniform(1.0, 1.8))  # cost skew (heavy tail)
            if rng.random() < 0.4:
                alpha = float(rng.uniform(0.1, 0.4))
        out.append(
            QueryProfile(
                name=f"cust_{q:03d}",
                n_rows=n_rows,
                mean_row_cost=float(10 ** rng.uniform(-3.3, -2.4)),
                cost_sigma=sigma,
                partition_alpha=alpha,
                hot_fraction=hot,
                row_bytes=float(10 ** rng.uniform(2.0, 3.5)),
                locality_constrained=constrained,
            )
        )
    return out


#: Fig. 4 — TPCx-BB: 30 queries; Q10 and Q19 run sentiment-analysis-style
#: UDFs over review text with heavily skewed groupings; the other UDF
#: queries are comparatively balanced.
def tpcxbb_suite(seed: int = 11) -> List[QueryProfile]:
    rng = np.random.default_rng(seed)
    suite = []
    for q in range(1, 31):
        if q == 10:   # sentiment UDF over skewed review groups; the complex
            # plan is locality-constrained, so the legacy static round-robin
            # could not be applied (§II.B) — DySkew runs Distribute-Late.
            suite.append(QueryProfile(
                name="q10", n_rows=24_000, mean_row_cost=4e-3, cost_sigma=1.4,
                partition_alpha=0.0, hot_fraction=0.045, row_bytes=2_000,
                locality_constrained=True,
            ))
        elif q == 19:  # review-sentiment UDF, store-returns skew
            suite.append(QueryProfile(
                name="q19", n_rows=18_000, mean_row_cost=3e-3, cost_sigma=1.4,
                partition_alpha=0.0, hot_fraction=0.026, row_bytes=1_500,
                locality_constrained=True,
            ))
        else:
            suite.append(QueryProfile(
                name=f"q{q:02d}",
                n_rows=int(rng.integers(8_000, 16_000)),
                mean_row_cost=float(10 ** rng.uniform(-3.5, -2.8)),
                cost_sigma=0.4,
                partition_alpha=0.0,
                hot_fraction=float(rng.uniform(0.0, 0.02)),
                row_bytes=800.0,
            ))
    return suite


def production_mix(num_queries: int = 200, seed: int = 23) -> List[QueryProfile]:
    """Fig. 5: production Snowpark population.

    The redistribution policy is declared per consumer operator (§III.A):
    ~30 % of the population are Snowpark UDF operators running Eager, ~55 %
    run the generalized Distribute-Late default (fires only when skew is
    detected), and ~15 % declare Never (ordering / local-state deps).
    'Applied' — the paper's 37.6 % — counts queries that actually moved a
    meaningful fraction of rows."""
    rng = np.random.default_rng(seed)
    out = []
    for q in range(num_queries):
        r = rng.random()
        pol = rng.random()
        if pol < 0.25:
            policy, constrained = Policy.EAGER_SNOWPARK, False
        elif pol < 0.80:
            policy, constrained = Policy.LATE, bool(rng.random() < 0.4)
        else:
            policy, constrained = Policy.NEVER, False
        if r < 0.30:  # skewed — redistribution should engage
            out.append(QueryProfile(
                name=f"prod_skew_{q:03d}",
                n_rows=int(rng.integers(10_000, 24_000)),
                mean_row_cost=float(10 ** rng.uniform(-3.0, -2.2)),
                cost_sigma=float(rng.uniform(0.9, 1.8)),
                partition_alpha=float(rng.uniform(0.2, 0.6)),
                hot_fraction=float(rng.uniform(0.02, 0.07)),
                policy=policy, locality_constrained=constrained,
            ))
        elif r < 0.90:  # balanced bulk work — Late never fires
            out.append(QueryProfile(
                name=f"prod_bal_{q:03d}",
                n_rows=int(rng.integers(6_000, 12_000)),
                mean_row_cost=float(10 ** rng.uniform(-3.6, -3.0)),
                cost_sigma=0.3,
                policy=policy, locality_constrained=constrained,
            ))
        else:  # heavy-row blob processing — density guard territory
            out.append(QueryProfile(
                name=f"prod_blob_{q:03d}",
                n_rows=int(rng.integers(24, 64)),
                mean_row_cost=float(10 ** rng.uniform(-1.5, -0.7)),
                cost_sigma=0.4,
                row_bytes=float(10 ** rng.uniform(7.5, 8.5)),  # 30–300 MB rows
                batch_rows=4096,
                policy=policy, locality_constrained=constrained,
            ))
    return out


def multi_tenant_suite(num_tenants: int = 8, seed: int = 41) -> List[QueryProfile]:
    """Concurrent-tenant mix for the shared-cluster scenario: a couple of
    heavily skewed 'noisy neighbour' queries interleaved with balanced
    bread-and-butter queries, sized so neighbours genuinely overlap.

    About one tenant in four is skewed (partition + cost skew); the rest
    are balanced.  All are Snowpark UDF operators (Eager policy) unless
    locality-constrained, mirroring the production population of Fig. 5.
    """
    rng = np.random.default_rng(seed)
    out = []
    for q in range(num_tenants):
        if q % 4 == 0:  # noisy neighbour: hot producer + heavy-tailed cost
            out.append(QueryProfile(
                name=f"tenant_skew_{q:02d}",
                n_rows=int(rng.integers(6_000, 10_000)),
                mean_row_cost=float(10 ** rng.uniform(-3.0, -2.6)),
                cost_sigma=float(rng.uniform(1.2, 1.8)),
                partition_alpha=float(rng.uniform(0.8, 1.5)),
                hot_fraction=float(rng.uniform(0.15, 0.35)),
            ))
        else:
            out.append(QueryProfile(
                name=f"tenant_bal_{q:02d}",
                n_rows=int(rng.integers(3_000, 6_000)),
                mean_row_cost=float(10 ** rng.uniform(-3.4, -3.0)),
                cost_sigma=float(rng.uniform(0.3, 0.6)),
            ))
    return out


def skew_interference_suite(
    num_tenants: int = 4, seed: int = 53
) -> List[QueryProfile]:
    """Cross-tenant skew-interference study: ONE aggressor — a large query
    with a hot producer and heavy-tailed per-row cost, exactly the shape
    that monopolizes interpreter pools and the NIC — sharing the cluster
    with small, balanced, latency-sensitive victims.

    The interesting measurements are the victims' tail latency and the
    Jain's fairness index across tenants, with and without the
    fair-share admission layer (see `sim/replay.py`).
    """
    rng = np.random.default_rng(seed)
    out = [QueryProfile(
        name="aggressor_00",
        n_rows=12_000,
        mean_row_cost=3e-3,
        cost_sigma=1.6,
        partition_alpha=1.2,
        hot_fraction=0.30,
        row_bytes=4_000.0,
    )]
    for q in range(1, num_tenants):
        out.append(QueryProfile(
            name=f"victim_{q:02d}",
            n_rows=int(rng.integers(1_500, 3_000)),
            mean_row_cost=float(10 ** rng.uniform(-3.4, -3.0)),
            cost_sigma=float(rng.uniform(0.3, 0.5)),
        ))
    return out


def many_tenants_suite(
    num_tenants: int = 256, seed: int = 71
) -> List[Tuple[QueryProfile, float]]:
    """Hundreds-of-tenants open-loop mix: the scale regime (128–512
    concurrent queries on one warehouse) where per-tenant state-machine
    tick dispatch dominates the event loop and the batched
    `repro.sim.batched_link.BatchedLinkSim` path is required.

    Each tenant is deliberately small (a few hundred rows) so the
    interesting cost is *breadth* — hundreds of live link state machines
    ticking — not per-query depth.  One tenant in eight is a skewed
    noisy neighbour; weights are uniform (the fair-share layer is
    orthogonal to this scaling study).  Returns (profile, weight) pairs
    for `replay.open_loop_tenants`, which cycles arrivals over them.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[QueryProfile, float]] = []
    for q in range(num_tenants):
        if q % 8 == 0:  # sparse noisy neighbours keep links firing
            out.append((QueryProfile(
                name="many_skew",
                n_rows=int(rng.integers(480, 768)),
                mean_row_cost=float(10 ** rng.uniform(-2.7, -2.4)),
                cost_sigma=float(rng.uniform(1.0, 1.5)),
                partition_alpha=float(rng.uniform(0.6, 1.2)),
                hot_fraction=float(rng.uniform(0.10, 0.25)),
                batch_rows=64,
            ), 1.0))
        else:
            out.append((QueryProfile(
                name="many_bal",
                n_rows=int(rng.integers(256, 512)),
                mean_row_cost=float(10 ** rng.uniform(-3.0, -2.7)),
                cost_sigma=float(rng.uniform(0.3, 0.6)),
                batch_rows=64,
            ), 1.0))
    return out


def slo_suite(
    seed: int = 67,
) -> List[Tuple[QueryProfile, float, Optional[float]]]:
    """Mixed SLO classes for the deadline-aware admission study under
    open-loop overload:

      gold   — small, balanced, latency-critical interactive queries with
               a TIGHT deadline (and a high fair-share weight);
      silver — medium queries with a loose deadline;
      bulk   — larger skewed batch queries with NO deadline (weight 1),
               the background pressure the SLO classes contend with.

    Returns (profile, weight, slo_target_seconds) triples for
    `replay.open_loop_tenants`; targets are seconds from a query's
    arrival to its last-row completion.  The interesting comparison
    (`bench_multi_tenant.py --slo`) is weight-only fair share vs
    deadline-aware admission (± preemption, ± autoscale) on gold/silver
    attainment and p99 tardiness while the warehouse is offered more
    load than it can serve.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[QueryProfile, float, Optional[float]]] = []
    for i in range(3):
        out.append((QueryProfile(
            name="gold",
            n_rows=int(rng.integers(900, 1_500)),
            mean_row_cost=float(10 ** rng.uniform(-3.4, -3.1)),
            cost_sigma=float(rng.uniform(0.3, 0.5)),
        ), 4.0, 0.5))
    for i in range(2):
        out.append((QueryProfile(
            name="silver",
            n_rows=int(rng.integers(2_000, 3_200)),
            mean_row_cost=float(10 ** rng.uniform(-3.2, -2.9)),
            cost_sigma=float(rng.uniform(0.4, 0.7)),
        ), 2.0, 2.0))
    for i in range(3):
        out.append((QueryProfile(
            name="bulk",
            n_rows=int(rng.integers(4_000, 7_000)),
            mean_row_cost=float(10 ** rng.uniform(-3.0, -2.6)),
            cost_sigma=float(rng.uniform(1.0, 1.6)),
            partition_alpha=float(rng.uniform(0.6, 1.2)),
            hot_fraction=float(rng.uniform(0.10, 0.25)),
        ), 1.0, None))
    return out


def faults_suite(
    seed: int = 73,
) -> List[Tuple[QueryProfile, float, Optional[float]]]:
    """SLO classes for the fault-injection economics study
    (`bench_multi_tenant.py --faults`): the same gold/silver/bulk shape
    as :func:`slo_suite` but drawn from its own seed, sized so that a
    worker crash mid-run voids a visible slice of in-service rows.  The
    study crosses these tenants with `sim.faults.hazard_schedule`
    failure rates to trace cost-per-SLO — worker-seconds spent (wasted
    + re-executed service included) per deadline met — across
    policies × failure rates × autoscale on/off.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[QueryProfile, float, Optional[float]]] = []
    for i in range(3):
        out.append((QueryProfile(
            name="gold",
            n_rows=int(rng.integers(900, 1_500)),
            mean_row_cost=float(10 ** rng.uniform(-3.4, -3.1)),
            cost_sigma=float(rng.uniform(0.3, 0.5)),
        ), 4.0, 0.6))
    for i in range(2):
        out.append((QueryProfile(
            name="silver",
            n_rows=int(rng.integers(2_000, 3_200)),
            mean_row_cost=float(10 ** rng.uniform(-3.2, -2.9)),
            cost_sigma=float(rng.uniform(0.4, 0.7)),
        ), 2.0, 2.5))
    for i in range(2):
        out.append((QueryProfile(
            name="bulk",
            n_rows=int(rng.integers(3_500, 6_000)),
            mean_row_cost=float(10 ** rng.uniform(-3.0, -2.6)),
            cost_sigma=float(rng.uniform(0.9, 1.5)),
            partition_alpha=float(rng.uniform(0.6, 1.2)),
            hot_fraction=float(rng.uniform(0.10, 0.25)),
        ), 1.0, None))
    return out


def priority_class_suite(seed: int = 61) -> List[Tuple[QueryProfile, float]]:
    """Two priority classes for the open-loop fair-share scenario:

      gold — small, balanced, latency-sensitive interactive queries
             (high fair-share weight);
      bulk — larger, skewed batch queries (low weight), the background
             pressure gold must be isolated from.

    Returns (profile, weight) pairs; `replay.open_loop_tenants` cycles
    arrivals over them.
    """
    rng = np.random.default_rng(seed)
    out: List[Tuple[QueryProfile, float]] = []
    for i in range(3):
        out.append((QueryProfile(
            name="gold",
            n_rows=int(rng.integers(1_200, 2_000)),
            mean_row_cost=float(10 ** rng.uniform(-3.3, -3.0)),
            cost_sigma=float(rng.uniform(0.3, 0.5)),
        ), 8.0))
    for i in range(3):
        out.append((QueryProfile(
            name="bulk",
            n_rows=int(rng.integers(4_000, 7_000)),
            mean_row_cost=float(10 ** rng.uniform(-3.0, -2.6)),
            cost_sigma=float(rng.uniform(1.0, 1.6)),
            partition_alpha=float(rng.uniform(0.6, 1.2)),
            hot_fraction=float(rng.uniform(0.10, 0.25)),
        ), 1.0))
    return out


# --------------------------------------------------------------------- #
# Open-loop arrival processes
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """An open-loop query arrival process: timestamps are generated ahead
    of time and do NOT react to completions (no closed-loop think time),
    so queueing delay compounds into the latency tail under overload —
    the regime elastic engines are judged in.

      poisson — homogeneous Poisson stream at ``rate`` arrivals/s;
      burst   — on/off modulated Poisson (a 2-state MMPP): baseline
                ``rate`` in the off state, ``rate * burst_factor`` during
                bursts; burst durations are exponential with mean
                ``mean_burst_s`` and cover ``burst_fraction`` of time.
    """

    kind: str = "poisson"          # poisson | burst
    rate: float = 2.0              # arrivals/s (baseline state)
    burst_factor: float = 8.0
    burst_fraction: float = 0.25
    mean_burst_s: float = 2.0


def arrival_times(
    process: ArrivalProcess, num_arrivals: int, seed: int
) -> np.ndarray:
    """Materialize ``num_arrivals`` open-loop arrival timestamps."""
    rng = np.random.default_rng(seed)
    if process.kind == "poisson":
        return np.cumsum(rng.exponential(1.0 / process.rate, num_arrivals))
    if process.kind != "burst":
        raise ValueError(f"unknown arrival process kind: {process.kind!r}")
    f = min(max(process.burst_fraction, 1e-6), 1 - 1e-6)
    mean_off_s = process.mean_burst_s * (1.0 - f) / f
    times: List[float] = []
    t, on = 0.0, False
    while len(times) < num_arrivals:
        dur = rng.exponential(process.mean_burst_s if on else mean_off_s)
        r = process.rate * (process.burst_factor if on else 1.0)
        a = t + rng.exponential(1.0 / r)
        while a < t + dur and len(times) < num_arrivals:
            times.append(a)
            a += rng.exponential(1.0 / r)
        t += dur
        on = not on
    return np.asarray(times)


def heavy_rows_case(row_gb: float = 2.0, n_rows: int = 48) -> QueryProfile:
    """§III.B regression case: large objects (high-res images / JSON blobs);
    ~100 GB total moved unnecessarily by unguarded eager redistribution."""
    return QueryProfile(
        name="heavy_rows",
        n_rows=n_rows,
        mean_row_cost=80e-3,     # real but modest compute per blob
        cost_sigma=0.3,
        partition_alpha=0.0,     # NO skew — redistribution has no benefit
        row_bytes=row_gb * 1e9,
        row_bytes_sigma=0.05,
        batch_rows=4096,
    )


def self_skip_case() -> QueryProfile:
    """§III.B forced-remote study: mild skew on a small cluster, where
    skipping the local worker wastes local CPU and network."""
    return QueryProfile(
        name="self_skip",
        n_rows=12_000,
        mean_row_cost=2e-3,
        cost_sigma=0.8,
        partition_alpha=0.3,
        hot_fraction=0.04,
        row_bytes=64_000.0,   # sizeable rows: forced-remote NIC cost shows
    )


# ------------------------------------------------------------------ #
# Multi-stage pipeline suite (skew that propagates across stages)
# ------------------------------------------------------------------ #
#
# Stage model functions are module-level (not lambdas) so scenario
# definitions stay introspectable and the suite can be rebuilt
# identically anywhere.  Each is a pure function of (keys, rng).


def _explode_fanout(keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Nested-document explode: 0-4 child rows per parent."""
    return rng.integers(0, 5, len(keys))


def _rekey_wide(keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Re-key exploded children onto a wide key space (decorrelates from
    the parent key — the shuffle after this attenuates inherited skew)."""
    return keys * 37 + rng.integers(0, 64, len(keys))


def _collapse_groups(keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Groupby onto FEW groups: most keys spread over 61 buckets, but a
    hot slice of the key space collapses onto one bucket — the hash
    exchange after this concentrates that bucket on a single worker no
    matter how balanced the previous stage left its output."""
    out = keys % 61
    out[keys % 3 == 0] = 3          # ~1/3 of the key space piles up
    return out


def _agg_row_sizes(keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Aggregation-stage row widths: the hot collapsed group carries
    compact pre-aggregated partials, the long tail carries wide
    payloads.  This is the byte asymmetry where blanket round-robin
    spreading pays heavy NIC for rows that were never skewed, while
    adaptive redistribution moves only the (cheap) hot-group overflow."""
    return np.where(keys == 3, 1024.0, 524288.0)


def _hot_key_cost(keys: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Per-row UDF cost with a 4x-hot key slice (value skew on top of
    partition skew, §II's compound case)."""
    cost = rng.lognormal(np.log(3e-4), 0.4, len(keys))
    cost[keys % 5 == 0] *= 4.0
    return cost


def pipeline_suite(quick: bool = False):
    """Chained-stage pipeline scenarios for the skew-propagation study:
    ``(name, stages, inputs)`` triples consumed by
    `repro.sim.pipeline.PipelineSimulator` (strategies per stage are
    defaults — the bench overrides them per A/B arm).

      fanout_explode    — parse→explode with rekeying: inherited skew
                          ATTENUATES through the wide rehash.
      groupby_attenuate — skewed scan whose 'worker' exchange hands the
                          next stage whatever balance (or skew) the
                          stage-0 redistribution policy achieved.
      collision_chain   — balanced map feeding a collapsing groupby:
                          the hash exchange AMPLIFIES skew mid-pipeline,
                          then a 'worker' exchange propagates whatever
                          the reduce stage did about it.
      etl_chain         — 4-stage mix of all three mechanisms.

    ``quick`` shrinks row counts ~4x for CI smoke runs."""
    from repro.sim.pipeline import PipelineInput, StageSpec

    r = 4 if quick else 1

    def rows(n: int) -> int:
        return max(n // r, 256)

    fanout_explode = (
        "fanout_explode",
        [
            StageSpec(name="parse", shuffle="hash", mean_row_cost=3e-4,
                      fanout_fn=_explode_fanout, key_fn=_rekey_wide,
                      row_bytes=2048.0),
            StageSpec(name="transform", mean_row_cost=2e-4),
        ],
        [
            PipelineInput(name="docs", n_rows=rows(3000), num_keys=256,
                          zipf_alpha=1.3),
        ],
    )
    groupby_attenuate = (
        "groupby_attenuate",
        [
            StageSpec(name="scan_udf", shuffle="worker", mean_row_cost=4e-4,
                      cost_fn=_hot_key_cost, row_bytes=8192.0),
            StageSpec(name="reduce", mean_row_cost=3e-4),
        ],
        [
            PipelineInput(name="events", n_rows=rows(4000), num_keys=128,
                          zipf_alpha=1.5),
            PipelineInput(name="dims", n_rows=rows(1200), num_keys=512,
                          zipf_alpha=0.0, partition="rr"),
        ],
    )
    collision_chain = (
        "collision_chain",
        [
            StageSpec(name="map", shuffle="hash", mean_row_cost=2.5e-4,
                      key_fn=_collapse_groups, row_bytes=2048.0),
            StageSpec(name="groupby", shuffle="worker", mean_row_cost=6e-4,
                      cost_sigma=0.3, size_fn=_agg_row_sizes),
            StageSpec(name="score", mean_row_cost=2e-4),
        ],
        [
            PipelineInput(name="facts", n_rows=rows(5000), num_keys=4096,
                          zipf_alpha=0.0, partition="rr"),
        ],
    )
    etl_chain = (
        "etl_chain",
        [
            StageSpec(name="ingest", shuffle="hash", mean_row_cost=2e-4,
                      fanout_fn=_explode_fanout, key_fn=_rekey_wide,
                      row_bytes=4096.0),
            StageSpec(name="enrich", shuffle="hash", mean_row_cost=3e-4,
                      key_fn=_collapse_groups, row_bytes=8192.0),
            StageSpec(name="aggregate", shuffle="worker", mean_row_cost=5e-4,
                      cost_fn=_hot_key_cost, row_bytes=2048.0),
            StageSpec(name="export", mean_row_cost=1.5e-4),
        ],
        [
            PipelineInput(name="stream_a", n_rows=rows(2500), num_keys=512,
                          zipf_alpha=1.2),
            PipelineInput(name="stream_b", n_rows=rows(1500), num_keys=1024,
                          zipf_alpha=0.0, partition="rr"),
        ],
    )
    return [fanout_explode, groupby_attenuate, collision_chain, etl_chain]
