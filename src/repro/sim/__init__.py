"""Discrete-event simulator: the paper-faithful reproduction layer.

engine   — ONE array-backed event loop (workers / adaptive links /
           network) serving both the single-query API and N concurrent
           tenants, with optional weighted fair-share admission
legacy   — the seed list-of-tuples engine, kept as the equivalence
           reference for the unified loop
workload — synthetic suites matching the paper's evaluation scenarios,
           plus open-loop arrival processes and interference traffic
replay   — strategy comparison + aggregate statistics (single-tenant,
           closed- and open-loop multi-tenant: per-class tails, Jain's
           fairness), with optional process-pool fan-out
"""

from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    QueryResult,
    Simulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.workload import QueryProfile, generate_query

__all__ = [
    "Batch",
    "ClusterConfig",
    "MultiQuerySimulator",
    "QueryProfile",
    "QueryResult",
    "Simulator",
    "StrategyConfig",
    "TenantQuery",
    "generate_query",
]
