"""Discrete-event simulator: the paper-faithful reproduction layer.

engine   — workers / adaptive links / network event loop
workload — synthetic suites matching the paper's evaluation scenarios
replay   — strategy comparison + aggregate statistics
"""

from repro.sim.engine import (
    Batch,
    ClusterConfig,
    QueryResult,
    Simulator,
    StrategyConfig,
)
from repro.sim.workload import QueryProfile, generate_query

__all__ = [
    "Batch",
    "ClusterConfig",
    "QueryProfile",
    "QueryResult",
    "Simulator",
    "StrategyConfig",
    "generate_query",
]
