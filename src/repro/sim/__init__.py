"""Discrete-event simulator: the paper-faithful reproduction layer.

engine   — array-backed workers / adaptive links / network event loop,
           plus the multi-tenant concurrent-query engine
legacy   — the seed list-of-tuples engine, kept as the equivalence
           reference for the array-backed core
workload — synthetic suites matching the paper's evaluation scenarios
replay   — strategy comparison + aggregate statistics (single- and
           multi-tenant), with optional process-pool fan-out
"""

from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    QueryResult,
    Simulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.workload import QueryProfile, generate_query

__all__ = [
    "Batch",
    "ClusterConfig",
    "MultiQuerySimulator",
    "QueryProfile",
    "QueryResult",
    "Simulator",
    "StrategyConfig",
    "TenantQuery",
    "generate_query",
]
