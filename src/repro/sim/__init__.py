"""Discrete-event simulator: the paper-faithful reproduction layer.

engine       — ONE array-backed event loop (workers / adaptive links /
               network) serving both the single-query API and N
               concurrent tenants, with optional weighted fair-share
               admission, batched state-machine ticks, and a closed-form
               'none'-strategy fast path
batched_link — (T, n) stacked link state: one jitted tick call advances
               every tenant's state machines (the hundreds-of-tenants
               scaling path)
legacy       — the seed list-of-tuples engine, kept as the equivalence
               reference for the unified loop
workload     — synthetic suites matching the paper's evaluation
               scenarios, plus open-loop arrival processes, interference
               traffic and the many-tenants scaling mix
pipeline     — multi-stage UDF pipelines: chained engine stages with
               inter-stage shuffles and per-row lineage, so skew
               amplification/attenuation is measurable stage by stage
replay       — strategy comparison + aggregate statistics (single-tenant,
               closed- and open-loop multi-tenant: per-class tails,
               Jain's fairness; pipeline skew-propagation summaries),
               with optional process-pool fan-out
"""

from repro.sim.batched_link import BatchedLinkSim
from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    QueryResult,
    Simulator,
    StrategyConfig,
    TenantQuery,
    closed_form_none_result,
)
from repro.sim.pipeline import (
    PipelineInput,
    PipelineResult,
    PipelineSimulator,
    StageSpec,
    override_strategy,
)
from repro.sim.workload import QueryProfile, generate_query

__all__ = [
    "Batch",
    "BatchedLinkSim",
    "ClusterConfig",
    "MultiQuerySimulator",
    "PipelineInput",
    "PipelineResult",
    "PipelineSimulator",
    "QueryProfile",
    "QueryResult",
    "Simulator",
    "StageSpec",
    "StrategyConfig",
    "TenantQuery",
    "closed_form_none_result",
    "generate_query",
    "override_strategy",
]
