"""Deterministic fault injection for the simulator event loop.

A `FaultSchedule` is DATA, not a process: every fault the engine will
inject — worker crashes, spot preemptions with advance notice, transient
slowdowns, NIC degradations — is fixed before `MultiQuerySimulator.run`
pops its first event, either declared explicitly per scenario or drawn
once from the seeded hazard process in :func:`hazard_schedule`.  The
engine consumes the schedule as first-class heap events (FAIL /
PREEMPT_NOTICE / RECOVER) and never consults randomness while the loop
runs, so the repo's determinism contract holds with faults on exactly as
it does with faults off: same schedule + same tenants ⇒ bit-identical
trajectory, including every detection, retry and recovery.

Event semantics (see `sim/engine.py` for the full recovery path):

  crash       — the worker dies at ``time`` with NO warning.  Its
                in-flight service chunk is lost (the partial service is
                wasted spend), its queued rows freeze, and nothing is
                recovered until the heartbeat/idle-time detector notices
                the silence.  ``duration`` < inf means a replacement
                instance takes the slot at ``time + duration``.
  preempt     — spot preemption WITH notice: at ``time`` the scheduler
                learns the instance is going away (routing stops
                immediately, the instance keeps draining its queue), and
                at ``time + notice`` the plug is pulled — whatever it
                could not finish recovers at that instant, no heartbeat
                wait.  ``duration`` counts from the pull, like crash.
  slowdown    — the worker serves ``factor``× slower for ``duration``
                seconds (applied per service chunk at chunk start).  The
                stretch is visible to siblings through completions, which
                is what the N-strikes sync-slope straggler detector keys
                on.
  nic_degrade — ``worker`` names a NODE: its uplink occupancy stretches
                by ``factor`` for ``duration`` seconds.

``retry_base`` / ``retry_cap`` parameterize the sender-side retry loop:
a transfer that lands on a dead/draining/excluded destination bounces to
the least-backlogged eligible worker after ``min(base * 2**attempt,
cap)`` seconds of backoff (attempts counted per failed destination).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

#: Event kind names (the engine switches on these).
CRASH = "crash"
PREEMPT = "preempt"
SLOWDOWN = "slowdown"
NIC_DEGRADE = "nic_degrade"
FAULT_KINDS = (CRASH, PREEMPT, SLOWDOWN, NIC_DEGRADE)

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``worker`` is a worker index for
    crash/preempt/slowdown and a NODE index for nic_degrade."""

    time: float
    kind: str
    worker: int
    #: crash/preempt: seconds until a replacement rejoins (inf = never);
    #: slowdown/nic_degrade: length of the degraded window.
    duration: float = _INF
    #: preempt only: advance warning between the notice and the pull.
    notice: float = 0.0
    #: slowdown: service-time multiplier; nic_degrade: occupancy
    #: multiplier.  Ignored for crash/preempt.
    factor: float = 4.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}"
            )
        if not (self.time >= 0.0 and np.isfinite(self.time)):
            raise ValueError(f"fault time must be finite >= 0: {self.time}")
        if self.worker < 0:
            raise ValueError(f"fault worker/node must be >= 0: {self.worker}")
        if not self.duration > 0.0:
            raise ValueError(f"fault duration must be > 0: {self.duration}")
        if self.notice < 0.0:
            raise ValueError(f"preempt notice must be >= 0: {self.notice}")
        if self.kind in (SLOWDOWN, NIC_DEGRADE) and not self.factor >= 1.0:
            raise ValueError(
                f"{self.kind} factor must be >= 1 (a speedup is not a "
                f"fault): {self.factor}"
            )


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A replayable set of fault events plus the sender retry knobs.

    An EMPTY schedule is the contract-critical case: the engine treats
    ``FaultSchedule()`` exactly like ``faults=None`` — not a single new
    branch executes, so the legacy rtol-1e-9 equivalence pin and the
    policy digest pins are untouched.
    """

    events: Tuple[FaultEvent, ...] = ()
    #: Capped exponential backoff for transfers bounced off a
    #: dead/draining destination: ``min(retry_base * 2**attempt,
    #: retry_cap)`` seconds.
    retry_base: float = 1e-3
    retry_cap: float = 64e-3

    def __post_init__(self):
        object.__setattr__(
            self, "events",
            tuple(sorted(self.events, key=lambda e: e.time)),
        )
        if not self.retry_base > 0.0:
            raise ValueError(f"retry_base must be > 0: {self.retry_base}")
        if self.retry_cap < self.retry_base:
            raise ValueError(
                f"retry_cap {self.retry_cap} < retry_base {self.retry_base}"
            )

    def __bool__(self) -> bool:
        return bool(self.events)

    def validate(self, num_workers: int, num_nodes: int) -> None:
        """Raise if any event targets a worker/node outside the cluster."""
        for e in self.events:
            limit = num_nodes if e.kind == NIC_DEGRADE else num_workers
            what = "node" if e.kind == NIC_DEGRADE else "worker"
            if e.worker >= limit:
                raise ValueError(
                    f"fault event at t={e.time} targets {what} {e.worker} "
                    f"but the cluster has {limit}"
                )

    def injected_counts(self) -> Dict[str, int]:
        """Events by kind (telemetry for ``last_fault_stats``)."""
        out = {k: 0 for k in FAULT_KINDS}
        for e in self.events:
            out[e.kind] += 1
        return out


def hazard_schedule(
    seed: int,
    num_workers: int,
    num_nodes: int,
    horizon: float,
    crash_rate: float = 0.0,
    preempt_rate: float = 0.0,
    slowdown_rate: float = 0.0,
    nic_rate: float = 0.0,
    mttr: float = 1.0,
    notice: float = 0.05,
    slow_factor: float = 4.0,
    nic_factor: float = 4.0,
    min_live: int = 2,
    start: float = 0.0,
) -> FaultSchedule:
    """Draw a replayable schedule from a seeded merged Poisson hazard.

    Rates are events/second over the whole cluster; event times are the
    merged process's exponential inter-arrivals, kinds are drawn
    proportionally to their rates, targets uniformly.  Repair times
    (``duration``) are exponential with mean ``mttr``.  All randomness is
    consumed HERE, at construction, from ``np.random.default_rng(seed)``
    — the same seed always yields the identical schedule, and the engine
    draws nothing at run time.

    ``min_live`` is a liveness floor baked into the draw: a crash or
    preemption whose outage would leave fewer than ``min_live`` workers
    simultaneously up is suppressed (the draw is still consumed, so the
    remaining events are unchanged).  This keeps hazard-generated
    scenarios inside the regime the recovery layer — and
    `FaultConfig.min_hosts` — is specified for.
    """
    total = crash_rate + preempt_rate + slowdown_rate + nic_rate
    if total <= 0.0 or horizon <= 0.0:
        return FaultSchedule()
    rng = np.random.default_rng(seed)
    probs = np.asarray(
        [crash_rate, preempt_rate, slowdown_rate, nic_rate]
    ) / total
    events: List[FaultEvent] = []
    down: List[Tuple[float, float]] = []
    t = start
    while True:
        t += float(rng.exponential(1.0 / total))
        if t >= start + horizon:
            break
        kind = FAULT_KINDS[int(rng.choice(4, p=probs))]
        dur = float(rng.exponential(mttr)) + 1e-6
        if kind == NIC_DEGRADE:
            events.append(FaultEvent(
                time=t, kind=kind, worker=int(rng.integers(num_nodes)),
                duration=dur, factor=nic_factor,
            ))
            continue
        w = int(rng.integers(num_workers))
        if kind == SLOWDOWN:
            events.append(FaultEvent(
                time=t, kind=kind, worker=w, duration=dur,
                factor=slow_factor,
            ))
            continue
        t_down = t + (notice if kind == PREEMPT else 0.0)
        t_up = t_down + dur
        overlapping = sum(1 for s, e in down if s < t_up and e > t_down)
        if overlapping >= max(num_workers - min_live, 0):
            continue  # draw consumed, fault suppressed (liveness floor)
        down.append((t_down, t_up))
        events.append(FaultEvent(
            time=t, kind=kind, worker=w, duration=dur,
            notice=(notice if kind == PREEMPT else 0.0),
        ))
    return FaultSchedule(events=tuple(events))


def default_sim_fault_config():
    """`FaultConfig` scaled to simulator time: query latencies are
    O(seconds), so heartbeats tick every 20 virtual ms and a silent
    worker is declared dead after ~2 missed windows — detection latency
    stays well under typical SLO targets while the N-strikes straggler
    hysteresis keeps its paper defaults."""
    from repro.runtime.fault_tolerance import FaultConfig

    return FaultConfig(
        heartbeat_interval=0.02,
        missed_beats_dead=2,
        straggler_theta=0.5,
        n_strikes=3,
        slope_window=8,
        min_hosts=2,
    )
