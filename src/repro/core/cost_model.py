"""Cost-aware redistribution gate (paper goal #3: 'low-overhead
redistribution ... decisions should be cost-aware so that the overhead of
transferring rows does not exceed the performance gains').

The model prices a candidate redistribution in seconds on both sides:

  transfer_time = bytes_moved / link_bandwidth
                + items_moved * per_item_overhead      (serialization / RPC)
  time_saved    = current_makespan - balanced_makespan

and admits the move iff  time_saved > cost_gate * transfer_time.

This module is the jax-traced entry point used inside ``AdaptiveLink.step``;
the arithmetic itself lives in `repro.core.admission`
(:func:`~repro.core.admission.transfer_seconds`,
:func:`~repro.core.admission.cost_gate_admits`), whose plain-operator
implementations are polymorphic over Python floats, numpy and jax arrays —
one formula set shared with the simulator / serving / data-pipeline hot
paths, so the in-graph gate can never drift from the host-side one.

On TPU the 'network' is ICI (~50 GB/s/link); in the simulator it is the
configured NIC bandwidth.  The same formula prices the three row-size
regimes called out in the paper: ordinary rows (cheap), 100 GB+ blobs
(§III.B — transfer dominates, gate rejects), and our TPU analogues
(KV-cache migration, expert-weight replication).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import admission


@dataclasses.dataclass(frozen=True)
class CostModelConfig:
    link_bandwidth: float = 50e9     # bytes/s (TPU v5e ICI per link)
    per_item_overhead: float = 5e-6  # s per moved item (serialize+route)
    cost_gate: float = 1.0           # admit iff saved > gate * transfer


def transfer_time(
    bytes_moved: jax.Array,
    items_moved: jax.Array,
    cfg: CostModelConfig,
) -> jax.Array:
    return admission.transfer_seconds(
        bytes_moved.astype(jnp.float32),
        items_moved.astype(jnp.float32),
        cfg.link_bandwidth,
        cfg.per_item_overhead,
    )


def balance_benefit(
    loads_before: jax.Array,
    loads_after: jax.Array,
) -> jax.Array:
    """Makespan reduction (seconds of straggler time removed)."""
    return jnp.maximum(jnp.max(loads_before) - jnp.max(loads_after), 0.0)


def admit(
    loads_before: jax.Array,
    loads_after: jax.Array,
    bytes_moved: jax.Array,
    items_moved: jax.Array,
    cfg: CostModelConfig,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (admit?, est_time_saved, est_transfer_time)."""
    saved = balance_benefit(loads_before, loads_after)
    t_move = transfer_time(bytes_moved, items_moved, cfg)
    return admission.cost_gate_admits(saved, t_move, cfg.cost_gate), saved, t_move
