"""Core types for the DySkew adaptive data link.

The paper models each data-link instance as an independent state machine
(Fig. 2) progressing through four phases.  We encode states and policies as
integers so the whole machine is `jax.lax`-traceable and can be carried in a
jitted training/serving step, while remaining usable from plain Python in the
discrete-event simulator (`repro.sim`).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


class LinkState(enum.IntEnum):
    """States of the adaptive-link state machine (paper §III.A, Fig. 2).

    Phase 1: INIT — link configured with its policy, before data flows.
    Phase 2: DECIDING — processing locally while the skew model evaluates.
    Phase 3: DRAINING — intermediate: finish in-flight batch/file boundaries
             before committing to distributed mode.
    Phase 4: LOCAL_TERMINAL / DISTRIBUTED_TERMINAL — committed modes.
             DISTRIBUTING is the active distributed state reachable before a
             terminal commit in looping configurations.
    """

    INIT = 0
    DECIDING = 1
    DRAINING = 2
    DISTRIBUTING = 3
    LOCAL_TERMINAL = 4
    DISTRIBUTED_TERMINAL = 5

    @property
    def is_terminal(self) -> bool:
        return self in (LinkState.LOCAL_TERMINAL, LinkState.DISTRIBUTED_TERMINAL)

    @property
    def routes_remote(self) -> bool:
        """Whether a link in this state sends rows to remote instances."""
        return self in (LinkState.DISTRIBUTING, LinkState.DISTRIBUTED_TERMINAL)


NUM_STATES = len(LinkState)


class Policy(enum.IntEnum):
    """Redistribution policy declared by the consumer operator (§III.A).

    NEVER          — rows never leave the local instance (ordering / local
                     state dependencies).
    LATE           — default: process locally, redistribute only once the
                     skew model fires (N strikes).
    EARLY          — redistribute immediately; observation phase skipped.
    EAGER_SNOWPARK — the paper's Snowpark policy: EARLY + row-size/batch-
                     density guard (§III.B) + no self-skipping.
    """

    NEVER = 0
    LATE = 1
    EARLY = 2
    EAGER_SNOWPARK = 3


class SkewModelKind(enum.IntEnum):
    ROW_PERCENTAGE = 0   # Eq. (1)
    IDLE_TIME = 1
    SYNC_TIME_SLOPE = 2  # Eq. (2)


@dataclasses.dataclass(frozen=True)
class DySkewConfig:
    """Static configuration of the adaptive link (hashable; safe to close
    over in jit)."""

    policy: Policy = Policy.LATE
    skew_model: SkewModelKind = SkewModelKind.ROW_PERCENTAGE
    # Eq. (1)/(2) threshold θ: instance i is skewed when
    #   metric_i * theta > mean(metric_{-i}).
    theta: float = 0.5
    # N-strikes framework: N consecutive detections before redistribution.
    n_strikes: int = 3
    # Idle-time model: a sibling is idle if it received no row/signal for
    # `idle_grace` ticks; skew fires when >= `idle_sibling_frac` of siblings
    # are idle while we are busy.
    idle_grace: int = 2
    idle_sibling_frac: float = 0.5
    # Sync-time-slope model: sliding window length (measurements).
    slope_window: int = 8
    # Row Size Model (§III.B): target batch density (rows/batch) and the
    # low-density trigger. Paper: normal batches carry thousands of rows;
    # heavy-row batches drop density by >99 %.
    target_batch_density: float = 4096.0
    min_batch_density_frac: float = 0.01
    # A batch counts as 'heavy-row' only if density collapsed BECAUSE rows
    # are large (>= heavy_row_bytes); small end-of-stream remainder batches
    # must not trip the guard.
    heavy_row_bytes: float = 1e6
    # Whether the local instance is a valid redistribution destination.
    # Paper §III.B removes the self-skipping logic for Snowpark.
    self_skip: bool = False
    # Looping: terminal states may re-enter DECIDING (non-looping default).
    looping: bool = False
    # Cost model: refuse a redistribution whose estimated transfer time
    # exceeds `cost_gate` × the estimated compute time saved.
    cost_gate: float = 1.0

    @property
    def min_batch_density(self) -> float:
        return self.target_batch_density * self.min_batch_density_frac

    def replace(self, **kw: Any) -> "DySkewConfig":
        return dataclasses.replace(self, **kw)


def link_metrics_zeros(num_instances: int, slope_window: int) -> Dict[str, jax.Array]:
    """Per-instance runtime metrics observed by the skew models.

    A pytree of arrays shaped (num_instances, ...) so a single SPMD program
    holds every sibling's view (the paper's 'state machines can observe the
    state of sibling instances').
    """
    n = num_instances
    return {
        # Cumulative rows processed by each instance (row-percentage model).
        "rows": jnp.zeros((n,), jnp.float32),
        # Ticks since each instance last received a row/signal (idle model).
        "idle_ticks": jnp.zeros((n,), jnp.float32),
        # Sliding window of per-tick synchronous processing time (slope model),
        # newest entry last.
        "sync_window": jnp.zeros((n, slope_window), jnp.float32),
        # Rows per batch observed this tick (Row Size Model).
        "batch_density": jnp.full((n,), 0.0, jnp.float32),
        # Bytes per row observed this tick (Row Size Model / cost model).
        "bytes_per_row": jnp.zeros((n,), jnp.float32),
    }


def link_state_init(
    num_instances: int,
    config: DySkewConfig,
) -> Dict[str, jax.Array]:
    """Initial carried state for `num_instances` sibling link instances."""
    n = num_instances
    return {
        "state": jnp.full((n,), int(LinkState.INIT), jnp.int32),
        "strikes": jnp.zeros((n,), jnp.int32),
        "metrics": link_metrics_zeros(n, config.slope_window),
        # Count of redistribution transitions committed (telemetry; feeds the
        # production-rollout benchmark's '% of queries redistributed').
        "transitions": jnp.zeros((n,), jnp.int32),
        "tick": jnp.zeros((), jnp.int32),
    }


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoutingPlan:
    """Result of a redistribution decision for one tick.

    ``dest`` maps each work item to a destination instance; ``distribute``
    is the per-instance boolean saying whether that producer is in a
    remote-routing state this tick.  Registered as a pytree so plans flow
    through jit/scan.
    """

    dest: jax.Array          # (num_items,) int32 destination instance ids
    distribute: jax.Array    # (num_instances,) bool
    est_bytes_moved: Optional[jax.Array] = None  # scalar, cost-model telemetry
    est_time_saved: Optional[jax.Array] = None   # scalar, cost-model telemetry
