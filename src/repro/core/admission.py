"""Shared per-batch admission planner for DySkew redistribution.

One host-side implementation of the three admission guards every DySkew
call-site needs before it may move work off its producer:

  density guard — the Row Size Model (§III.B): a batch whose density
      collapsed *because rows are huge* stays local unless enough sibling
      interpreters are idle to make the move worthwhile;
  cost gate     — goal 3 (§I): refuse a redistribution whose estimated
      transfer time exceeds the estimated straggler time saved;
  self-skip     — destination eligibility for the §III.B forced-remote
      ablation (the producer — or its whole node — is excluded).

Historically `sim/engine.py`, `serving/engine.py` and `data/pipeline.py`
each re-implemented this gating by hand; they now all call this planner.
The jax-traced twin of the cost gate lives in `repro.core.cost_model`
(used inside `AdaptiveLink.step`); the formulas here are kept identical
but run on plain Python/numpy scalars so they are cheap inside the
simulator's per-batch hot loop.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import numpy as np

from repro.core.types import DySkewConfig


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of a per-batch admission check (telemetry-friendly)."""

    admit: bool
    reason: str = "ok"      # ok | density_guard | cost_gate
    est_transfer: float = 0.0
    est_saved: float = 0.0


def transfer_seconds(
    bytes_moved: float,
    rows_moved: int,
    bandwidth: float,
    per_row_overhead: float,
) -> float:
    """Estimated seconds to move ``rows_moved`` rows of ``bytes_moved``
    total bytes over a link (serialization priced per row)."""
    return bytes_moved / bandwidth + rows_moved * per_row_overhead


def straggler_savings(
    est_row_cost: float, rows_moved: int, num_instances: int
) -> float:
    """Estimated straggler seconds removed by spreading ``rows_moved``
    rows (of opaque estimated cost) across ``num_instances`` workers."""
    return est_row_cost * rows_moved * (1.0 - 1.0 / max(num_instances, 1))


class BatchAdmission:
    """The DySkew admission guards, bound to one :class:`DySkewConfig`.

    ``enable_density_guard`` / ``enable_cost_gate`` exist for the paper's
    ablations; a disabled guard admits everything.
    """

    def __init__(
        self,
        cfg: DySkewConfig,
        *,
        enable_density_guard: bool = True,
        enable_cost_gate: bool = True,
    ):
        self.cfg = cfg
        self.enable_density_guard = enable_density_guard
        self.enable_cost_gate = enable_cost_gate

    # -- Row Size Model (§III.B) ------------------------------------- #

    def density_guard_blocks(
        self,
        num_rows: int,
        bytes_per_row: float,
        idle_sibling_frac: Union[float, Callable[[], float]] = 0.0,
    ) -> bool:
        """True → keep the batch local: density collapsed because rows are
        heavy and siblings are not idle enough to justify moving them.

        ``idle_sibling_frac`` may be a callable so callers can defer the
        (O(n)) sibling scan until the cheap size checks have passed.
        """
        cfg = self.cfg
        if not (
            self.enable_density_guard
            and num_rows < cfg.min_batch_density
            and bytes_per_row >= cfg.heavy_row_bytes
        ):
            return False
        frac = idle_sibling_frac() if callable(idle_sibling_frac) else idle_sibling_frac
        return frac < cfg.idle_sibling_frac

    # -- Cost gate (§I goal 3) ---------------------------------------- #

    def cost_gate_blocks(self, est_saved: float, est_transfer: float) -> bool:
        """True → the move is refused: savings do not clear the gate."""
        if not self.enable_cost_gate:
            return False
        return est_saved <= self.cfg.cost_gate * est_transfer

    def admit_move(
        self,
        bytes_moved: float,
        rows_moved: int,
        est_row_cost: float,
        num_instances: int,
        bandwidth: float,
        per_row_overhead: float,
    ) -> AdmissionDecision:
        """Full cost-gate decision for a candidate redistribution."""
        t_move = transfer_seconds(
            bytes_moved, rows_moved, bandwidth, per_row_overhead
        )
        saved = straggler_savings(est_row_cost, rows_moved, num_instances)
        if self.cost_gate_blocks(saved, t_move):
            return AdmissionDecision(False, "cost_gate", t_move, saved)
        return AdmissionDecision(True, "ok", t_move, saved)

    # -- Self-skip eligibility (§III.B forced-remote) ------------------ #

    def eligible_destinations(
        self,
        num_instances: int,
        producer: int,
        node_of: Optional[Callable[[int], int]] = None,
    ) -> np.ndarray:
        """Bool mask of valid destinations for ``producer``.

        With ``self_skip`` unset (the paper's Snowpark optimization) every
        instance is eligible.  With it set, the producer is excluded — or,
        when ``node_of`` is given, every interpreter on the producer's
        node (Fig. 1: redistribution targets *other* VW nodes).
        """
        mask = np.ones(num_instances, bool)
        if not self.cfg.self_skip:
            return mask
        if node_of is None:
            mask[producer] = False
        else:
            own = node_of(producer)
            for w in range(num_instances):
                if node_of(w) == own:
                    mask[w] = False
        return mask
