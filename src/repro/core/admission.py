"""Shared admission planning for DySkew redistribution and tenancy.

Two planners live here, both host-side and dependency-light:

:class:`BatchAdmission` — the three per-batch guards every DySkew
call-site needs before it may move work off its producer:

  density guard — the Row Size Model (§III.B): a batch whose density
      collapsed *because rows are huge* stays local unless enough sibling
      interpreters are idle to make the move worthwhile;
  cost gate     — goal 3 (§I): refuse a redistribution whose estimated
      transfer time exceeds the estimated straggler time saved;
  self-skip     — destination eligibility for the §III.B forced-remote
      ablation (the producer — or its whole node — is excluded).

:class:`DeadlineAwareAdmission` — the SLO layer on top of
:class:`FairShareAdmission`: per-tenant SLO targets translate pending
work into absolute deadlines, an earliest-deadline-first credit boost
relaxes the admission threshold as slack runs out (the full charge still
lands on the deficit, so long-run throughput shares stay weighted), EDF
ordering of parked-work release, and a :meth:`preempt_candidates` /
:meth:`preempt_transfer` API that names admitted-but-unstarted work of
over-share tenants to displace in favour of an urgent tenant.

:class:`AutoscalePolicy` — hysteresis warehouse autoscaling: grow the
interpreter pool (whole workers) when backlog per active worker or SLO
attainment degrades, shrink when the pool runs light, with a cooldown
between actions.  Pure decision logic — the simulator/serving engines
own the actual pool rescaling.

:class:`FairShareAdmission` — a weighted deficit-round-robin admission
layer for multi-tenant execution over ONE shared virtual warehouse.
Tenants carry priority weights; the planner paces each tenant's entry
into the shared interpreter pool (rows lane) and onto the shared NIC
(bytes lane, cost-gated per the Row Size Model: only batches whose
bytes-per-row clears ``heavy_row_bytes`` are charged network budget).
It is consumed by the multi-tenant simulator (`repro.sim.engine`), the
serving scheduler (`repro.serving.engine`) and the multi-tenant data
pipeline (`repro.data.pipeline`).

Invariants:

  * One formula set.  Historically `sim/engine.py`, `serving/engine.py`
    and `data/pipeline.py` each re-implemented the per-batch gating by
    hand; they now all call :class:`BatchAdmission`.  The cost-gate
    arithmetic (:func:`transfer_seconds`, :func:`straggler_savings`,
    :func:`cost_gate_admits`) is written with plain operators only, so
    it is polymorphic over Python floats, numpy arrays AND jax arrays —
    the in-graph gate ``AdaptiveLink.step`` consults
    (:class:`CostModelConfig` / :func:`admit_redistribution`, below)
    runs the same functions under jit rather than re-stating them.
  * Determinism.  Neither planner draws randomness; given the same call
    sequence they return the same decisions, which is what lets the
    simulator's equivalence pins and the replay harness's process-pool
    fan-out stay reproducible.
  * Starvation-freedom.  :class:`FairShareAdmission` guarantees every
    backlogged tenant is eventually admitted: deficits are credited to
    all live tenants on every completed service quantum, deficits are
    capped, and a tenant at its cap is always admissible.  When nothing
    is in service the planner admits unconditionally (work conservation
    — the pool is never idled while work waits).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.types import DySkewConfig


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of a per-batch admission check (telemetry-friendly)."""

    admit: bool
    reason: str = "ok"      # ok | density_guard | cost_gate
    est_transfer: float = 0.0
    est_saved: float = 0.0


def transfer_seconds(
    bytes_moved,
    rows_moved,
    bandwidth,
    per_row_overhead,
):
    """Estimated seconds to move ``rows_moved`` rows of ``bytes_moved``
    total bytes over a link (serialization priced per row).

    Polymorphic: operands may be Python floats, numpy or jax arrays."""
    return bytes_moved / bandwidth + rows_moved * per_row_overhead


def straggler_savings(est_row_cost, rows_moved, num_instances):
    """Estimated straggler seconds removed by spreading ``rows_moved``
    rows (of opaque estimated cost) across ``num_instances`` workers.

    Polymorphic over floats / numpy / jax for the scalar operands."""
    return est_row_cost * rows_moved * (1.0 - 1.0 / max(num_instances, 1))


def cost_gate_admits(est_saved, est_transfer, cost_gate):
    """The cost-gate predicate: admit iff the estimated straggler time
    saved strictly clears ``cost_gate`` times the estimated transfer
    time.  Written with plain operators so the SAME implementation runs
    on Python floats (simulator hot loop), numpy arrays, and jax traced
    values (:func:`admit_redistribution` inside ``AdaptiveLink.step``).
    """
    return est_saved > cost_gate * est_transfer


# --------------------------------------------------------------------- #
# In-graph redistribution gate (paper goal #3)
# --------------------------------------------------------------------- #
#
# The cost-aware gate as consumed from inside a jitted step: it prices a
# candidate redistribution in seconds on both sides —
#
#   transfer_time = bytes_moved / link_bandwidth
#                 + items_moved * per_item_overhead    (serialize / RPC)
#   time_saved    = current_makespan - balanced_makespan
#
# and admits iff time_saved > cost_gate * transfer_time.  On TPU the
# 'network' is ICI (~50 GB/s/link); in the simulator it is the NIC.
# Everything below is written with plain operators and array methods
# (``.max()``, ``.astype``), so the SAME code runs on host numpy arrays
# and on jax traced values — one formula set with the host-side planners
# above, which is what keeps the in-graph gate from drifting.  (This
# replaces the former `repro.core.cost_model` shim.)


@dataclasses.dataclass(frozen=True)
class CostModelConfig:
    link_bandwidth: float = 50e9     # bytes/s (TPU v5e ICI per link)
    per_item_overhead: float = 5e-6  # s per moved item (serialize+route)
    cost_gate: float = 1.0           # admit iff saved > gate * transfer


def balance_benefit(loads_before, loads_after):
    """Makespan reduction (seconds of straggler time removed), clamped
    at zero.  Polymorphic over numpy and jax arrays."""
    d = loads_before.max() - loads_after.max()
    return d * (d > 0)


def admit_redistribution(
    loads_before,
    loads_after,
    bytes_moved,
    items_moved,
    cfg: CostModelConfig,
):
    """Full in-graph gate decision.

    Returns ``(admit?, est_time_saved, est_transfer_time)``; operands may
    be numpy arrays or jax traced values (``AdaptiveLink.step`` calls
    this under jit)."""
    saved = balance_benefit(loads_before, loads_after)
    t_move = transfer_seconds(
        bytes_moved.astype(np.float32),
        items_moved.astype(np.float32),
        cfg.link_bandwidth,
        cfg.per_item_overhead,
    )
    return cost_gate_admits(saved, t_move, cfg.cost_gate), saved, t_move


class BatchAdmission:
    """The DySkew admission guards, bound to one :class:`DySkewConfig`.

    ``enable_density_guard`` / ``enable_cost_gate`` exist for the paper's
    ablations; a disabled guard admits everything.
    """

    def __init__(
        self,
        cfg: DySkewConfig,
        *,
        enable_density_guard: bool = True,
        enable_cost_gate: bool = True,
    ):
        self.cfg = cfg
        self.enable_density_guard = enable_density_guard
        self.enable_cost_gate = enable_cost_gate

    # -- Row Size Model (§III.B) ------------------------------------- #

    def density_guard_blocks(
        self,
        num_rows: int,
        bytes_per_row: float,
        idle_sibling_frac: Union[float, Callable[[], float]] = 0.0,
    ) -> bool:
        """True → keep the batch local: density collapsed because rows are
        heavy and siblings are not idle enough to justify moving them.

        ``idle_sibling_frac`` may be a callable so callers can defer the
        (O(n)) sibling scan until the cheap size checks have passed.
        """
        cfg = self.cfg
        if not (
            self.enable_density_guard
            and num_rows < cfg.min_batch_density
            and bytes_per_row >= cfg.heavy_row_bytes
        ):
            return False
        frac = idle_sibling_frac() if callable(idle_sibling_frac) else idle_sibling_frac
        return frac < cfg.idle_sibling_frac

    # -- Cost gate (§I goal 3) ---------------------------------------- #

    def cost_gate_blocks(self, est_saved: float, est_transfer: float) -> bool:
        """True → the move is refused: savings do not clear the gate."""
        if not self.enable_cost_gate:
            return False
        return not cost_gate_admits(est_saved, est_transfer, self.cfg.cost_gate)

    def admit_move(
        self,
        bytes_moved: float,
        rows_moved: int,
        est_row_cost: float,
        num_instances: int,
        bandwidth: float,
        per_row_overhead: float,
    ) -> AdmissionDecision:
        """Full cost-gate decision for a candidate redistribution."""
        t_move = transfer_seconds(
            bytes_moved, rows_moved, bandwidth, per_row_overhead
        )
        saved = straggler_savings(est_row_cost, rows_moved, num_instances)
        if self.cost_gate_blocks(saved, t_move):
            return AdmissionDecision(False, "cost_gate", t_move, saved)
        return AdmissionDecision(True, "ok", t_move, saved)

    # -- Self-skip eligibility (§III.B forced-remote) ------------------ #

    def eligible_destinations(
        self,
        num_instances: int,
        producer: int,
        node_of: Optional[Callable[[int], int]] = None,
    ) -> np.ndarray:
        """Bool mask of valid destinations for ``producer``.

        With ``self_skip`` unset (the paper's Snowpark optimization) every
        instance is eligible.  With it set, the producer is excluded — or,
        when ``node_of`` is given, every interpreter on the producer's
        node (Fig. 1: redistribution targets *other* VW nodes).
        """
        mask = np.ones(num_instances, bool)
        if not self.cfg.self_skip:
            return mask
        if node_of is None:
            mask[producer] = False
        else:
            own = node_of(producer)
            for w in range(num_instances):
                if node_of(w) == own:
                    mask[w] = False
        return mask


# --------------------------------------------------------------------- #
# Fair-share multi-tenant admission (weighted deficit round robin)
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class FairShareConfig:
    """Tuning for :class:`FairShareAdmission`.

    ``quantum_rows`` / ``quantum_bytes`` set the DRR round size: every
    time that many rows complete service, one round of credit is dealt to
    the live tenants in proportion to their weights.  ``burst_quanta``
    caps how many rounds of unspent credit a tenant may bank (its burst
    allowance).  ``heavy_row_bytes`` is the Row Size Model threshold for
    the NIC lane: only batches at or above it are charged byte budget —
    light rows ride the interpreter-pool lane alone.  ``None`` charges
    every batch's bytes.
    """

    quantum_rows: float = 64.0
    quantum_bytes: float = 32e6
    burst_quanta: float = 4.0
    heavy_row_bytes: Optional[float] = None


class FairShareAdmission:
    """Weighted deficit-round-robin admission over a shared pool + NIC.

    Each tenant ``q`` holds two deficit counters — rows (interpreter-pool
    slots) and bytes (NIC budget).  Admitting a batch deducts its charge;
    completed service credits every live tenant's deficits in proportion
    to its weight (one round per completed quantum), so admission
    throughput converges to weighted fair shares under contention while
    idle capacity is never reserved:

      * if nothing is in service, any request is admitted immediately
        (work conservation);
      * deficits are capped at ``burst_quanta`` rounds, and a tenant at
        its cap is ALWAYS admissible — together with per-quantum credits
        this makes starvation impossible for positive weights.

    Callers integrate in one of two modes:

      park/release — `try_admit` at each arrival; park rejected work and
          retry (in `release_order`) after calling `on_complete` for
          finished service.  Used by the simulator and serving engine.
      DRR pick — `pick_next(costs)` selects which tenant's next work item
          to serve, classic deficit-round-robin.  Used by the data
          pipeline to interleave per-tenant document streams.
    """

    def __init__(
        self,
        weights: Sequence[float],
        cfg: FairShareConfig = FairShareConfig(),
    ):
        if not len(weights):
            raise ValueError("need at least one tenant weight")
        if any(w <= 0 for w in weights):
            raise ValueError(f"tenant weights must be positive: {weights}")
        self.cfg = cfg
        self.weights = [float(w) for w in weights]
        self.nq = len(self.weights)
        self.live = [True] * self.nq
        # A tenant is 'backlogged' from its first refused admission until
        # its next successful one; credit is dealt over the backlogged set
        # (falling back to all live tenants when nobody is waiting), so no
        # credit evaporates at an idle tenant's cap — the aggregate
        # admission rate tracks the completion rate (work conservation).
        self.backlogged = [False] * self.nq
        # Start saturated: fair-share pacing only bites under contention.
        self.deficit_rows = [self._cap_rows(q) for q in range(self.nq)]
        self.deficit_bytes = [self._cap_bytes(q) for q in range(self.nq)]
        self.outstanding_rows = [0.0] * self.nq
        self._total_outstanding = 0.0
        self._round_acc = 0.0
        self._cursor = 0
        # Telemetry.
        self.admitted = [0] * self.nq
        self.deferred = [0] * self.nq
        self.lost_rows = [0.0] * self.nq
        self.readmitted_rows = [0] * self.nq

    # -- weighted shares ------------------------------------------------ #

    def share_of(self, q: int) -> float:
        """Tenant ``q``'s normalized weight among live tenants.  Used for
        the deficit caps, so a tenant's burst allowance is stable whether
        or not it is currently waiting."""
        if not self.live[q]:
            return 0.0
        total = sum(w for w, a in zip(self.weights, self.live) if a)
        return self.weights[q] / total if total > 0 else 0.0

    def _credit_share(self, q: int) -> float:
        """Tenant ``q``'s share of each dealt credit round: normalized
        over the BACKLOGGED live tenants when anyone is waiting (so no
        credit evaporates at an idle tenant's cap and aggregate admission
        tracks the completion rate), else over all live tenants."""
        if not self.live[q]:
            return 0.0
        any_backlogged = any(
            b and a for b, a in zip(self.backlogged, self.live)
        )
        if any_backlogged and not self.backlogged[q]:
            return 0.0
        total = sum(
            w for w, a, b in zip(self.weights, self.live, self.backlogged)
            if a and (b or not any_backlogged)
        )
        return self.weights[q] / total if total > 0 else 0.0

    def _cap_rows(self, q: int) -> float:
        return self.cfg.burst_quanta * self.cfg.quantum_rows * max(
            self.share_of(q), 1e-9
        )

    def _cap_bytes(self, q: int) -> float:
        return self.cfg.burst_quanta * self.cfg.quantum_bytes * max(
            self.share_of(q), 1e-9
        )

    def deactivate(self, q: int) -> None:
        """Tenant ``q`` finished: stop dealing it credit; survivors'
        shares grow accordingly."""
        self.live[q] = False
        self.backlogged[q] = False

    # -- park/release mode --------------------------------------------- #

    def _nic_charge(self, nbytes: float, bytes_per_row: float) -> float:
        """Row Size Model cost-gating of the NIC lane: light rows are an
        interpreter-pool concern only; heavy rows also consume network
        budget (they are what saturates the uplink — §III.B).

        This is an admission-time ESTIMATE: admission runs before routing
        decides how many of the batch's bytes actually cross the NIC, so
        a heavy batch that ends up staying local is still charged.  The
        bias is conservative (network budget is reserved, never
        exceeded) and symmetric across tenants with similar workloads."""
        hv = self.cfg.heavy_row_bytes
        if hv is not None and bytes_per_row < hv:
            return 0.0
        return nbytes

    def _admissible(
        self,
        q: int,
        rows: int,
        charge_b: float,
        boost_r: float = 0.0,
        boost_b: float = 0.0,
        rows_advance: float = 0.0,
    ) -> bool:
        """Pure threshold test (no mutation): would ``rows``/``charge_b``
        clear admission now?  ``rows_advance`` hypothesizes extra row
        credit (capped) — the preemption dry-run probe, so engines can
        check an admission WOULD succeed before displacing victims."""
        if self._total_outstanding <= 0.0:
            return True
        dr = min(self.deficit_rows[q] + rows_advance, self._cap_rows(q))
        ok_rows = dr + boost_r >= rows or dr >= self._cap_rows(q)
        ok_bytes = (
            charge_b == 0.0
            or self.deficit_bytes[q] + boost_b >= charge_b
            or self.deficit_bytes[q] >= self._cap_bytes(q)
        )
        return ok_rows and ok_bytes

    def _admit_checked(
        self,
        q: int,
        rows: int,
        nbytes: float,
        bytes_per_row: float,
        boost_r: float = 0.0,
        boost_b: float = 0.0,
    ) -> bool:
        """The ONE copy of the park-or-admit body, shared with
        :class:`DeadlineAwareAdmission` (which passes its EDF boosts;
        the base planner's boosts are zero).  The boost relaxes only the
        admission THRESHOLD — the charge always lands in full."""
        charge_b = self._nic_charge(nbytes, bytes_per_row)
        if self._total_outstanding > 0.0:
            if not self._admissible(q, rows, charge_b, boost_r, boost_b):
                self.deferred[q] += 1
                self.backlogged[q] = True
                return False
        # Charge in full, carrying debt (negative deficit) when the batch
        # exceeds the banked credit — standard DRR accounting.  Without
        # the debt, a tenant submitting oversized batches via the
        # saturation rule would be systematically undercharged and exceed
        # its weighted share.
        self.deficit_rows[q] -= rows
        self.deficit_bytes[q] -= charge_b
        self.outstanding_rows[q] += rows
        self._total_outstanding += rows
        self.admitted[q] += 1
        self.backlogged[q] = False
        return True

    def try_admit(
        self, q: int, rows: int, nbytes: float, bytes_per_row: float = 0.0
    ) -> bool:
        """Admit ``rows``/``nbytes`` of tenant ``q`` now, or refuse.

        On True the charge is deducted and the work counts as in-service
        until :meth:`on_complete`.  On False nothing is deducted — park
        the work and retry after the next completion.
        """
        return self._admit_checked(q, rows, nbytes, bytes_per_row)

    def on_complete(self, q: int, rows: int) -> None:
        """Report ``rows`` of tenant ``q`` finishing service.  Credits one
        DRR round to every live tenant per completed ``quantum_rows``."""
        take = min(float(rows), self.outstanding_rows[q])
        self.outstanding_rows[q] -= take
        self._total_outstanding = max(self._total_outstanding - take, 0.0)
        self._round_acc += rows
        qr, qb = self.cfg.quantum_rows, self.cfg.quantum_bytes
        while self._round_acc >= qr:
            self._round_acc -= qr
            for a in range(self.nq):
                if not self.live[a]:
                    continue
                s = self._credit_share(a)
                if s <= 0.0:
                    continue
                self.deficit_rows[a] = min(
                    self.deficit_rows[a] + qr * s, self._cap_rows(a)
                )
                self.deficit_bytes[a] = min(
                    self.deficit_bytes[a] + qb * s, self._cap_bytes(a)
                )

    def on_lost(self, q: int, rows: int, refund: bool = False) -> None:
        """Report ``rows`` of tenant ``q`` LOST from service (worker
        crash/preemption) or withdrawn (straggler migration) before
        completing.

        The rows are retired from the in-service ledger — they will never
        reach :meth:`on_complete`, and without retirement the ledger
        never drains and work-conserving admission wedges.  With
        ``refund=False`` (failure) the original charge STANDS: the spend
        physically happened, and the re-admission of the recovered rows
        is charged again — that second charge is the tenant's retry debt.
        With ``refund=True`` (the SYSTEM chose to displace the rows, e.g.
        a straggler drain) the row charge is credited back up to the cap,
        mirroring :meth:`DeadlineAwareAdmission.preempt_transfer`.
        """
        take = min(float(rows), self.outstanding_rows[q])
        self.outstanding_rows[q] -= take
        self._total_outstanding = max(self._total_outstanding - take, 0.0)
        self.lost_rows[q] += take
        if refund:
            self.deficit_rows[q] = min(
                self.deficit_rows[q] + take, self._cap_rows(q)
            )
        elif self.live[q]:
            # The recovered rows will be back asking for admission.
            self.backlogged[q] = True

    def try_readmit(
        self,
        q: int,
        rows: int,
        deadline: Optional[float] = None,
        now: float = 0.0,
    ) -> bool:
        """Admit recovered rows re-entering after a loss.  Same gate and
        same charge as fresh work — recovery is paid for, not free — but
        no NIC charge: the re-fetch transfer is modeled (and paid) by the
        engine's recovery routing, and the bytes were already billed at
        original admission.  ``deadline``/``now`` are accepted for
        signature compatibility with the deadline-aware subclass and
        ignored here."""
        ok = self.try_admit(q, rows, 0.0, 0.0)
        if ok:
            self.readmitted_rows[q] += rows
        return ok

    def release_order(self) -> List[int]:
        """Round-robin order in which parked tenants should retry
        :meth:`try_admit` after a completion; the cursor advances one
        position per call so ties rotate fairly."""
        order = [(self._cursor + i) % self.nq for i in range(self.nq)]
        self._cursor = (self._cursor + 1) % self.nq
        return order

    # -- DRR pick mode -------------------------------------------------- #

    def pick_next(self, costs: Sequence[Optional[float]]) -> int:
        """Classic deficit round robin: pick the tenant whose head-of-line
        item (``costs[q]``; None = no item) should be served next.

        Each visit deals the visited tenant one weighted quantum; the
        first tenant whose deficit covers its item cost wins and pays.
        Terminates because every full rotation strictly grows every
        candidate's deficit.
        """
        cand = [
            q for q in range(self.nq)
            if costs[q] is not None and self.live[q]
        ]
        if not cand:
            raise ValueError("pick_next: no live tenant has a pending item")
        total_w = sum(self.weights[q] for q in cand)
        # Hard bound on rotations: enough for the costliest item even at
        # the smallest weighted quantum (plus slack); beyond it, serve the
        # largest-deficit candidate rather than loop.
        min_gain = self.cfg.quantum_rows * min(
            self.weights[q] / total_w for q in cand
        )
        max_cost = max(float(costs[q]) for q in cand)
        max_visits = (int(max_cost / max(min_gain, 1e-12)) + 2) * self.nq
        for _ in range(max_visits):
            q = self._cursor
            self._cursor = (self._cursor + 1) % self.nq
            if q not in cand:
                continue
            self.deficit_rows[q] += (
                self.cfg.quantum_rows * self.weights[q] / total_w
            )
            if self.deficit_rows[q] >= float(costs[q]):
                self.deficit_rows[q] -= float(costs[q])
                self.admitted[q] += 1
                return q
        q = max(cand, key=lambda a: self.deficit_rows[a])
        # Charge the served item like the normal path does (carrying debt)
        # — zeroing the deficit here let a tenant with oversized items
        # earn a free reset every time the rotation bound tripped,
        # systematically exceeding its weighted share.
        self.deficit_rows[q] -= float(costs[q])
        self.admitted[q] += 1
        return q


# --------------------------------------------------------------------- #
# SLO layer: deadline-aware admission + warehouse autoscaling
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class DeadlineConfig:
    """Tuning for :class:`DeadlineAwareAdmission`.

    ``urgency_horizon`` is the slack (seconds to deadline) below which the
    EDF credit boost ramps in: at slack >= horizon the planner behaves
    exactly like weight-only fair share, at slack <= 0 the boost is at
    its ``boost_quanta`` maximum.  ``preempt_headroom`` is the multiple
    of a tenant's weighted share of in-service rows beyond which it is
    named a preemption candidate (1.0 = any over-share tenant; higher =
    only clearly-over tenants).
    """

    urgency_horizon: float = 1.0
    boost_quanta: float = 2.0
    preempt_headroom: float = 1.25


class DeadlineAwareAdmission(FairShareAdmission):
    """Per-tenant SLO targets + EDF credit boost over weighted DRR.

    Each tenant may declare an SLO target (seconds from a work item's
    arrival to its completion).  Callers pass the item's absolute
    ``deadline`` and the current virtual ``now`` to :meth:`try_admit`;
    under contention the admission threshold is relaxed by a boost that
    grows linearly as slack shrinks inside ``urgency_horizon`` — but the
    FULL charge still lands on the tenant's deficit (debt), so admitted
    throughput still converges to the weighted shares over time; the
    boost only reorders WHO gets through while the deadline is live.

    Three additions over the base planner:

      * EDF release ordering — :meth:`release_order` sorts the parked
        tenants by earliest refused deadline (stable w.r.t. the base
        round-robin rotation, so tenants without deadlines keep rotating
        fairly behind the urgent ones).
      * :meth:`preempt_candidates` — names tenants whose in-service rows
        exceed ``preempt_headroom`` times their weighted share of the
        total (most-over-share first): their admitted-but-unstarted work
        is what an engine may re-park to make room for an urgent tenant.
      * :meth:`preempt_transfer` — the bookkeeping for one preemption:
        the victim's re-parked rows leave service (their charge is
        refunded, since re-admission will charge them again) and the
        urgent tenant's row deficit is advanced by the same amount
        (capped), which is what makes its retry admissible.

    Starvation-freedom is inherited: boosts and advances only ever ADD
    admissibility, deficits stay capped, and a tenant at its cap remains
    always admissible, so every backlogged tenant — with or without an
    SLO — is still eventually served.
    """

    def __init__(
        self,
        weights: Sequence[float],
        slo_targets: Sequence[Optional[float]],
        cfg: FairShareConfig = FairShareConfig(),
        deadline_cfg: DeadlineConfig = DeadlineConfig(),
    ):
        super().__init__(weights, cfg)
        if len(slo_targets) != self.nq:
            raise ValueError(
                f"slo_targets length {len(slo_targets)} != tenant count "
                f"{self.nq}"
            )
        self.slo_targets = [
            None if s is None else float(s) for s in slo_targets
        ]
        self.dcfg = deadline_cfg
        inf = float("inf")
        #: Earliest deadline among each tenant's currently-refused offers
        #: (inf = none pending); drives the EDF release order.
        self.pending_deadline = [inf] * self.nq
        # Telemetry.
        self.preempted_rows = [0.0] * self.nq
        self.boost_admits = [0] * self.nq

    # -- EDF credit boost ---------------------------------------------- #

    def _urgency(self, deadline: Optional[float], now: float) -> float:
        """0 (relaxed) → 1 (at/past deadline) inside the horizon."""
        if deadline is None or deadline == float("inf"):
            return 0.0
        h = max(self.dcfg.urgency_horizon, 1e-12)
        u = 1.0 - (deadline - now) / h
        return min(max(u, 0.0), 1.0)

    def try_admit(
        self,
        q: int,
        rows: int,
        nbytes: float,
        bytes_per_row: float = 0.0,
        deadline: Optional[float] = None,
        now: float = 0.0,
    ) -> bool:
        u = self._urgency(deadline, now)
        s = max(self.share_of(q), 1e-9)
        boost_r = u * self.dcfg.boost_quanta * self.cfg.quantum_rows * s
        boost_b = u * self.dcfg.boost_quanta * self.cfg.quantum_bytes * s
        contended = self._total_outstanding > 0.0
        boosted = (
            contended and boost_r > 0.0 and self.deficit_rows[q] < rows
        )
        # One shared park-or-admit body (the base class's), with the EDF
        # boosts relaxing the threshold; the full charge still lands.
        if not self._admit_checked(
            q, rows, nbytes, bytes_per_row, boost_r, boost_b
        ):
            if deadline is not None and deadline < self.pending_deadline[q]:
                self.pending_deadline[q] = deadline
            return False
        if boosted:
            self.boost_admits[q] += 1
        self.pending_deadline[q] = float("inf")
        return True

    def would_admit(
        self,
        q: int,
        rows: int,
        nbytes: float,
        bytes_per_row: float = 0.0,
        deadline: Optional[float] = None,
        now: float = 0.0,
        rows_advance: float = 0.0,
    ) -> bool:
        """Dry-run of :meth:`try_admit` (no state touched):
        ``rows_advance`` hypothesizes the row credit a preemption could
        transfer, so an engine can verify the urgent admission WOULD
        succeed before it displaces any victim's work."""
        u = self._urgency(deadline, now)
        s = max(self.share_of(q), 1e-9)
        return self._admissible(
            q, rows, self._nic_charge(nbytes, bytes_per_row),
            u * self.dcfg.boost_quanta * self.cfg.quantum_rows * s,
            u * self.dcfg.boost_quanta * self.cfg.quantum_bytes * s,
            rows_advance,
        )

    def try_readmit(
        self,
        q: int,
        rows: int,
        deadline: Optional[float] = None,
        now: float = 0.0,
    ) -> bool:
        """Recovered-row re-admission with the EDF boost: rows lost near
        a deadline re-enter with the same urgency relaxation a fresh
        urgent batch would get (the full retry charge still lands)."""
        ok = self.try_admit(q, rows, 0.0, 0.0, deadline=deadline, now=now)
        if ok:
            self.readmitted_rows[q] += rows
        return ok

    def release_order(self) -> List[int]:
        """EDF first: parked tenants with earlier refused deadlines come
        before later/deadline-free ones; ties keep the base round-robin
        rotation (the sort is stable), so no-SLO tenants still rotate."""
        order = super().release_order()
        return sorted(order, key=lambda q: self.pending_deadline[q])

    # -- preemption ----------------------------------------------------- #

    def preempt_candidates(
        self, protect: Sequence[int] = ()
    ) -> List[Tuple[int, float]]:
        """Over-share tenants whose in-service rows exceed
        ``preempt_headroom`` × their weighted share of total in-service
        rows.  Returns ``(tenant, excess_rows)`` pairs, most-over-share
        first (ties by tenant index); ``protect`` tenants are skipped."""
        tot = self._total_outstanding
        if tot <= 0.0:
            return []
        skip = set(protect)
        out: List[Tuple[int, float]] = []
        for q in range(self.nq):
            if q in skip or not self.live[q]:
                continue
            fair = self.dcfg.preempt_headroom * self.share_of(q) * tot
            excess = self.outstanding_rows[q] - fair
            if excess > 0.0:
                out.append((q, excess))
        out.sort(key=lambda t: -t[1])
        return out

    def preempt_transfer(self, victim: int, urgent: int, rows: float) -> None:
        """Account one preemption of ``rows`` admitted-but-unstarted rows
        from ``victim`` in favour of ``urgent`` (see class docstring)."""
        take = min(float(rows), self.outstanding_rows[victim])
        self.outstanding_rows[victim] -= take
        self._total_outstanding = max(self._total_outstanding - take, 0.0)
        # Refund the victim (its re-parked rows will be charged again on
        # re-admission) and advance the urgent tenant by the same amount.
        self.deficit_rows[victim] = min(
            self.deficit_rows[victim] + take, self._cap_rows(victim)
        )
        self.deficit_rows[urgent] = min(
            self.deficit_rows[urgent] + take, self._cap_rows(urgent)
        )
        self.preempted_rows[victim] += take
        self.backlogged[victim] = True


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Tuning for :class:`AutoscalePolicy`.

    The pool grows by ``step`` whole workers when queued rows per active
    worker exceed ``backlog_high`` — or when running SLO attainment sags
    below ``attainment_low`` while any backlog exists — and shrinks when
    backlog per worker falls under ``backlog_low`` with attainment
    healthy.  ``interval`` is the decision cadence (virtual seconds) and
    ``cooldown`` the minimum time between two resizes (the hysteresis
    that stops flapping).  ``min_workers``/``max_workers`` bound the pool
    (engines clamp ``max_workers`` to the physical cluster).
    """

    min_workers: int = 8
    max_workers: int = 1 << 30
    backlog_high: float = 64.0
    backlog_low: float = 8.0
    attainment_low: float = 0.9
    step: int = 4
    interval: float = 0.25
    cooldown: float = 0.5


class AutoscalePolicy:
    """Deterministic hysteresis autoscaler (decision logic only).

    Engines call :meth:`decide` on a fixed cadence with the observed
    backlog and (optionally) the running SLO attainment; the returned
    worker count is what the pool should be rescaled to.  No randomness,
    no wall clock — the same observation sequence always produces the
    same resize sequence, preserving the engines' determinism contract.
    """

    def __init__(self, cfg: AutoscaleConfig):
        self.cfg = cfg
        self._last_resize = -float("inf")
        #: (now, old, new) log of every applied resize (telemetry).
        self.resizes: List[Tuple[float, int, int]] = []

    def decide(
        self,
        now: float,
        active: int,
        backlog_rows: float,
        attainment: Optional[float] = None,
    ) -> int:
        c = self.cfg
        if now - self._last_resize < c.cooldown:
            return active
        per = backlog_rows / max(active, 1)
        target = active
        if per > c.backlog_high or (
            attainment is not None
            and attainment < c.attainment_low
            and backlog_rows > 0.0
        ):
            target = active + c.step
        elif per < c.backlog_low and (
            attainment is None or attainment >= c.attainment_low
        ):
            target = active - c.step
        target = min(max(target, c.min_workers), c.max_workers)
        if target != active:
            self._last_resize = now
            self.resizes.append((now, active, target))
        return target
