"""The invariant contracts the repo's fast paths depend on, as data.

Every closed form this reproduction has landed (closed-form drain,
batched GTICK, batched waterfill) is *licensed* by contracts that used
to live only in docstrings and runtime pins:

  * policies draw randomness exclusively from the injected
    ``PolicyContext.rng`` stream (never global numpy/stdlib RNG state);
  * a ``drain_safe=True`` policy mutates observable state only inside
    ``route``/``propose`` (what lets the engine exit the heap once every
    arrival is routed);
  * sim-path code never consults wall clocks or environment ordering —
    one violation silently corrupts the rtol-1e-9 legacy equivalence
    pin;
  * jit-reachable tick code performs no host syncs or Python branches
    on traced values (what keeps the batched GTICK one dispatch).

This module states those contracts as plain data so they have ONE home
shared by the runtime (``from repro.core import contracts``) and the
static analyzer (``tools/lint`` loads this file directly, without
importing the ``repro.core`` package, so linting needs no numpy/jax).
Keep it stdlib-only and side-effect-free.

``tests/test_dyslint.py`` cross-checks :data:`CAPABILITY_FLAGS` against
the live ``RedistributionPolicy`` class attributes, so the two cannot
drift apart silently.
"""

from __future__ import annotations

# --------------------------------------------------------------------- #
# Capability-flag contract (repro.core.policy.RedistributionPolicy)
# --------------------------------------------------------------------- #

#: Every capability flag a registered policy may declare, with its
#: default value on the ``RedistributionPolicy`` base class.  The
#: capability lint pass starts each ``@register_policy`` class from
#: these defaults and applies the class-body overrides it can see.
CAPABILITY_FLAGS = {
    "uses_link": False,
    "never_redistributes": False,
    "drain_safe": True,
    "batched_waterfill": False,
    "pays_decision_overhead": True,
    "stochastic": False,
}

#: The decorator that marks a class as a registered policy (and thus
#: subject to the capability-contract pass).
POLICY_DECORATOR = "register_policy"

#: Methods in which a ``drain_safe=True`` policy may mutate ``self``:
#: construction, plus the two engine entry points that only run while
#: arrivals are still being routed.  Private helpers (``_name``) called
#: exclusively from these methods inherit the permission.  Anything
#: else — ``place_one``, ``wants_spread``, ``paces_spread``, mask
#: pushes — can fire after routing is complete, where a mutation would
#: invalidate the closed-form drain.
MUTATION_SAFE_METHODS = ("__init__", "route", "propose")

#: The injected-randomness attribute: any read of ``ctx.rng`` /
#: ``self.ctx.rng`` requires ``stochastic=True``.
RNG_ATTRIBUTE = "rng"

#: The adaptive-link mask attribute: reads (or a ``set_link_mask``
#: override) require ``uses_link=True`` — the engine only creates and
#: ticks link instances for policies that declare the flag.
LINK_MASK_ATTRIBUTE = "link_mask"


# --------------------------------------------------------------------- #
# Determinism contract (the sim/serving/data bit-identity surface)
# --------------------------------------------------------------------- #

#: Repo-relative directory prefixes in which global-state RNG, wall
#: clocks and environment-order iteration are forbidden.  Virtual time
#: comes from the event heap; randomness comes from seeds threaded
#: through configs (``np.random.default_rng(seed)`` is fine, the module
#: singleton and argless generators are not).
DETERMINISM_SCOPE = (
    "src/repro/sim/",
    "src/repro/core/",
    "src/repro/serving/",
    "src/repro/data/",
    # The fault-injection detection path: `sim/faults.py` is already
    # covered by the sim/ prefix; the runtime-side detector it drives
    # (heartbeats, N-strikes straggler exclusion, elastic remesh) must
    # hold the same bar — same-seed fault runs are pinned bit-for-bit.
    "src/repro/runtime/fault_tolerance.py",
)

#: Modules covered by bit-identity pins (the rtol-1e-9 legacy
#: equivalence pin of ``tests/test_sim_equivalence.py``, the PR 6
#: digest pins of ``tests/test_policy_interface.py``, and the pipeline
#: pins of ``tests/test_pipeline.py``).  The float-order pass flags
#: order-sensitive reductions over unordered containers here: a sum
#: whose operand order depends on set hashing is a different float
#: result on a different run.
PINNED_MODULES = (
    "src/repro/sim/engine.py",
    "src/repro/sim/faults.py",
    "src/repro/sim/legacy.py",
    "src/repro/sim/batched_link.py",
    "src/repro/sim/pipeline.py",
    "src/repro/core/state_machine.py",
    "src/repro/core/skew_models.py",
    "src/repro/core/admission.py",
    "src/repro/core/policy.py",
    "src/repro/core/adaptive_link.py",
    # Acknowledged by the dyflow pin-impact pass (DY602): these are
    # reachable from the pin roots through the interprocedural graph —
    # types.py batch helpers and the fault-tolerance detector feed every
    # pin; replay/workload feed the PR 6 digest pins.
    "src/repro/core/types.py",
    "src/repro/runtime/fault_tolerance.py",
    "src/repro/sim/replay.py",
    "src/repro/sim/workload.py",
)


# --------------------------------------------------------------------- #
# Jit-reachability contract (the tick hot path)
# --------------------------------------------------------------------- #

#: Functions that are jit-reachable through CROSS-module dispatch the
#: per-module AST analysis cannot see (e.g. ``sim/engine.py`` jits
#: ``partial(_tick_impl, cfg=cfg)`` which calls
#: ``state_machine.tick``).  Maps repo-relative path -> {function name
#: -> tuple of parameter names that are static at every jit call site
#: (hashable config objects bound via ``partial`` or
#: ``static_argnames``)}.  The jax-hazard pass seeds its reachability
#: closure from these in addition to what it derives per module.
JIT_REACHABLE = {
    "src/repro/core/state_machine.py": {
        "tick": ("config",),
        "tick_many": ("config",),
        "advance": ("config",),
    },
    "src/repro/core/skew_models.py": {
        "detect_skew": ("config",),
        "update_metrics": (),
        "apply_n_strikes": ("n_strikes",),
        "heavy_row_disable": ("config",),
        "batch_density_heavy_rows": ("config",),
    },
    # train/loop.py jits the closure returned by make_train_step.
    "src/repro/train/step.py": {
        "train_step": (),
    },
}


#: Calls whose results are static (trace-time Python values) even
#: though the per-module analysis cannot prove it: host-side config
#: reads that are constant for the lifetime of a trace.
STATIC_CALLS = (
    "repro.models.perf_flags.get_flags",
)


# --------------------------------------------------------------------- #
# Units/dimension contract (the DY5xx dyflow pass)
# --------------------------------------------------------------------- #

#: The unit vocabulary: name suffix -> (dimension, scale).  A name
#: carrying one of these suffixes (``wall_s``, ``kv_bytes``,
#: ``deficit_rows``) declares the unit of the value it binds; the
#: units pass seeds its dataflow from these, propagates through
#: assignments, arithmetic, calls and returns, and flags cross-DIMENSION
#: mixing (seconds added to bytes) and same-dimension SCALE mixing
#: (``*_gb`` compared to ``*_bytes``) repo-wide.  Scales are relative to
#: the dimension's canonical unit (seconds / bytes / rows / tokens).
UNIT_SUFFIXES = {
    "s": ("seconds", 1.0),
    "secs": ("seconds", 1.0),
    "seconds": ("seconds", 1.0),
    "ms": ("seconds", 1e-3),
    "us": ("seconds", 1e-6),
    "ns": ("seconds", 1e-9),
    "bytes": ("bytes", 1.0),
    "kb": ("bytes", 2.0 ** 10),
    "mb": ("bytes", 2.0 ** 20),
    "gb": ("bytes", 2.0 ** 30),
    "rows": ("rows", 1.0),
    "tokens": ("tokens", 1.0),
}

#: Whole-name override patterns, checked BEFORE the suffix rules
#: (regex, (dimension, scale)).  ``worker_seconds_spent`` is the
#: autoscale economics currency (worker-count x wall seconds — NOT
#: addable to plain latency seconds); ``cost_per_slo`` and ``frac_*`` /
#: ``*_frac`` names are dimensionless ratios despite any embedded unit
#: token (``frac_tokens`` is a fraction OF tokens, not a token count).
UNIT_NAME_PATTERNS = (
    (r"(^|_)worker_seconds(_|$)", ("worker_seconds", 1.0)),
    (r"(^|_)cost_per_slo(_|$)", ("ratio", 1.0)),
    (r"(^|_)frac(tion)?(_|$)", ("ratio", 1.0)),
    (r"(^|_)(jain|ratio|attainment)(_|$)", ("ratio", 1.0)),
)

#: Near-miss suffixes that look like units but are OUTSIDE the
#: vocabulary.  ``tools/check_bench.py`` rejects BENCH row keys carrying
#: one (a ``p99_sec`` column is a mislabeled ``p99_s``), and the units
#: pass treats them as unit-intent it cannot resolve.
UNIT_SUFFIX_NEAR_MISSES = {
    "sec": "s", "msec": "ms", "msecs": "ms", "millis": "ms",
    "usec": "us", "usecs": "us", "nanos": "ns", "byte": "bytes",
    "kib": "kb", "mib": "mb", "gib": "gb", "token": "tokens",
}

#: Repo-relative prefixes the units pass sweeps (the whole production
#: tree plus the benches that mint BENCH records from its numbers).
UNITS_SCOPE = ("src/repro/", "benchmarks/")


# --------------------------------------------------------------------- #
# Pin-impact contract (the DY6xx dyflow pass)
# --------------------------------------------------------------------- #

#: Repo-relative prefix the whole-program call graph covers.
GRAPH_SCOPE = ("src/repro/",)

#: Registry-mediated dispatch: calling one of the FACTORIES yields "some
#: registered policy", so a method call on the result is an edge to that
#: method on the base class and on EVERY ``@register_policy`` subclass.
#: Declared here (not inferred) because the registry's dict lives behind
#: runtime decoration the static graph cannot execute.
POLICY_REGISTRY = {
    "module": "src/repro/core/policy.py",
    "base": "RedistributionPolicy",
    "decorator": POLICY_DECORATOR,
    "factories": ("resolve_policy", "make_policy", "policy_class"),
}

#: The bit-identity pins, as data: pin name -> (test anchor, call-graph
#: roots).  The DY6xx pass computes the reachability closure of each
#: root set over the interprocedural call graph, commits it as
#: ``tools/lint/pin_map.json`` (stale map = lint failure), and checks
#: that every closure module is acknowledged in :data:`PINNED_MODULES` —
#: so "which functions feed which pins" is an artifact CI can diff a PR
#: against, not tribal knowledge.
PINS = {
    "legacy_equivalence_rtol1e9": {
        "test": "tests/test_sim_equivalence.py",
        "roots": (
            "src/repro/sim/engine.py::Simulator.run_query",
            "src/repro/sim/engine.py::MultiQuerySimulator.run",
            "src/repro/sim/legacy.py::LegacySimulator.run_query",
        ),
    },
    "policy_digests": {
        "test": "tests/test_policy_interface.py",
        "roots": (
            "src/repro/sim/engine.py::MultiQuerySimulator.run",
            "src/repro/sim/replay.py::run_open_loop",
        ),
    },
    "pipeline_digests": {
        "test": "tests/test_pipeline.py",
        "roots": (
            "src/repro/sim/pipeline.py::PipelineSimulator.run",
        ),
    },
    "fault_bit_identity": {
        "test": "tests/test_faults.py",
        "roots": (
            "src/repro/sim/engine.py::MultiQuerySimulator.run",
            "src/repro/sim/faults.py::hazard_schedule",
        ),
    },
}

#: Where the committed pin-impact map lives (regenerate with
#: ``python tools/lint/runner.py --write-pin-map``).
PIN_MAP_PATH = "tools/lint/pin_map.json"


# --------------------------------------------------------------------- #
# Lint surface
# --------------------------------------------------------------------- #

#: Default root-relative paths ``make lint`` sweeps.  Tests are
#: deliberately excluded: lint fixtures (including a deliberately
#: misdeclared policy) live under ``tests/lint_fixtures/``.
DEFAULT_LINT_PATHS = ("src", "tools", "benchmarks")
