"""The adaptive-link state machine (paper §III.A, Fig. 2).

Each link instance is an independent state machine; the redistribution policy
selects which transitions are reachable.  The machine is vectorized over
instances (shape (n,)) and expressed with `jnp.where` so it advances inside a
jitted SPMD step; the same code runs on host numpy arrays in the simulator.

Transitions implemented (red default path + policy-gated paths):

  NEVER:            INIT → LOCAL_TERMINAL
  LATE (default):   INIT → DECIDING --N-strikes--> DRAINING → DISTRIBUTING
                    → DISTRIBUTED_TERMINAL            (non-looping commit)
                    DISTRIBUTING --N clean ticks--> DECIDING   (looping only)
  EARLY:            INIT → DISTRIBUTING → DISTRIBUTED_TERMINAL
  EAGER_SNOWPARK:   INIT → DISTRIBUTING (eager; stays adaptive)
                    DISTRIBUTING --heavy-rows & not-skewed--> LOCAL_TERMINAL
                    (the §III.B Row-Size-Model intervention)

The DRAINING state is the paper's 'intermediate state': in the engine it
completes in-flight file boundaries; in our synchronous setting it consumes
exactly one tick, which models the one-batch drain delay and keeps the
trace shape-stable.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import skew_models
from repro.core.types import DySkewConfig, LinkState, Policy


def routes_remote(state: jax.Array) -> jax.Array:
    """Per-instance bool: does this state send rows to remote instances?"""
    return jnp.logical_or(
        state == int(LinkState.DISTRIBUTING),
        state == int(LinkState.DISTRIBUTED_TERMINAL),
    )


def is_terminal(state: jax.Array) -> jax.Array:
    return jnp.logical_or(
        state == int(LinkState.LOCAL_TERMINAL),
        state == int(LinkState.DISTRIBUTED_TERMINAL),
    )


def _advance_never(state: jax.Array) -> jax.Array:
    return jnp.where(
        state == int(LinkState.INIT), int(LinkState.LOCAL_TERMINAL), state
    )


def _advance_late(
    state: jax.Array,
    fire: jax.Array,
    clean_fire: jax.Array,
    looping: bool,
) -> jax.Array:
    s = state
    out = s
    # INIT → DECIDING
    out = jnp.where(s == int(LinkState.INIT), int(LinkState.DECIDING), out)
    # DECIDING → DRAINING on N-strikes fire
    out = jnp.where(
        jnp.logical_and(s == int(LinkState.DECIDING), fire),
        int(LinkState.DRAINING),
        out,
    )
    # DRAINING → DISTRIBUTING (one-tick drain)
    out = jnp.where(s == int(LinkState.DRAINING), int(LinkState.DISTRIBUTING), out)
    if looping:
        # DISTRIBUTING → DECIDING after N consecutive clean ticks
        out = jnp.where(
            jnp.logical_and(s == int(LinkState.DISTRIBUTING), clean_fire),
            int(LinkState.DECIDING),
            out,
        )
    else:
        # Non-looping: commit after one distributing tick.
        out = jnp.where(
            s == int(LinkState.DISTRIBUTING),
            int(LinkState.DISTRIBUTED_TERMINAL),
            out,
        )
    return out


def _advance_early(state: jax.Array) -> jax.Array:
    s = state
    out = jnp.where(s == int(LinkState.INIT), int(LinkState.DISTRIBUTING), s)
    out = jnp.where(
        s == int(LinkState.DISTRIBUTING),
        int(LinkState.DISTRIBUTED_TERMINAL),
        out,
    )
    return out


def _advance_eager_snowpark(state: jax.Array, heavy: jax.Array) -> jax.Array:
    s = state
    out = jnp.where(s == int(LinkState.INIT), int(LinkState.DISTRIBUTING), s)
    # §III.B: not skewed AND batch density collapsed → disable redistribution.
    out = jnp.where(
        jnp.logical_and(s == int(LinkState.DISTRIBUTING), heavy),
        int(LinkState.LOCAL_TERMINAL),
        out,
    )
    return out


def advance(
    link: Dict[str, jax.Array],
    config: DySkewConfig,
) -> Dict[str, jax.Array]:
    """Advance every sibling instance's state machine by one tick.

    ``link`` is the pytree from ``types.link_state_init`` whose ``metrics``
    have already been updated for this tick (see
    ``skew_models.update_metrics``).
    """
    state = link["state"]
    strikes = link["strikes"]
    metrics = link["metrics"]

    skewed_now = skew_models.detect_skew(metrics, config)
    fire, skew_strikes = skew_models.apply_n_strikes(
        skewed_now, strikes, config.n_strikes
    )
    # Strikes only accumulate while the machine is actively DECIDING —
    # INIT is 'before data processing begins' (paper phase 1).
    deciding = state == int(LinkState.DECIDING)
    fire = jnp.logical_and(fire, deciding)
    # Clean-tick counter for looping fallback shares the strike register:
    # while DISTRIBUTING we count *clean* ticks instead of skewed ones.
    distributing = state == int(LinkState.DISTRIBUTING)
    clean_now = jnp.logical_not(skewed_now)
    clean_strikes = jnp.where(clean_now, strikes + 1, 0).astype(strikes.dtype)
    clean_fire = clean_strikes >= config.n_strikes
    new_strikes = jnp.where(
        deciding,
        skew_strikes,
        jnp.where(distributing, clean_strikes, jnp.zeros_like(strikes)),
    )

    heavy = skew_models.heavy_row_disable(metrics, config)

    policy = config.policy
    if policy == Policy.NEVER:
        new_state = _advance_never(state)
    elif policy == Policy.LATE:
        new_state = _advance_late(state, fire, clean_fire, config.looping)
    elif policy == Policy.EARLY:
        new_state = _advance_early(state)
    elif policy == Policy.EAGER_SNOWPARK:
        new_state = _advance_eager_snowpark(state, heavy)
    else:  # pragma: no cover - config validation
        raise ValueError(f"unknown policy {policy!r}")

    became_remote = jnp.logical_and(
        jnp.logical_not(routes_remote(state)), routes_remote(new_state)
    )
    transitions = link["transitions"] + became_remote.astype(jnp.int32)

    return {
        "state": new_state.astype(jnp.int32),
        "strikes": new_strikes,
        "metrics": metrics,
        "transitions": transitions,
        "tick": link["tick"] + 1,
    }


def tick(
    link: Dict[str, jax.Array],
    config: DySkewConfig,
    *,
    rows_this_tick: jax.Array,
    sync_time_this_tick: jax.Array,
    batch_density: jax.Array,
    bytes_per_row: jax.Array,
    signal_this_tick: jax.Array | None = None,
) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Full per-tick update: metrics ingest + state-machine advance.

    Returns (new_link_state, distribute_mask) where ``distribute_mask`` is
    the per-instance bool for 'this producer routes remotely this tick'.
    """
    metrics = skew_models.update_metrics(
        link["metrics"],
        rows_this_tick=rows_this_tick,
        sync_time_this_tick=sync_time_this_tick,
        batch_density=batch_density,
        bytes_per_row=bytes_per_row,
        signal_this_tick=signal_this_tick,
    )
    link = dict(link, metrics=metrics)
    new_link = advance(link, config)
    return new_link, routes_remote(new_link["state"])


def tick_many(
    link: Dict[str, jax.Array],
    config: DySkewConfig,
    *,
    rows_this_tick: jax.Array,
    sync_time_this_tick: jax.Array,
    batch_density: jax.Array,
    bytes_per_row: jax.Array,
    signal_this_tick: jax.Array | None = None,
    active: jax.Array | None = None,
) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """:func:`tick` batched over a leading tenant axis: ONE call advances T
    independent sibling groups (one per concurrent query/tenant).

    ``link`` is the :func:`tick` pytree with every leaf stacked to a
    leading (T, ...) axis — (T, n) vectors, (T, n, W) sync windows, (T,)
    tick counters — and all metric/signal inputs are (T, n).  ``active``
    is an optional (T,) bool: inactive rows (tenants that have not arrived
    yet, or have drained) keep their prior state bit-for-bit and report an
    all-False distribute mask, so callers can pad a fixed-capacity state
    stack and mask the unused slots.

    The per-tenant computation is ``jax.vmap`` of :func:`tick`, which on
    the reductions involved (sibling sums over n, window sums over W) is
    bit-identical per row to the unbatched call — the property the
    simulator's equivalence pin relies on when it routes single-tenant
    runs AND on-grid-arrival multi-link tenant groups through the
    batched path (see `repro.sim.batched_link` and
    `repro.sim.engine._arrivals_on_grid` for the full envelope).
    """
    if signal_this_tick is None:
        signal_this_tick = jnp.zeros_like(rows_this_tick, dtype=bool)

    def one(l, rows, sync, density, bpr, signal):
        return tick(
            l,
            config,
            rows_this_tick=rows,
            sync_time_this_tick=sync,
            batch_density=density,
            bytes_per_row=bpr,
            signal_this_tick=signal,
        )

    new_link, distribute = jax.vmap(one)(
        link, rows_this_tick, sync_time_this_tick, batch_density,
        bytes_per_row, signal_this_tick,
    )
    if active is not None:
        def keep_inactive(new: jax.Array, old: jax.Array) -> jax.Array:
            m = active.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        new_link = jax.tree_util.tree_map(keep_inactive, new_link, link)
        distribute = jnp.logical_and(distribute, active[:, None])
    return new_link, distribute
