"""Pluggable redistribution policies: the strategy seam shared by the
simulator, the serving engine and the data pipeline.

The paper's comparison — legacy static round-robin vs DySkew's adaptive
per-link redistribution — used to live as string ``kind ==`` branches
inside ``repro.sim.engine``; this module extracts the seam so a new
placement policy is a ~100-line plugin instead of an engine patch.

Contract
--------
A :class:`RedistributionPolicy` observes per-link state (per-destination
outstanding backlog, the tenant's opaque per-row cost estimate, the
adaptive link's distribute mask when it consumes one) and proposes
per-destination row counts for each batch.  All randomness comes from the
injected ``PolicyContext.rng`` stream (ab-sim pattern), so stochastic
policies are bit-reproducible run-to-run for a fixed seed, and the
deterministic built-ins consult no RNG at all.  Cost/admission guards do
NOT fork per policy: the generic :meth:`RedistributionPolicy.route` wraps
every proposal with the shared `repro.core.admission.BatchAdmission`
planner (density guard before proposing, cost gate after), the same
guards the serving engine and data pipeline apply.

Engines resolve policies BY NAME through the registry
(:func:`register_policy` / :func:`resolve_policy`); an unresolvable name
raises ``ValueError`` at :class:`StrategyConfig` construction instead of
silently behaving like ``none``.  Capability flags are CLASS attributes —
the simulator's fast paths ask the policy, not a string, whether their
closed forms apply:

  * ``uses_link`` — the policy consumes the adaptive-link state machine:
    the engine creates/ticks link instances (batched-tick groups included)
    and pushes each tick's distribute mask via :meth:`set_link_mask`.
  * ``never_redistributes`` — every row provably stays on its producer,
    which is what licenses the engine's closed-form 'none' fast path.
  * ``drain_safe`` — policy state changes only inside :meth:`route`, so
    once every arrival has been routed nothing the policy could do can
    change the result; this is what licenses the closed-form drain.  A
    policy that mutates observable state on any other trigger must clear
    this flag, and the engine will replay the heap to exhaustion.
  * ``batched_waterfill`` — the proposal is exactly a waterfill over
    :meth:`spread_backlog`, so the engine's coalesced same-instant
    arrival run may plan it through one ``waterfill_counts_many`` call
    (bit-identical to the scalar path by shared repair).
  * ``pays_decision_overhead`` — the engine charges
    ``StrategyConfig.decision_overhead`` per routed batch (the legacy
    static strategies historically paid none).

Registering a new policy::

    @register_policy
    class MyPolicy(RedistributionPolicy):
        name = "mine"
        def propose(self, producer, k, backlog, unit):
            counts = np.zeros(len(backlog), np.int64)
            counts[int(np.argmin(backlog))] = k   # conservation: sum == k
            return counts

    StrategyConfig(kind="mine")            # simulator
    ServeConfig(scheduler="mine")          # serving placement
    DataConfig(placement="mine")           # data-pipeline sharding

Conservation invariant: ``propose`` returns either ``None`` (keep the
whole batch on its producer) or an ``(n,)`` int64 vector of
per-destination row counts summing EXACTLY to ``k`` with zero rows on
non-finite (+inf-masked: decommissioned or self-skip-ineligible)
destinations.  ``tests/test_policy_interface.py`` property-checks every
registered policy against this.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.admission import BatchAdmission
from repro.core.types import DySkewConfig, Policy


# --------------------------------------------------------------------- #
# Waterfill routing math (shared by the sim engine and the policies)
# --------------------------------------------------------------------- #


def _waterfill_repair(
    bl: np.ndarray, counts: np.ndarray, diff: int, finite: np.ndarray,
    unit: float,
) -> np.ndarray:
    """Repair the floor rounding of a closed-form waterfill in place.

    Shared verbatim between the scalar :func:`waterfill_counts` and the
    batched :func:`waterfill_counts_many` (which calls it per row needing
    repair), so the two are bit-identical by construction — including the
    argmax/argsort tie-breaking that a re-implementation would have to
    replicate exactly.
    """
    while diff > 0:
        # Trim one item at a time from the currently most-loaded bin —
        # bulk-trimming a single bin un-levels the fill (hypothesis-found).
        loads = np.where(counts > 0, bl + counts * unit, -np.inf)
        d = int(np.argmax(loads))
        counts[d] -= 1
        diff -= 1
    if diff < 0:
        order = np.argsort(np.where(finite, bl + counts * unit, np.inf))
        ne = int(finite.sum())
        i = 0
        while diff < 0:
            counts[order[i % ne]] += 1
            diff += 1
            i += 1
    return counts


def waterfill_counts(backlog: np.ndarray, k: int, unit: float) -> np.ndarray:
    """Assign ``k`` unit-cost rows to bins so resulting loads are as level
    as possible (vectorized least-backlog greedy for identical costs).

    The continuous water level is solved in closed form (with the j lowest
    backlogs submerged, level_j = (k*unit + sum of those backlogs) / j; the
    true level is the largest j consistent with its own submerged set) and
    the integer counts are floored from it, so no bisection loop is needed;
    the trim/top-up passes of `_waterfill_repair` fix the floor rounding
    exactly.
    """
    n = len(backlog)
    finite = np.isfinite(backlog)
    out = np.zeros(n, np.int64)
    if k == 0:
        return out
    if not finite.any():
        out[0] = k
        return out
    bl = backlog.copy()
    blf = np.sort(bl[finite])
    levels = (k * unit + np.cumsum(blf)) / np.arange(1, len(blf) + 1)
    j = int(np.nonzero(levels >= blf)[0][-1])  # always valid at j=0
    counts = np.floor(np.maximum(levels[j] - bl, 0.0) / unit)
    counts[~finite] = 0
    counts = counts.astype(np.int64)
    diff = int(counts.sum()) - k
    if diff:
        counts = _waterfill_repair(bl, counts, diff, finite, unit)
    return counts


def waterfill_counts_many(
    backlogs: np.ndarray, ks: np.ndarray, units: np.ndarray
) -> np.ndarray:
    """:func:`waterfill_counts` batched over a leading axis: row ``b`` of
    the (B, n) result equals ``waterfill_counts(backlogs[b], ks[b],
    units[b])`` bit-for-bit.

    The closed-form level is solved for every row at once (one (B, n)
    sort + cumsum instead of B scalar calls; rows pad their non-finite
    backlogs with +inf so the sorted prefix — and hence the cumsum prefix
    the level formula reads — matches the scalar compacted sort exactly),
    and the rank-based trim/top-up repair runs only on the rows whose
    floored counts missed ``k`` — through the SAME `_waterfill_repair`
    the scalar path uses, so tie-breaking cannot drift.
    """
    bl = np.asarray(backlogs, np.float64)
    B, n = bl.shape
    ks = np.asarray(ks, np.int64)
    units = np.asarray(units, np.float64)
    finite = np.isfinite(bl)
    ne = finite.sum(axis=1)
    out = np.zeros((B, n), np.int64)
    live = (ks > 0) & (ne > 0)
    # Degenerate rows: k == 0 → all zeros; no finite bin → everything on
    # bin 0 (same as the scalar fallback).
    none_finite = (ks > 0) & (ne == 0)
    out[none_finite, 0] = ks[none_finite]
    if not live.any():
        return out
    padded = np.where(finite, bl, np.inf)
    blf = np.sort(padded, axis=1)
    with np.errstate(invalid="ignore"):
        levels = (
            ks[:, None] * units[:, None] + np.cumsum(blf, axis=1)
        ) / np.arange(1, n + 1)
        cond = (levels >= blf) & (np.arange(n) < ne[:, None])
    j = n - 1 - np.argmax(cond[:, ::-1], axis=1)  # last True per row
    level = levels[np.arange(B), j]
    with np.errstate(invalid="ignore"):
        counts = np.floor(
            np.maximum(level[:, None] - bl, 0.0) / units[:, None]
        )
    counts[~finite] = 0.0
    counts[~live] = 0.0
    counts = counts.astype(np.int64)
    diffs = counts.sum(axis=1) - np.where(live, ks, 0)
    for b in np.flatnonzero(diffs):
        counts[b] = _waterfill_repair(
            bl[b], counts[b], int(diffs[b]), finite[b], float(units[b])
        )
    out[live] = counts[live]
    return out


# --------------------------------------------------------------------- #
# Policy context and registry
# --------------------------------------------------------------------- #


def _no_mask() -> Optional[np.ndarray]:
    return None


def _default_est() -> float:
    return 1e-3


def _no_outstanding() -> Sequence[float]:
    raise RuntimeError(
        "PolicyContext.outstanding was not supplied — only the simulator "
        "seam (RedistributionPolicy.route) reads it; standalone contexts "
        "(serving placement, data sharding, property tests) use propose/"
        "place_one/assign, which take the backlog explicitly"
    )


def _no_idle(_p: int) -> float:
    return 0.0


@dataclasses.dataclass
class PolicyContext:
    """What a policy may observe, supplied by the host engine.

    The live views are zero-arg callables because the engine locals they
    read (cost estimate, outstanding backlog, autoscale masks) are
    reassigned during a run — a policy must always see the current value.
    ``rng`` is the injected randomness stream: the host derives it from
    its own seed (the simulator spawns one child stream per tenant), so
    a stochastic policy is reproducible for a fixed seed without ever
    touching global numpy state.
    """

    num_workers: int
    # Seeded default: a context built without an explicit stream must
    # still be reproducible run-to-run (an argless default_rng() here
    # once handed every standalone context — serving placement tests,
    # ad-hoc policy probes — a fresh OS-entropy stream).
    rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0)
    )
    node_of: Callable[[int], int] = staticmethod(lambda w: 0)
    network_bandwidth: float = 1.25e9
    per_row_serialize: float = 2e-6
    # Live engine views (see class docstring).
    est_row_cost: Callable[[], float] = staticmethod(_default_est)
    outstanding: Callable[[], Sequence[float]] = staticmethod(
        _no_outstanding
    )
    idle_sibling_frac: Callable[[int], float] = staticmethod(_no_idle)
    #: Boolean (n,) mask of ROUTABLE workers (commissioned ∧ live ∧ not
    #: draining/excluded), or None when the whole pool is eligible.
    #: Under faults the engine composes worker liveness into this view,
    #: so every mask-aware policy is fault-aware for free.
    active_mask: Callable[[], Optional[np.ndarray]] = staticmethod(_no_mask)
    #: Int ids of routable workers (None = whole pool eligible).
    active_ids: Callable[[], Optional[np.ndarray]] = staticmethod(_no_mask)
    #: Fault layer: boolean (n,) liveness-only mask (True = the worker is
    #: up and accepting rows, independent of autoscale commissioning), or
    #: None when no fault schedule is active.  Most policies should use
    #: ``active_mask``, which already folds this in; ``live_mask`` lets a
    #: policy distinguish "decommissioned" from "dead/draining".
    live_mask: Callable[[], Optional[np.ndarray]] = staticmethod(_no_mask)


_REGISTRY: Dict[str, Type["RedistributionPolicy"]] = {}


def register_policy(
    cls: Type["RedistributionPolicy"],
) -> Type["RedistributionPolicy"]:
    """Class decorator: register ``cls`` under its ``name`` attribute."""
    name = cls.name
    if not name:
        raise ValueError(f"{cls.__name__} has no `name` to register under")
    if name in _REGISTRY:
        raise ValueError(
            f"redistribution policy {name!r} is already registered "
            f"({_REGISTRY[name].__name__})"
        )
    _REGISTRY[name] = cls
    return cls


def resolve_policy(name: str) -> Type["RedistributionPolicy"]:
    """Look a policy class up by registry name; unknown names raise
    ``ValueError`` (the silent-fallthrough bug this registry replaces:
    an unmatched ``kind`` string used to behave like ``none``)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown redistribution policy {name!r}; registered: "
            f"{sorted(_REGISTRY)}"
        ) from None


def available_policies() -> List[str]:
    """Sorted names of every registered policy (the tournament roster)."""
    return sorted(_REGISTRY)


# --------------------------------------------------------------------- #
# Strategy configuration (resolves a policy through the registry)
# --------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    kind: str = "dyskew"              # any registered policy name
    dyskew: DySkewConfig = dataclasses.field(
        default_factory=lambda: DySkewConfig(policy=Policy.EAGER_SNOWPARK)
    )
    # Metrics-subsystem cadence: state machines tick every `tick_interval`
    # seconds of virtual time.
    tick_interval: float = 50e-3
    # Adaptive-decision CPU overhead charged per routed batch (metrics
    # sampling + state machine + waterfill in the VW worker thread). The
    # legacy static strategy pays none.
    decision_overhead: float = 200e-6
    # EMA horizon for the opaque per-row cost estimate.
    cost_ema: float = 0.2
    # Disable the per-batch admission guards (ablations).
    enable_density_guard: bool = True
    enable_cost_gate: bool = True
    # Free-form per-policy tuning knobs as (name, value) pairs (a tuple
    # keeps the config hashable); read via `param`.
    params: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        # Fail at CONSTRUCTION, not deep inside a run: an unknown kind
        # used to fall through every engine branch and silently behave
        # like 'none'.
        resolve_policy(self.kind)

    def param(self, name: str, default: float) -> float:
        for k, v in self.params:
            if k == name:
                return v
        return default

    def admission(self) -> BatchAdmission:
        """The shared `repro.core` admission planner for this strategy."""
        return BatchAdmission(
            self.dyskew,
            enable_density_guard=self.enable_density_guard,
            enable_cost_gate=self.enable_cost_gate,
        )

    def policy_class(self) -> Type["RedistributionPolicy"]:
        return resolve_policy(self.kind)

    def make_policy(self, ctx: PolicyContext) -> "RedistributionPolicy":
        """One policy instance per (tenant, run) — policies are stateful
        (round-robin counters, tuning state, eligibility caches)."""
        return resolve_policy(self.kind)(self, ctx)


# --------------------------------------------------------------------- #
# The policy interface
# --------------------------------------------------------------------- #


class RedistributionPolicy:
    """Base class: a per-tenant, per-run placement policy.

    Subclasses usually implement only :meth:`propose` (pure placement
    math) and inherit the guarded :meth:`route` seam, the single-row
    :meth:`place_one` placement (serving) and the whole-batch
    :meth:`assign` sharding (data pipeline).  See the module docstring
    for the capability flags and the conservation contract.
    """

    #: Registry name (class attribute; set by subclasses).
    name: ClassVar[str] = ""
    #: Consumes the adaptive-link state machine (tick cadence + mask).
    uses_link: ClassVar[bool] = False
    #: Provably keeps every row on its producer (closed-form 'none' hook).
    never_redistributes: ClassVar[bool] = False
    #: State changes only inside `route` (closed-form drain hook).
    drain_safe: ClassVar[bool] = True
    #: Proposal is a pure waterfill over `spread_backlog` (coalesced-run
    #: batched planning hook).
    batched_waterfill: ClassVar[bool] = False
    #: Engine charges `StrategyConfig.decision_overhead` per routed batch.
    pays_decision_overhead: ClassVar[bool] = True
    #: Consults the injected RNG stream.
    stochastic: ClassVar[bool] = False

    def __init__(self, strategy: StrategyConfig, ctx: PolicyContext):
        self.strategy = strategy
        self.ctx = ctx
        # The shared per-batch admission planner (density guard / cost
        # gate / self-skip eligibility) — guards do not fork per policy.
        self.admission = strategy.admission()
        self.link_mask: List[bool] = [False] * ctx.num_workers
        self._elig: Dict[int, np.ndarray] = {}

    # -- pure placement math (reused by serving, data, and tests) ------ #

    def propose(
        self, producer: int, k: int, backlog: np.ndarray, unit: float
    ) -> Optional[np.ndarray]:
        """Per-destination row counts for ``k`` rows from ``producer``.

        ``backlog`` is the observed per-destination load in seconds with
        ineligible destinations masked to +inf; ``unit`` is the estimated
        seconds per row.  Returns ``None`` (keep the batch local) or an
        (n,) int64 counts vector summing exactly to ``k`` with zero on
        non-finite destinations.
        """
        return None

    def place_one(self, backlog: np.ndarray, producer: int = -1) -> int:
        """Destination of a single fresh row/request (the serving
        engine's eager placement).  Default: least loaded."""
        bl = np.asarray(backlog, np.float64)
        return int(np.argmin(np.where(np.isfinite(bl), bl, np.inf)))

    def assign(
        self, costs: np.ndarray, producers: np.ndarray, n: int
    ) -> np.ndarray:
        """Destination per item for a whole batch with known per-item
        costs (the data pipeline's sharding step).  Default: sequential
        `place_one` against the running backlog."""
        costs = np.asarray(costs, np.float64)
        producers = np.asarray(producers, np.int64)
        backlog = np.zeros(n, np.float64)
        out = np.empty(len(costs), np.int64)
        for i in range(len(costs)):
            d = self.place_one(backlog, producer=int(producers[i]))
            out[i] = d
            backlog[d] += costs[i]
        return out

    # -- shared guard pipeline (the BatchAdmission planner) ------------ #

    def density_blocks(self, producer: int, batch) -> bool:
        """Row Size Model admission guard (§III.B): low batch density +
        no skew benefit visible → keep the heavy rows local."""
        bpr = batch.total_bytes / max(batch.num_rows, 1)
        return self.admission.density_guard_blocks(
            batch.num_rows, bpr,
            lambda: self.ctx.idle_sibling_frac(producer),
        )

    def admits(self, producer: int, batch, dests: np.ndarray) -> bool:
        """Cost gate (§I goal 3): refuse when estimated movement time
        exceeds estimated straggler savings."""
        if not self.strategy.enable_cost_gate:
            return True
        moving = dests != producer
        dec = self.admission.admit_move(
            float(batch.sizes[moving].sum()), int(moving.sum()),
            self.ctx.est_row_cost(), self.ctx.num_workers,
            self.ctx.network_bandwidth, self.ctx.per_row_serialize,
        )
        return dec.admit

    def eligible(self, producer: int) -> np.ndarray:
        """Self-skip eligibility mask for ``producer`` (cached — the
        mask depends only on topology)."""
        m = self._elig.get(producer)
        if m is None:
            m = self.admission.eligible_destinations(
                self.ctx.num_workers, producer, self.ctx.node_of
            )
            self._elig[producer] = m
        return m

    def spread_backlog(self, producer: int, out_vec) -> np.ndarray:
        """The waterfill input: outstanding rows × estimated row cost,
        with decommissioned (autoscale) and self-skip-ineligible
        destinations masked to +inf.  ``out_vec`` is the live
        outstanding list (scalar path) or a planner's shadow copy."""
        bl = np.asarray(out_vec) * self.ctx.est_row_cost()
        act = self.ctx.active_mask()
        if act is not None:
            # Decommissioned workers are ineligible destinations.
            bl = np.where(act, bl, np.inf)
        lv = self.ctx.live_mask()
        if lv is not None:
            # Dead/draining/excluded workers are ineligible too.  The
            # simulator folds liveness into active_mask already; this
            # guards hosts that supply the two views independently.
            bl = np.where(lv, bl, np.inf)
        if self.strategy.dyskew.self_skip:
            # Forced-remote ablation (§III.B): the producer must bypass
            # its own node's interpreters entirely (Fig. 1 —
            # redistribution targets interpreters on *other* VW nodes),
            # leaving local CPU idle.
            bl = np.where(self.eligible(producer), bl, np.inf)
        return bl

    def spread_unit(self) -> float:
        return max(self.ctx.est_row_cost(), 1e-9)

    # -- the simulator seam -------------------------------------------- #

    def wants_spread(self, producer: int, batch) -> bool:
        """Cheap pre-proposal check: False keeps the batch local without
        computing a plan.  The coalesced-run planner consults this too."""
        return not self.density_blocks(producer, batch)

    def route(
        self, producer: int, batch, now: float
    ) -> Optional[np.ndarray]:
        """Per-ROW destinations for one batch, or None to keep it local.

        The generic guard pipeline around :meth:`propose` — density
        guard, proposal over the masked backlog, cost gate — shared by
        every policy that does not override it (the legacy static_rr
        pays no guards and overrides).
        """
        if not self.wants_spread(producer, batch):
            return None
        counts = self.propose(
            producer, batch.num_rows,
            self.spread_backlog(producer, self.ctx.outstanding()),
            self.spread_unit(),
        )
        if counts is None:
            return None
        dests = np.repeat(np.arange(self.ctx.num_workers), counts)
        if len(dests) != batch.num_rows:
            raise ValueError(
                f"policy {self.name!r} broke conservation: proposed "
                f"{len(dests)} rows for a {batch.num_rows}-row batch"
            )
        if not self.admits(producer, batch, dests):
            return None
        return dests

    def paces_spread(self, producer: int) -> bool:
        """Flow-control hook: True → the producer paces against the
        least-backlogged eligible destination (it can spread), False →
        against its own worker's backlog (it routes locally)."""
        return not self.never_redistributes

    def set_link_mask(self, mask: List[bool]) -> None:
        """Engine push of the adaptive link's distribute mask after each
        metrics tick (only called when ``uses_link``)."""
        self.link_mask = mask


# --------------------------------------------------------------------- #
# Built-in policies (the three ported engine strategies)
# --------------------------------------------------------------------- #


@register_policy
class NonePolicy(RedistributionPolicy):
    """Default 1:1 link — no redistribution, ever.  Rows stay on their
    producer; a fresh row with NO producer (serving placement) goes to
    the least-loaded worker (the eager free path — placing is not
    redistributing)."""

    name = "none"
    never_redistributes = True
    pays_decision_overhead = False

    def route(self, producer, batch, now):
        return None

    def paces_spread(self, producer):
        return False

    def assign(self, costs, producers, n):
        return np.asarray(producers, np.int64).copy()


@register_policy
class StaticRRPolicy(RedistributionPolicy):
    """The legacy Snowpark solution (paper §II.B, Fig. 1): per-row
    round-robin across all interpreters from the start — oblivious to
    backlog, density and cost, and paying no guards and no decision
    overhead (it makes no decision)."""

    name = "static_rr"
    pays_decision_overhead = False

    def __init__(self, strategy, ctx):
        super().__init__(strategy, ctx)
        self._rr = 0

    def route(self, producer, batch, now):
        # Bit-exact port of the engine's static_rr branch: cyclic per-ROW
        # destinations (row i → slot (rr+i) mod n), cycling only the
        # commissioned workers under autoscale.  Guards don't apply.
        k = batch.num_rows
        ids = self.ctx.active_ids()
        if ids is None:
            dests = (self._rr + np.arange(k)) % self.ctx.num_workers
        else:
            dests = ids[(self._rr + np.arange(k)) % len(ids)]
        self._rr += k
        return dests

    def propose(self, producer, k, backlog, unit):
        # Counts form of the same cycle over the eligible destinations
        # (serving/data reuse; the simulator takes `route`).
        ids = np.flatnonzero(np.isfinite(np.asarray(backlog, np.float64)))
        if not len(ids):
            return None
        counts = np.bincount(
            ids[(self._rr + np.arange(k)) % len(ids)],
            minlength=len(backlog),
        ).astype(np.int64)
        self._rr += k
        return counts

    def place_one(self, backlog, producer=-1):
        ids = np.flatnonzero(np.isfinite(np.asarray(backlog, np.float64)))
        d = int(ids[self._rr % len(ids)])
        # dyslint: disable=DY202 -- place_one is the serving/data seam; the sim's closed-form drain never calls it
        self._rr += 1
        return d


@register_policy
class DySkewPolicy(RedistributionPolicy):
    """The paper's adaptive link: redistribute only when the per-link
    state machine's distribute mask says the producer is skewed, by
    waterfilling the batch over observed backlog, behind the density
    guard and cost gate."""

    name = "dyskew"
    uses_link = True
    batched_waterfill = True

    def wants_spread(self, producer, batch):
        return self.link_mask[producer] and not self.density_blocks(
            producer, batch
        )

    def paces_spread(self, producer):
        # Flow control follows the link: while the mask says the producer
        # routes locally, it paces against its own worker's backlog.
        return self.link_mask[producer]

    def propose(self, producer, k, backlog, unit):
        return waterfill_counts(backlog, k, unit)


# --------------------------------------------------------------------- #
# New policies (landed through the seam, ≲150 LoC each)
# --------------------------------------------------------------------- #


@register_policy
class PowerOfTwoPolicy(RedistributionPolicy):
    """Power-of-two-choices sampling: probe two uniformly random eligible
    destinations per batch and send the whole batch to the less loaded —
    the classic O(1)-state load balancer (exponential improvement over
    one random choice).  All randomness comes from the injected
    `PolicyContext.rng`, so a fixed seed reproduces the trajectory."""

    name = "p2c"
    stochastic = True

    def propose(self, producer, k, backlog, unit):
        bl = np.asarray(backlog, np.float64)
        ids = np.flatnonzero(np.isfinite(bl))
        if not len(ids):
            return None
        if len(ids) == 1:
            d = int(ids[0])
        else:
            a, b = self.ctx.rng.choice(len(ids), size=2, replace=False)
            # Lower backlog wins; tie → the first sample.
            d = int(ids[a] if bl[ids[a]] <= bl[ids[b]] else ids[b])
        counts = np.zeros(len(bl), np.int64)
        counts[d] = k
        return counts

    def place_one(self, backlog, producer=-1):
        counts = self.propose(producer, 1, backlog, 1.0)
        return int(np.argmax(counts))


@register_policy
class KeyAffinityPolicy(RedistributionPolicy):
    """Key-affinity / locality-aware placement: keep as many rows as the
    balanced water level (plus a slack allowance) permits on their
    producer, and spill only the excess — preferring same-node
    destinations by penalizing remote backlogs.  Minimizes rows moved
    off their producer subject to staying near-level.

    Knobs (via ``StrategyConfig.params``): ``affinity_slack`` — extra
    local rows allowed as a fraction of the batch (default 0.25);
    ``affinity_remote_penalty`` — row-equivalents added to off-node
    backlogs when spilling (default 8)."""

    name = "key_affinity"

    def propose(self, producer, k, backlog, unit):
        bl = np.asarray(backlog, np.float64)
        n = len(bl)
        finite = np.isfinite(bl)
        if not finite.any():
            return None
        counts = np.zeros(n, np.int64)
        spill = k
        if 0 <= producer < n and finite[producer]:
            level_counts = waterfill_counts(bl, k, unit)
            slack = int(self.strategy.param("affinity_slack", 0.25) * k)
            keep = min(k, int(level_counts[producer]) + slack)
            counts[producer] = keep
            spill = k - keep
        if spill:
            pen = self.strategy.param("affinity_remote_penalty", 8.0) * unit
            node = self.ctx.node_of
            home = node(producer) if 0 <= producer < n else -1
            off_node = np.asarray(
                [node(w) != home for w in range(n)], bool
            )
            spilled = waterfill_counts(
                np.where(off_node, bl + pen, bl), spill, unit
            )
            counts += spilled
        return counts

    def place_one(self, backlog, producer=-1):
        bl = np.asarray(backlog, np.float64)
        finite = np.isfinite(bl)
        if 0 <= producer < len(bl) and finite[producer]:
            fin = bl[finite]
            # Affinity: stay home unless the producer is clearly above
            # the mean load.
            if bl[producer] <= float(fin.mean()) + float(fin.std()):
                return producer
        return super().place_one(bl, producer)


@register_policy
class HillClimbPolicy(RedistributionPolicy):
    """Online hill-climbing: one scalar knob — the spread fraction θ of
    each batch that leaves the producer (the rest stays local) — tuned
    from per-link state.  Every ``hc_adjust_every`` routed batches the
    policy compares the smoothed backlog imbalance (max − mean, in row
    units) against the previous window and keeps walking θ in the same
    direction if the imbalance improved, else reverses — classic
    hill-climbing on a live objective.  Deterministic: the observations
    come from the routing trajectory, not an RNG.

    Knobs (via ``StrategyConfig.params``): ``hc_theta0`` (initial spread
    fraction, default 0.5), ``hc_step`` (θ step, default 0.15),
    ``hc_adjust_every`` (batches per adjustment, default 8)."""

    name = "hillclimb"

    def __init__(self, strategy, ctx):
        super().__init__(strategy, ctx)
        self.theta = float(strategy.param("hc_theta0", 0.5))
        self._step = float(strategy.param("hc_step", 0.15))
        self._every = max(int(strategy.param("hc_adjust_every", 8)), 1)
        self._dir = 1.0
        self._ema = 0.0
        self._prev = float("inf")
        self._routes = 0

    def _observe(self, bl_finite: np.ndarray, unit: float) -> None:
        imb = float(bl_finite.max() - bl_finite.mean()) / max(unit, 1e-9)
        self._ema = 0.8 * self._ema + 0.2 * imb
        self._routes += 1
        if self._routes % self._every == 0:
            if self._ema > self._prev:
                self._dir = -self._dir    # got worse → reverse course
            self.theta = float(np.clip(
                self.theta + self._dir * self._step, 0.0, 1.0
            ))
            self._prev = self._ema

    def propose(self, producer, k, backlog, unit):
        bl = np.asarray(backlog, np.float64)
        finite = np.isfinite(bl)
        if not finite.any():
            return None
        self._observe(bl[finite], unit)
        counts = np.zeros(len(bl), np.int64)
        keep = 0
        if 0 <= producer < len(bl) and finite[producer]:
            keep = k - int(round(self.theta * k))
            counts[producer] = keep
        spill = k - keep
        if spill:
            counts += waterfill_counts(bl, spill, unit)
        return counts
