"""DySkew core: the paper's primary contribution as composable JAX modules.

Public API:
  types.DySkewConfig / Policy / LinkState / SkewModelKind
  skew_models — Eq.(1) row-percentage, idle-time, Eq.(2) sync-slope,
                N-strikes, batch-density Row Size Model
  state_machine — per-link-instance adaptive state machine (Fig. 2);
                `tick` advances one query's sibling group, `tick_many`
                vmaps it over a stacked (T, n) tenant axis with
                inactive-row masking (the batched simulator tick)
  redistribution — round_robin (legacy baseline), lpt_greedy, zigzag
  admission — shared admission planning: per-batch guards (density
              guard, cost gate, self-skip eligibility), the in-graph
              redistribution gate (CostModelConfig /
              admit_redistribution — polymorphic over numpy and jax, one
              formula set for the host planners and the jitted step),
              and the weighted fair-share multi-tenant layer
  adaptive_link.AdaptiveLink — the assembled adaptive data link
  policy — the pluggable redistribution-policy seam: the
           `RedistributionPolicy` interface and name registry that
           `StrategyConfig` (simulator), `ServeConfig.scheduler`
           (serving) and `DataConfig.placement` (data pipeline) all
           resolve placement through
"""

from repro.core.adaptive_link import AdaptiveLink, AdaptiveLinkConfig
from repro.core.admission import (
    AdmissionDecision,
    BatchAdmission,
    CostModelConfig,
    FairShareAdmission,
    FairShareConfig,
)
from repro.core.policy import (
    PolicyContext,
    RedistributionPolicy,
    StrategyConfig,
    available_policies,
    register_policy,
    resolve_policy,
)
from repro.core.types import (
    DySkewConfig,
    LinkState,
    Policy,
    RoutingPlan,
    SkewModelKind,
    link_state_init,
)

__all__ = [
    "AdaptiveLink",
    "AdaptiveLinkConfig",
    "AdmissionDecision",
    "BatchAdmission",
    "CostModelConfig",
    "DySkewConfig",
    "FairShareAdmission",
    "FairShareConfig",
    "LinkState",
    "Policy",
    "PolicyContext",
    "RedistributionPolicy",
    "RoutingPlan",
    "SkewModelKind",
    "StrategyConfig",
    "available_policies",
    "link_state_init",
    "register_policy",
    "resolve_policy",
]
