"""Skew-detection models (paper §III.A) + the Row Size Model (§III.B).

All models consume the sibling-observable metrics pytree produced by
``repro.core.types.link_metrics_zeros`` and return a per-instance boolean
``skewed`` vector.  Everything is pure jnp so the models run identically

  * inside a jitted SPMD step (metrics all_gather'd across shards), and
  * in the discrete-event simulator (metrics as host numpy arrays).

The N-strikes framework wraps any model: skew must be detected N consecutive
ticks before a redistribution transition is allowed, which suppresses
transient fluctuations (paper: 'reduces sensitivity to transient
fluctuations and avoids false positives').
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import DySkewConfig, SkewModelKind


def _mean_of_others(x: jax.Array) -> jax.Array:
    """mean(x_{-i}) for every i, shape-preserving.

    With n==1 there are no siblings; returns +inf so no instance ever
    reports skew against an empty sibling set.
    """
    n = x.shape[0]
    if n <= 1:
        return jnp.full_like(x, jnp.inf)
    total = jnp.sum(x)
    return (total - x) / (n - 1)


def row_percentage_skew(metrics: Dict[str, jax.Array], theta: float) -> jax.Array:
    """Eq. (1):  R_i · θ > mean(R_{-i}).

    θ ∈ (0, 1]; smaller θ demands a larger imbalance before firing
    (θ = 0.5 fires when an instance holds >2× the sibling-average rows).
    """
    rows = metrics["rows"]
    return rows * theta > _mean_of_others(rows)


def idle_time_skew(
    metrics: Dict[str, jax.Array],
    idle_grace: float,
    idle_sibling_frac: float,
) -> jax.Array:
    """Idle-time model: instance i is skewed if it is busy while a
    threshold fraction of its siblings sit idle.

    'An instance is considered idle if it has not received a row or signal
    for a configurable period. If the number of idle siblings exceeds a
    threshold, the current instance is considered skewed.'
    Directly measures resource under-utilization — the model the paper calls
    most effective for UDF-like variable per-row costs.
    """
    idle = metrics["idle_ticks"] >= idle_grace            # (n,)
    n = idle.shape[0]
    if n <= 1:
        return jnp.zeros((n,), bool)
    idle_f = idle.astype(jnp.float32)
    total_idle = jnp.sum(idle_f)
    idle_siblings = total_idle - idle_f                   # excludes self
    threshold = idle_sibling_frac * (n - 1)
    busy = jnp.logical_not(idle)
    return jnp.logical_and(busy, idle_siblings >= threshold)


def sync_slope(window: jax.Array) -> jax.Array:
    """Least-squares slope of each instance's sync-time window.

    window: (n, W) cumulative-sync-time samples, newest last. Returns (n,).
    """
    w = window.shape[-1]
    t = jnp.arange(w, dtype=jnp.float32)
    t = t - jnp.mean(t)
    denom = jnp.sum(t * t)
    centered = window - jnp.mean(window, axis=-1, keepdims=True)
    return jnp.sum(centered * t, axis=-1) / jnp.maximum(denom, 1e-9)


def sync_time_slope_skew(metrics: Dict[str, jax.Array], theta: float) -> jax.Array:
    """Eq. (2):  dS_i/dt · θ ≥ mean(dS_{-i}/dt).

    Compares the *rate of change* of synchronous time across siblings over a
    sliding window — accelerating imbalance, not absolute imbalance.
    """
    slopes = sync_slope(metrics["sync_window"])
    others = _mean_of_others(slopes)
    # Guard: a flat window (all slopes ~0) must not fire.
    active = slopes > 1e-9
    return jnp.logical_and(slopes * theta >= others, active)


def detect_skew(metrics: Dict[str, jax.Array], config: DySkewConfig) -> jax.Array:
    """Dispatch to the configured model. Returns (n,) bool."""
    kind = config.skew_model
    if kind == SkewModelKind.ROW_PERCENTAGE:
        return row_percentage_skew(metrics, config.theta)
    if kind == SkewModelKind.IDLE_TIME:
        return idle_time_skew(metrics, config.idle_grace, config.idle_sibling_frac)
    if kind == SkewModelKind.SYNC_TIME_SLOPE:
        return sync_time_slope_skew(metrics, config.theta)
    raise ValueError(f"unknown skew model {kind!r}")


def apply_n_strikes(
    skewed_now: jax.Array, strikes: jax.Array, n_strikes: int
) -> Tuple[jax.Array, jax.Array]:
    """N-strikes hysteresis.

    Returns (fire, new_strikes): ``fire`` is True once an instance has
    accumulated N *consecutive* detections; a single clean tick resets the
    counter.
    """
    new_strikes = jnp.where(skewed_now, strikes + 1, 0).astype(strikes.dtype)
    fire = new_strikes >= n_strikes
    return fire, new_strikes


def batch_density_heavy_rows(
    metrics: Dict[str, jax.Array], config: DySkewConfig
) -> jax.Array:
    """Row Size Model (§III.B): heavy-row detection via batch density.

    'While Snowflake typically targets thousands of rows per batch, this
    density drops by over 99 % when processing large objects.'
    Returns per-instance bool: batches are pathologically sparse AND rows
    are actually large → redistribution overhead likely exceeds the
    benefit.  The row-size conjunct keeps ordinary small remainder batches
    from tripping the guard.
    """
    density = metrics["batch_density"]
    observed = density > 0.0  # density 0 = no batch seen yet; not evidence
    sparse = jnp.logical_and(observed, density < config.min_batch_density)
    large_rows = metrics["bytes_per_row"] >= config.heavy_row_bytes
    return jnp.logical_and(sparse, large_rows)


def heavy_row_disable(
    metrics: Dict[str, jax.Array], config: DySkewConfig
) -> jax.Array:
    """The §III.B intervention: if NOT skewed (idle-time model) AND batch
    density below threshold → the state machine should transition to the
    local terminal state, disabling redistribution for this link.
    """
    skewed = idle_time_skew(metrics, config.idle_grace, config.idle_sibling_frac)
    heavy = batch_density_heavy_rows(metrics, config)
    return jnp.logical_and(jnp.logical_not(skewed), heavy)


def update_metrics(
    metrics: Dict[str, jax.Array],
    rows_this_tick: jax.Array,
    sync_time_this_tick: jax.Array,
    batch_density: jax.Array,
    bytes_per_row: jax.Array,
    signal_this_tick: jax.Array | None = None,
) -> Dict[str, jax.Array]:
    """Advance the sibling-observable metrics by one tick.

    All arguments are (n,) vectors for the n sibling instances.
    ``signal_this_tick`` marks instances that are active without receiving
    rows (the paper counts an instance idle only if it got *no row or
    signal*); a worker mid-row is busy, not idle.
    """
    rows = metrics["rows"] + rows_this_tick
    received = rows_this_tick > 0
    if signal_this_tick is not None:
        received = jnp.logical_or(received, signal_this_tick)
    idle_ticks = jnp.where(received, 0.0, metrics["idle_ticks"] + 1.0)
    # Slide the sync window; store *cumulative* sync time so the slope model
    # sees rates of change (Eq. 2 uses dS/dt of cumulative S).
    prev_cum = metrics["sync_window"][:, -1]
    new_cum = prev_cum + sync_time_this_tick
    sync_window = jnp.concatenate(
        [metrics["sync_window"][:, 1:], new_cum[:, None]], axis=-1
    )
    return {
        "rows": rows,
        "idle_ticks": idle_ticks,
        "sync_window": sync_window,
        "batch_density": batch_density.astype(jnp.float32),
        "bytes_per_row": bytes_per_row.astype(jnp.float32),
    }
