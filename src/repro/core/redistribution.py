"""Routing planners: how redistributed rows are assigned to destinations.

The paper's *previous* solution — static round-robin across all Python
interpreter processes (§II.B, Fig. 1) — is kept as the legacy baseline.
DySkew routes by observed load instead.  Three planners:

  round_robin   — the legacy static strategy (baseline in every benchmark).
  lpt_greedy    — Longest-Processing-Time greedy: sort items by estimated
                  cost descending, assign each to the least-loaded
                  destination (exact greedy, `lax.scan`-sequential).
  zigzag        — vectorized near-LPT: sort descending, snake the sorted
                  items across destinations (no scan; O(n log n), the
                  in-graph default for large item counts).

All planners accept a per-destination eligibility mask so the same code
expresses the paper's self-skip ablation (§III.B 'Forced Remote
Distribution') and locality restrictions.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

_NEG = -1e30


def round_robin(
    num_items: int,
    num_instances: int,
    offset: jax.Array | int = 0,
    eligible: Optional[jax.Array] = None,
) -> jax.Array:
    """Legacy static assignment: item k → (offset + k) mod n over eligible
    destinations. With a full eligibility mask this is exactly Fig. 1."""
    idx = jnp.arange(num_items, dtype=jnp.int32) + jnp.asarray(offset, jnp.int32)
    if eligible is None:
        return idx % num_instances
    # Map the cyclic index into the compacted eligible set.
    elig_ids = jnp.nonzero(
        eligible, size=num_instances, fill_value=num_instances - 1
    )[0].astype(jnp.int32)
    n_elig = jnp.maximum(jnp.sum(eligible.astype(jnp.int32)), 1)
    return elig_ids[idx % n_elig]


def lpt_greedy(
    costs: jax.Array,
    num_instances: int,
    base_loads: Optional[jax.Array] = None,
    eligible: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Exact LPT greedy. Returns (dest, final_loads).

    Sequential in the number of items (lax.scan); use for moderate item
    counts (requests, batches) — tokens should use :func:`zigzag`.
    """
    n = num_instances
    loads = (
        jnp.zeros((n,), jnp.float32) if base_loads is None else base_loads.astype(jnp.float32)
    )
    mask = (
        jnp.zeros((n,), jnp.float32)
        if eligible is None
        else jnp.where(eligible, 0.0, -_NEG).astype(jnp.float32)  # +1e30 for ineligible
    )
    order = jnp.argsort(-costs)
    sorted_costs = costs[order].astype(jnp.float32)

    def step(carry, c):
        loads = carry
        d = jnp.argmin(loads + mask).astype(jnp.int32)
        loads = loads.at[d].add(c)
        return loads, d

    final_loads, dests_sorted = jax.lax.scan(step, loads, sorted_costs)
    dest = jnp.zeros_like(dests_sorted).at[order].set(dests_sorted)
    return dest, final_loads


def zigzag(
    costs: jax.Array,
    num_instances: int,
    base_loads: Optional[jax.Array] = None,
    eligible: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Vectorized near-LPT ('boustrophedon') assignment.

    Sort items by cost descending; walk destinations 0..n-1, n-1..0, ... so
    each pass pairs a heavy item with the destination that got a light one
    on the previous pass.  Ineligible destinations are excised by mapping
    the snake over the compacted eligible set.  Destination ranks are
    rotated by the rank of each destination's base load so pre-loaded
    instances receive the lighter items first.
    """
    n = num_instances
    num_items = costs.shape[0]
    order = jnp.argsort(-costs)

    if eligible is None:
        elig_ids = jnp.arange(n, dtype=jnp.int32)
        n_elig = n
        n_elig_arr = jnp.asarray(n, jnp.int32)
    else:
        elig_ids = jnp.nonzero(eligible, size=n, fill_value=0)[0].astype(jnp.int32)
        n_elig_arr = jnp.maximum(jnp.sum(eligible.astype(jnp.int32)), 1)
        n_elig = None  # dynamic

    k = jnp.arange(num_items, dtype=jnp.int32)
    ne = n_elig_arr if n_elig is None else jnp.asarray(n_elig, jnp.int32)
    pass_idx = k // ne
    pos = k % ne
    snaked = jnp.where(pass_idx % 2 == 0, pos, ne - 1 - pos)

    if base_loads is not None:
        # Least-loaded eligible destination should receive the heaviest item.
        loads_e = base_loads[elig_ids]
        if eligible is not None:
            loads_e = jnp.where(jnp.arange(n) < ne, loads_e, jnp.inf)
        rank = jnp.argsort(jnp.argsort(loads_e))  # rank of each slot by load
        inv = jnp.argsort(rank)
        snaked = inv[snaked]

    dest_sorted = elig_ids[snaked]
    dest = jnp.zeros_like(dest_sorted).at[order].set(dest_sorted)

    loads0 = (
        jnp.zeros((n,), jnp.float32) if base_loads is None else base_loads.astype(jnp.float32)
    )
    final_loads = loads0.at[dest].add(costs.astype(jnp.float32))
    return dest, final_loads


def eligibility_mask(
    num_instances: int,
    self_id: jax.Array | int,
    self_skip: bool,
) -> jax.Array:
    """Destination eligibility for a given producer.

    ``self_skip=True`` reproduces the generalized framework's forced-remote
    behavior; ``False`` is the paper's Snowpark optimization (local worker is
    a valid destination → no self-exclusion bias)."""
    mask = jnp.ones((num_instances,), bool)
    if self_skip:
        mask = mask.at[jnp.asarray(self_id, jnp.int32)].set(False)
    return mask


def local_assignment(num_items: int, self_id: jax.Array | int) -> jax.Array:
    """The default 1:1 producer→consumer link: everything stays local."""
    return jnp.full((num_items,), jnp.asarray(self_id, jnp.int32))


def makespan(dest: jax.Array, costs: jax.Array, num_instances: int) -> jax.Array:
    """Max per-destination load — the quantity skew mitigation minimizes."""
    loads = jnp.zeros((num_instances,), jnp.float32).at[dest].add(
        costs.astype(jnp.float32)
    )
    return jnp.max(loads)
