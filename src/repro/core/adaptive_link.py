"""AdaptiveLink — the paper's adaptive data link, as a reusable primitive.

Ties together the per-instance state machines (`state_machine`), the skew
models (`skew_models`), the routing planners (`redistribution`) and the
in-graph cost gate (`admission.admit_redistribution`) for the generic
setting:

    n producer instances each hold a set of work items; each item has an
    estimated cost (seconds of downstream compute) and a size (bytes to
    move it).  Once per tick the link decides, per instance, whether that
    instance keeps its items local or redistributes them, and — if so —
    where each item goes.

This host-level orchestration is used directly by the data pipeline
(items = packed sequences) and the serving scheduler (items = requests).
The MoE layer re-uses the state machine and planners in a fully in-graph
SPMD form (see `repro.models.layers.moe`).

Everything here is functionally pure and shape-static, so it can be jitted;
it also runs fine on host numpy inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import admission, redistribution, state_machine
from repro.core.types import DySkewConfig, RoutingPlan, link_state_init


@dataclasses.dataclass(frozen=True)
class AdaptiveLinkConfig:
    dyskew: DySkewConfig = dataclasses.field(default_factory=DySkewConfig)
    cost: admission.CostModelConfig = dataclasses.field(
        default_factory=admission.CostModelConfig
    )
    # Estimated per-item compute used for batch-density normalization when a
    # producer holds zero items this tick.
    num_instances: int = 8


class AdaptiveLink:
    """Functional adaptive link over ``num_instances`` sibling instances."""

    def __init__(self, config: AdaptiveLinkConfig):
        self.config = config
        self.n = config.num_instances

    def init_state(self) -> Dict[str, jax.Array]:
        return link_state_init(self.n, self.config.dyskew)

    # ------------------------------------------------------------------ #

    def _per_producer_metrics(
        self,
        item_costs: jax.Array,
        item_sizes: jax.Array,
        item_producer: jax.Array,
        item_valid: jax.Array,
    ) -> Dict[str, jax.Array]:
        n = self.n
        w = item_valid.astype(jnp.float32)
        rows = jnp.zeros((n,), jnp.float32).at[item_producer].add(w)
        sync = jnp.zeros((n,), jnp.float32).at[item_producer].add(
            w * item_costs.astype(jnp.float32)
        )
        byts = jnp.zeros((n,), jnp.float32).at[item_producer].add(
            w * item_sizes.astype(jnp.float32)
        )
        # One tick == one ingest batch per producer → density = rows/batch.
        density = rows
        bytes_per_row = jnp.where(rows > 0, byts / jnp.maximum(rows, 1.0), 0.0)
        return dict(
            rows=rows, sync=sync, density=density, bytes_per_row=bytes_per_row
        )

    def step(
        self,
        link: Dict[str, jax.Array],
        item_costs: jax.Array,
        item_sizes: jax.Array,
        item_producer: jax.Array,
        item_valid: jax.Array | None = None,
    ) -> Tuple[Dict[str, jax.Array], RoutingPlan]:
        """One link tick.

        Args:
          link: carried state from :meth:`init_state`.
          item_costs: (num_items,) estimated downstream compute seconds.
          item_sizes: (num_items,) bytes to move each item.
          item_producer: (num_items,) int32 owning instance per item.
          item_valid: (num_items,) bool; padding slots are False.

        Returns (new_link_state, RoutingPlan).
        """
        cfg = self.config.dyskew
        n = self.n
        num_items = item_costs.shape[0]
        if item_valid is None:
            item_valid = jnp.ones((num_items,), bool)

        per = self._per_producer_metrics(
            item_costs, item_sizes, item_producer, item_valid
        )

        link, distribute = state_machine.tick(
            link,
            cfg,
            rows_this_tick=per["rows"],
            sync_time_this_tick=per["sync"],
            batch_density=per["density"],
            bytes_per_row=per["bytes_per_row"],
        )

        # ---- Routing plan -------------------------------------------- #
        item_distributes = jnp.logical_and(distribute[item_producer], item_valid)
        plan_costs = jnp.where(item_distributes, item_costs, 0.0)

        # Base load: cost that is pinned to its producer (non-moving items).
        pinned = jnp.logical_and(item_valid, jnp.logical_not(item_distributes))
        base_loads = jnp.zeros((n,), jnp.float32).at[item_producer].add(
            jnp.where(pinned, item_costs, 0.0).astype(jnp.float32)
        )

        dest_moved, loads_after = redistribution.zigzag(
            plan_costs, n, base_loads=base_loads
        )
        if cfg.self_skip:
            # Forced-remote ablation: an item may not land on its producer.
            collide = dest_moved == item_producer
            dest_moved = jnp.where(
                collide, (dest_moved + 1) % n, dest_moved
            ).astype(jnp.int32)

        dest = jnp.where(item_distributes, dest_moved, item_producer).astype(
            jnp.int32
        )

        # ---- Cost gate ------------------------------------------------ #
        loads_before = jnp.zeros((n,), jnp.float32).at[item_producer].add(
            jnp.where(item_valid, item_costs, 0.0).astype(jnp.float32)
        )
        moved = jnp.logical_and(dest != item_producer, item_valid)
        bytes_moved = jnp.sum(jnp.where(moved, item_sizes, 0.0))
        items_moved = jnp.sum(moved.astype(jnp.int32))
        loads_planned = jnp.zeros((n,), jnp.float32).at[dest].add(
            jnp.where(item_valid, item_costs, 0.0).astype(jnp.float32)
        )
        ok, saved, t_move = admission.admit_redistribution(
            loads_before, loads_planned, bytes_moved, items_moved,
            self.config.cost,
        )
        dest = jnp.where(ok, dest, item_producer).astype(jnp.int32)

        plan = RoutingPlan(
            dest=dest,
            distribute=jnp.logical_and(distribute, ok),
            est_bytes_moved=jnp.where(ok, bytes_moved, 0.0),
            est_time_saved=jnp.where(ok, saved, 0.0),
        )
        return link, plan


def apply_plan_host(items: jax.Array, plan: RoutingPlan, num_instances: int):
    """Host-side helper: bucket items by destination (python lists).

    For the simulator and data pipeline; the SPMD path moves data with
    all_to_all instead.
    """
    import numpy as np

    dest = np.asarray(plan.dest)
    return [
        [items[i] for i in np.nonzero(dest == d)[0]] for d in range(num_instances)
    ]
