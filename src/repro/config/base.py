"""Config system: architecture definitions, input-shape suites, registry.

Every assigned architecture is a frozen ``ArchConfig`` registered under its
public id (``--arch <id>``).  Reduced variants (for CPU smoke tests) come
from ``ArchConfig.reduced()``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                 # d_ff per expert
    capacity_factor: float = 1.25
    # Which layers carry the MoE ffn ('all', 'every_other' — Jamba style).
    layout: str = "all"
    # DySkew adaptive dispatch on by default (the paper's technique).
    adaptive: bool = True


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # Positional / attention flavors.
    rope_style: str = "full"       # full | half (chatglm 2d) | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu | relu2
    # Optional sub-configs.
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    # hybrid: attention every `attn_period` layers (Jamba 1:7 → period 8,
    # attention at layer index `attn_offset` within each period).
    attn_period: int = 1
    attn_offset: int = 0
    # encoder-decoder (whisper): encoder layer count + fixed source length.
    encoder_layers: int = 0
    encoder_len: int = 0
    # vlm (pixtral): number of stub patch-embedding positions.
    num_patches: int = 0
    # KV cache storage dtype: 'model' (= activation dtype) or 'int8'
    # (symmetric per-(position, head) quantization — halves cache bytes;
    # required for qwen1.5-32b's 40-head MHA cache at decode_32k).
    kv_cache_dtype: str = "model"
    # Training defaults.
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    dtype: str = "bfloat16"
    # Sub-quadratic? (controls whether long_500k is lowered)
    sub_quadratic: bool = False

    # -- derived ------------------------------------------------------- #
    @property
    def head_dim_(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, 128)

    def is_attention_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_period == 1:
            return True
        return i % self.attn_period == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        if self.moe is None:
            return False
        if self.moe.layout == "all":
            return True
        if self.moe.layout == "every_other":
            return i % 2 == 1
        raise ValueError(self.moe.layout)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim_
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        for i in range(L):
            if self.is_attention_layer(i) and n_q > 0:
                total += d * hd * (n_q + 2 * n_kv) + n_q * hd * d
            elif self.mamba is not None:
                mc = self.mamba
                di = mc.d_inner(d)
                nh = mc.num_heads(d)
                g = max(nh // 8, 1)
                total += d * (2 * di + 2 * g * mc.d_state + nh) + di * d
            if self.is_moe_layer(i):
                total += self.moe.num_experts * 3 * d * self.moe.expert_ff
            elif f > 0:
                mats = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                total += mats * d * f
        if self.encoder_layers:
            for _ in range(self.encoder_layers):
                total += d * hd * (n_q + 2 * n_kv) + n_q * hd * d + 2 * d * f
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        for i in range(self.num_layers):
            if self.is_moe_layer(i):
                total -= (self.moe.num_experts - self.moe.top_k) * 3 * d * self.moe.expert_ff
        return total

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        kw: Dict = {}
        kw["num_layers"] = min(self.num_layers, 4 if self.attn_period > 1 else 2)
        if self.attn_period > 1:
            kw["num_layers"] = min(self.num_layers, self.attn_period)
            kw["attn_period"] = max(self.attn_period // 2, 2)
            kw["attn_offset"] = min(self.attn_offset, kw["attn_period"] - 1)
        d = 64
        kw["d_model"] = d
        kw["num_heads"] = 4 if self.num_heads else 0
        kw["num_kv_heads"] = (
            max(1, min(self.num_kv_heads, 2)) if self.num_heads else 0
        )
        kw["head_dim"] = 16 if self.num_heads else None
        kw["d_ff"] = 128 if self.d_ff else 0
        kw["vocab_size"] = 256
        if self.moe is not None:
            ne = min(self.moe.num_experts, 8)
            tk = min(self.moe.top_k, 2)
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=ne, top_k=tk, expert_ff=64,
                # Dropless for smoke tests: capacity covers the worst case,
                # so decode logits match the full forward exactly.
                capacity_factor=float(ne) / tk,
            )
        if self.mamba is not None:
            kw["mamba"] = dataclasses.replace(
                self.mamba, d_state=16, head_dim=16, chunk=32,
            )
        if self.encoder_layers:
            kw["encoder_layers"] = 2
            kw["encoder_len"] = 16
        if self.num_patches:
            kw["num_patches"] = 4
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


#: The assigned input-shape suite (identical across LM-family archs).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

#: Registry of assigned architecture ids → config module names.
ARCH_MODULES = {
    "whisper-base": "whisper_base",
    "granite-20b": "granite_20b",
    "chatglm3-6b": "chatglm3_6b",
    "starcoder2-3b": "starcoder2_3b",
    "qwen1.5-32b": "qwen1_5_32b",
    "pixtral-12b": "pixtral_12b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "kimi-k2-1t-a32b": "kimi_k2",
    "mamba2-1.3b": "mamba2_1_3b",
}

_CACHE: Dict[str, ArchConfig] = {}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _CACHE:
        if arch_id not in ARCH_MODULES:
            raise KeyError(
                f"unknown arch {arch_id!r}; known: {sorted(ARCH_MODULES)}"
            )
        mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[arch_id]}")
        _CACHE[arch_id] = mod.CONFIG
    return _CACHE[arch_id]


def all_arch_ids() -> Tuple[str, ...]:
    return tuple(ARCH_MODULES)


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch × shape) dry-run cell applies (see DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP: pure full-attention arch at 500k (sub-quadratic required)"
    return True, ""
