from repro.config.base import (
    SHAPES,
    ArchConfig,
    MambaConfig,
    MoEConfig,
    ShapeConfig,
    all_arch_ids,
    cell_is_runnable,
    get_config,
)

__all__ = [
    "SHAPES", "ArchConfig", "MambaConfig", "MoEConfig", "ShapeConfig",
    "all_arch_ids", "cell_is_runnable", "get_config",
]
