import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the production mesh, resolves shardings
from the logical-axis rules, lowers the appropriate step function with
ShapeDtypeStruct inputs (no allocation), compiles it, and records
memory_analysis / cost_analysis / the collective schedule for the roofline
table (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config.base import SHAPES, ArchConfig, all_arch_ids, cell_is_runnable, get_config
from repro.launch.mesh import dp_axes, dp_size, make_production_mesh, model_size
from repro.models.layers.moe import SpmdCtx
from repro.models.model_api import build
from repro.models.perf_flags import PerfFlags, use_flags
from repro.models.param import (
    default_rules,
    tree_abstract,
    tree_pspecs,
    tree_shardings,
)
from repro.optim.optimizers import OptimizerConfig
from repro.optim.specs import opt_state_specs
from repro.roofline import hw
from repro.roofline.analysis import analyze, model_flops_estimate
from repro.roofline.jaxpr_cost import trace_cost
from repro.train.step import make_decode_step, make_prefill_step, make_train_step


def param_dtype(cfg: ArchConfig, kind: str = "train"):
    # adamw archs keep fp32 master params for training; adafactor archs
    # store bf16. Serving always uses the inference dtype.
    if kind != "train":
        return jnp.dtype(cfg.dtype)
    return jnp.float32 if cfg.optimizer == "adamw" else jnp.bfloat16


def make_rules(cfg: ArchConfig, multi_pod: bool,
               fsdp_only: bool = False) -> Dict:
    rules = default_rules(multi_pod)
    rules["batch"] = ("pod", "data") if multi_pod else ("data",)
    rules["kv_seq"] = "model"
    if getattr(make_rules, "_h10", False):
        rules["expert_embed"] = None
    if fsdp_only:
        # H6: ZeRO-3-style sharding — every weight fully sharded on its
        # d_model dim over (data × model); no tensor parallelism, so no
        # per-layer activation psums. Vocab/experts keep the model axis
        # (the logits head and EP still want it).
        fs = ("pod", "data", "model") if multi_pod else ("data", "model")
        rules["embed"] = fs
        for ax in ("heads", "kv_heads", "mlp", "ssm_heads"):
            rules[ax] = None
    return rules


def replicated_like(tree: Any, mesh) -> Tuple[Any, Any]:
    """(abstract tree, replicated shardings) for small concrete-state trees."""
    ab = jax.eval_shape(lambda: tree)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), ab)
    return ab, sh


def spmd_ctx(cfg: ArchConfig, mesh, multi_pod: bool,
             tokens_per_call: int, batch: int) -> SpmdCtx:
    groups = dp_size(mesh)
    if tokens_per_call % groups != 0:
        groups = 1   # e.g. long_500k decode: batch=1 token per step
    n_ep = model_size(mesh)
    if cfg.moe is not None and cfg.moe.num_experts % n_ep != 0:
        n_ep = 1
    axes = ("pod", "data") if multi_pod else ("data",)
    if batch % dp_size(mesh) != 0:
        axes = ()
    return SpmdCtx(num_groups=groups, num_ep_shards=n_ep,
                   batch_axes=axes, model_axis="model")


def build_cell(arch_id: str, shape_name: str, multi_pod: bool,
               fsdp_only: bool = False):
    """Returns (fn, args_abstract, in_shardings, out_shardings, meta)."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, multi_pod, fsdp_only=fsdp_only)
    model = build(cfg)
    tokens_per_call = (
        shape.global_batch * shape.seq_len
        if shape.kind in ("train", "prefill") else shape.global_batch
    )
    ctx = spmd_ctx(cfg, mesh, multi_pod, tokens_per_call,
                   shape.global_batch)
    dp = rules["batch"]
    pdt = param_dtype(cfg, shape.kind)

    pspecs = model.specs()
    params_ab = tree_abstract(pspecs, dtype_override=pdt)
    params_sh = tree_shardings(pspecs, mesh, rules)

    B, S = shape.global_batch, shape.seq_len
    # Batch sharding falls back to replication when B is not divisible by
    # the dp extent (long_500k has global_batch=1).
    dp_total = dp_size(mesh)
    batch_spec = dp if B % dp_total == 0 else None
    tok_sh = NamedSharding(mesh, P(batch_spec, None))

    def extra_inputs() -> Tuple[Dict, Dict]:
        ab, sh = {}, {}
        if cfg.family == "encdec":
            ab["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            sh["frames"] = NamedSharding(mesh, P(batch_spec, None, None))
        if cfg.family == "vlm":
            ab["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            sh["patches"] = NamedSharding(mesh, P(batch_spec, None, None))
        return ab, sh

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(name=cfg.optimizer)
        fn = make_train_step(
            model, opt_cfg, ctx=ctx,
            param_pspecs=tree_pspecs(pspecs, mesh, rules),
        )
        opt_specs = opt_state_specs(opt_cfg, pspecs)
        state_ab: Dict[str, Any] = {
            "params": params_ab,
            "opt": tree_abstract(opt_specs),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sh: Dict[str, Any] = {
            "params": params_sh,
            "opt": tree_shardings(opt_specs, mesh, rules),
            "step": NamedSharding(mesh, P()),
        }
        dk = model.dyskew_init(ctx)
        if dk is not None:
            dk_ab, dk_sh = replicated_like(dk, mesh)
            state_ab["dyskew"] = dk_ab
            state_sh["dyskew"] = dk_sh
        xab, xsh = extra_inputs()
        batch_ab = dict(
            tokens=jax.ShapeDtypeStruct((B, S), jnp.int32),
            targets=jax.ShapeDtypeStruct((B, S), jnp.int32),
            **xab,
        )
        batch_sh = dict(tokens=tok_sh, targets=tok_sh, **xsh)
        args_ab = (state_ab, batch_ab)
        in_sh = (state_sh, batch_sh)
        out_sh = (state_sh, None)
        tokens = B * S
        mf = model_flops_estimate(cfg.active_param_count(), tokens, "train")

    elif shape.kind == "prefill":
        fn = make_prefill_step(build(cfg), ctx=ctx)
        dstate_specs = build(cfg).decode_state_specs(B, S)
        state_ab = tree_abstract(dstate_specs)
        state_sh = tree_shardings(dstate_specs, mesh, rules)
        xab, xsh = extra_inputs()
        inputs_ab = dict(tokens=jax.ShapeDtypeStruct((B, S), jnp.int32), **xab)
        inputs_sh = dict(tokens=tok_sh, **xsh)
        args_ab = (params_ab, state_ab, inputs_ab)
        in_sh = (params_sh, state_sh, inputs_sh)
        out_sh = (None, state_sh)
        mf = model_flops_estimate(cfg.active_param_count(), B * S, "prefill")

    else:  # decode
        fn = make_decode_step(build(cfg), ctx=ctx)
        dstate_specs = build(cfg).decode_state_specs(B, S)
        state_ab = tree_abstract(dstate_specs)
        state_sh = tree_shardings(dstate_specs, mesh, rules)
        token_ab = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        args_ab = (params_ab, state_ab, token_ab)
        in_sh = (params_sh, state_sh, tok_sh)
        out_sh = (None, state_sh)
        mf = model_flops_estimate(cfg.active_param_count(), B, "decode")

    meta = dict(
        arch=arch_id, shape=shape_name,
        mesh="multi" if multi_pod else "single",
        chips=hw.CHIPS_MULTI_POD if multi_pod else hw.CHIPS_SINGLE_POD,
        kind=shape.kind, model_flops=mf,
        params=model.num_params(),
        active_params=cfg.active_param_count(),
    )
    return fn, args_ab, in_sh, out_sh, mesh, meta


FLAG_MAP = {
    "h1": "causal_skip",
    "h2": "cast_before_gather",
    "h3": "constrain_kv",
    "h5": "constrain_activations",
    "h8": "constrain_grads",
    "h9": "moe_scatter_combine",
    "h11": "constrain_mamba_acts",
    # h7 = disable XLA excess precision (bf16 collectives stay bf16) —
    # handled via compiler options, not PerfFlags.
}


def parse_flags(spec_str: str):
    kw = {}
    h7 = False
    h6 = False
    for tok in spec_str.split(","):
        tok = tok.strip().lower()
        if not tok:
            continue
        if tok == "h7":
            h7 = True
            continue
        if tok == "h6":
            h6 = True
            continue
        if tok == "h10":
            make_rules._h10 = True
            continue
        kw[FLAG_MAP.get(tok, tok)] = True
    return PerfFlags(**kw), h7, h6


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = None, verbose: bool = True,
             flags: PerfFlags = PerfFlags(), tag: str = "",
             no_excess_precision: bool = False,
             fsdp_only: bool = False) -> Dict:
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict[str, Any] = dict(arch=arch_id, shape=shape_name, mesh=mesh_name)
    if not ok:
        rec["status"] = why
        if verbose:
            print(f"[dryrun] {arch_id} × {shape_name} × {mesh_name}: {why}")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json"
            ), "w") as f:
                json.dump(rec, f, indent=1)
        return rec

    t0 = time.time()
    try:
        if (flags.constrain_kv or flags.causal_skip) and flags.kv_pspec is None:
            dp = ("pod", "data") if multi_pod else ("data",)
            bspec = dp if SHAPES[shape_name].global_batch % (
                32 if multi_pod else 16) == 0 else None
            flags = dataclasses.replace(
                flags, kv_pspec=P(bspec, "model", None, None)
            )
        if flags.constrain_mamba_acts and flags.act_pspec is None:
            dp = ("pod", "data") if multi_pod else ("data",)
            bspec = dp if SHAPES[shape_name].global_batch % (
                32 if multi_pod else 16) == 0 else None
            flags = dataclasses.replace(
                flags, act_pspec=P(bspec, None, None)
            )
        with use_flags(flags):
            fn, args_ab, in_sh, out_sh, mesh, meta = build_cell(
                arch_id, shape_name, multi_pod, fsdp_only=fsdp_only
            )
            donate = (0,) if meta["kind"] == "train" else (1,)
            with jax.set_mesh(mesh):
                lowered = jax.jit(
                    fn, in_shardings=in_sh, out_shardings=out_sh,
                    donate_argnums=donate,
                ).lower(*args_ab)
                t_lower = time.time() - t0
                copts = (
                    {"xla_allow_excess_precision": False}
                    if no_excess_precision else None
                )
                compiled = lowered.compile(compiler_options=copts)
                t_compile = time.time() - t0 - t_lower
                jc = trace_cost(fn, *args_ab)

            mem = compiled.memory_analysis()
        terms = analyze(compiled, meta["chips"], meta["model_flops"],
                        jaxpr_cost=jc)
        rec.update(meta)
        rec.update(
            status="OK",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
                output_bytes=getattr(mem, "output_size_in_bytes", 0),
                # Liveness-aware working-set peak (temp_size is the SUM of
                # all temp allocations, not a peak — misleading).
                peak_bytes=getattr(mem, "peak_memory_in_bytes", 0),
                temp_bytes_sum=getattr(mem, "temp_size_in_bytes", 0),
                generated_code_bytes=getattr(
                    mem, "generated_code_size_in_bytes", 0
                ),
            ),
            roofline=terms.as_dict(),
        )
        # XLA's buffer-assignment peak includes live arguments; donated
        # outputs alias their inputs — peak IS the per-device residency.
        per_dev = rec["memory"]["peak_bytes"]
        rec["memory"]["per_device_total_gb"] = round(per_dev / 1024**3, 3)
        rec["memory"]["fits_hbm"] = bool(per_dev <= hw.HBM_BYTES)
        if verbose:
            r = rec["roofline"]
            print(
                f"[dryrun] {arch_id} × {shape_name} × {mesh_name}: OK "
                f"compile={rec['compile_s']}s "
                f"mem/dev={rec['memory']['per_device_total_gb']}GB "
                f"tc={r['t_compute_s']:.4f} tm={r['t_memory_s']:.4f} "
                f"tcoll={r['t_collective_s']:.4f} → {r['bottleneck']}"
            )
    except Exception as e:  # record failures — they are bugs to fix
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        if verbose:
            print(f"[dryrun] {arch_id} × {shape_name} × {mesh_name}: "
                  f"{rec['status']}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="")
    ap.add_argument("--shape", type=str, default="")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--flags", type=str, default="",
                    help="comma list: h1,h2,h3,h5 (perf hillclimb knobs)")
    ap.add_argument("--tag", type=str, default="")
    args = ap.parse_args()
    flags, h7, h6 = parse_flags(args.flags)

    archs = all_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (
        [False, True] if args.mesh == "both"
        else [args.mesh == "multi"]
    )
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir=args.out,
                               flags=flags, tag=args.tag,
                               no_excess_precision=h7, fsdp_only=h6)
                if str(rec.get("status", "")).startswith("FAIL"):
                    failures += 1
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
