"""Serving launcher CLI: continuous batching with the DySkew scheduler.

  python -m repro.launch.serve --arch starcoder2-3b --reduced --requests 64
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.serving.engine import Request, ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--scheduler", default="dyskew",
                    choices=["dyskew", "round_robin", "least_loaded"])
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt_len=int(rng.integers(64, 512)),
            max_new_tokens=int(rng.integers(300, 400)) if i % 7 == 0
            else int(rng.integers(20, 60)),
            arrival=float(i) * 0.02,
        )
        for i in range(args.requests)
    ]
    cfg = ServeConfig(num_replicas=args.replicas, scheduler=args.scheduler)
    res = ServingEngine(cfg).run(reqs)
    for k, v in res.items():
        print(f"{k}: {v:.4f}" if isinstance(v, float) else f"{k}: {v}")


if __name__ == "__main__":
    main()
