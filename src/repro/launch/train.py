"""Training launcher CLI.

  python -m repro.launch.train --arch granite-moe-1b-a400m --reduced \
      --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt

On this CPU container use --reduced (same-family small config); on a pod
the full config trains with the production mesh shardings.
"""

from __future__ import annotations

import argparse

from repro.config.base import get_config
from repro.data.pipeline import DataConfig
from repro.optim.optimizers import OptimizerConfig
from repro.train.loop import LoopConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", type=str, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})

    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
    )
    opt_cfg = OptimizerConfig(
        name=cfg.optimizer, lr=args.lr,
        warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
    )
    loop_cfg = LoopConfig(
        steps=args.steps, log_every=args.log_every,
        checkpoint_dir=args.ckpt,
    )

    def log(step, m):
        print(f"step {step:5d}  loss={m['loss']:.4f} "
              f"gnorm={m.get('grad_norm', 0):.3f} lr={m.get('lr', 0):.2e} "
              + (f"moe_drop={m['moe_dropped_frac']:.3f} " if 'moe_dropped_frac' in m else "")
              + f"wall={m['wall_s']}s")

    out = train(cfg, data_cfg, opt_cfg, loop_cfg, on_metrics=log)
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
