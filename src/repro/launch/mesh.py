"""Production mesh definitions.

Single pod: (data=16, model=16) = 256 chips (one TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
outer data-parallel axis crossing the DCN (gradient reduction over 'pod'
is the compression target; see repro.optim.grad_compress).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def dp_size(mesh) -> int:
    n = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            n *= mesh.shape[ax]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)
