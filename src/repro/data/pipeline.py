"""Data pipeline: synthetic token sources, sequence packing, and
DySkew-balanced sharding across data-parallel workers.

Variable-length documents make per-shard compute skewed (cost grows with
packed-sequence attention length²); the pipeline routes packed sequences
to DP shards through the generic ``AdaptiveLink`` — the batch-level
instantiation of the paper's technique (DESIGN.md §3.5).  A background
prefetch thread overlaps host batch assembly with device compute.

Multi-tenant mixing: with ``DataConfig.tenant_weights`` set, each tenant
gets its own deterministic document stream and the pipeline interleaves
them by classic deficit round robin (`FairShareAdmission.pick_next` from
`repro.core.admission`, the same planner the simulator and serving engine
use), with document token counts as the DRR cost — so over time each
tenant's share of emitted tokens converges to its weight.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import (
    AdaptiveLink,
    AdaptiveLinkConfig,
    BatchAdmission,
    DySkewConfig,
    Policy,
)
from repro.core.admission import FairShareAdmission, FairShareConfig
from repro.core.policy import PolicyContext, StrategyConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    # Lengths ~ clipped lognormal; heavier tail = more packing skew.
    doc_len_mean: float = 600.0
    doc_len_sigma: float = 1.0
    seed: int = 0
    pack: bool = True
    dyskew_balance: bool = True
    num_shards: int = 1
    prefetch: int = 2
    # Shard-placement policy: any name registered in `repro.core.policy`
    # (unknown names raise ValueError at pipeline construction).  The
    # default 'dyskew' keeps the AdaptiveLink balancing path; any other
    # policy assigns sequences through its `assign` placement over the
    # quadratic per-sequence cost model instead.
    placement: str = "dyskew"
    # Weighted fair-share mixing across tenant document streams (None =
    # single-tenant).  Tenant i's share of emitted tokens converges to
    # tenant_weights[i] / sum(tenant_weights).
    tenant_weights: Optional[Tuple[float, ...]] = None


class _TenantDoc(np.ndarray):
    """ndarray view carrying its owning tenant index (``tenant`` attr).

    Lets the packer credit `DataPipeline.tenant_tokens` when a document
    is actually placed — crediting at draw time over-counted whenever a
    document fit no sequence and was carried (previously: dropped)."""

    tenant: int


class SyntheticDocs:
    """Deterministic document stream (id, tokens)."""

    def __init__(self, cfg: DataConfig, seed_offset: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed + seed_offset)

    def __iter__(self) -> Iterator[np.ndarray]:
        import math

        mu = math.log(self.cfg.doc_len_mean) - 0.5 * self.cfg.doc_len_sigma**2
        while True:
            n = int(np.clip(
                self.rng.lognormal(mu, self.cfg.doc_len_sigma),
                16, self.cfg.seq_len,
            ))
            yield self.rng.integers(
                1, self.cfg.vocab_size, size=n, dtype=np.int32
            )


def pack_documents(
    docs: Iterator[np.ndarray],
    seq_len: int,
    count: int,
    carry: Optional[List[np.ndarray]] = None,
    on_pack: Optional[Callable[[np.ndarray], None]] = None,
) -> List[np.ndarray]:
    """Greedy first-fit packing of documents into `count` sequences.

    ``carry`` (when given) is the cross-batch leftover buffer: documents
    in it are offered FIRST, and a drawn document that fits no open
    sequence is appended to it for the next batch instead of being
    silently dropped (the drop both lost data and broke the tenant token
    accounting — the mixer had already credited the tokens).
    ``on_pack`` fires once per document actually placed, which is where
    per-tenant token accounting now lives."""
    seqs: List[List[np.ndarray]] = [[] for _ in range(count)]
    fill = np.zeros(count, np.int64)
    offer: List[np.ndarray] = list(carry) if carry else []
    if carry is not None:
        carry.clear()
    oi = 0
    for i in range(count * 4 + len(offer)):  # bounded attempts
        if fill.min() >= seq_len:
            break
        if oi < len(offer):
            doc = offer[oi]
            oi += 1
        else:
            try:
                doc = next(docs)
            except StopIteration:
                # Finite stream exhausted (pipeline streams are infinite;
                # direct callers may not be): pack what we have.
                break
        # first shard with room
        order = np.argsort(fill)
        for s in order:
            if fill[s] + len(doc) <= seq_len:
                seqs[s].append(doc)
                fill[s] += len(doc)
                if on_pack is not None:
                    on_pack(doc)
                break
        else:
            # Fits nowhere this batch: keep it for the next one — unless
            # it can never fit ANY sequence (len > seq_len), which would
            # carry it forever; such a doc is structurally unpackable
            # and is discarded uncounted (the pipeline's own streams
            # clip to seq_len, so this only guards direct callers).
            if carry is not None and len(doc) <= seq_len:
                carry.append(doc)
    if carry is not None:
        carry.extend(offer[oi:])
    out = []
    for s in range(count):
        toks = (np.concatenate(seqs[s]) if seqs[s]
                else np.zeros(0, np.int32))[:seq_len]
        pad = np.zeros(seq_len - len(toks), np.int32)
        out.append(np.concatenate([toks, pad]))
    return out


class DataPipeline:
    """Batches of packed sequences, DySkew-balanced across DP shards.

    The per-sequence cost model is quadratic in real (non-pad) length —
    the attention cost that actually skews step time across shards.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Cross-batch leftover buffer: documents that fit no sequence of
        # the current batch are carried to the next one, never dropped.
        self._carry: List[np.ndarray] = []
        if cfg.tenant_weights:
            # Per-tenant token accounting for observability/tests —
            # credited when a document is actually PACKED (see
            # `_on_pack`), not when the mixer draws it, so the counters
            # always equal the tokens that really reached batches.
            self.tenant_tokens = np.zeros(len(cfg.tenant_weights), np.int64)
            self.docs = iter(self._mixed_docs())
        else:
            self.docs = iter(SyntheticDocs(cfg))
        # Resolve the shard-placement policy through the shared registry
        # (ValueError on unknown names — construction-time, not deep in
        # a prefetch thread).  `uses_link` decides whether the
        # AdaptiveLink balancing path below is active.
        self.policy = StrategyConfig(kind=cfg.placement).make_policy(
            PolicyContext(num_workers=max(cfg.num_shards, 1))
        )
        self.link = AdaptiveLink(AdaptiveLinkConfig(
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK),
            num_instances=max(cfg.num_shards, 1),
        ))
        self.link_state = self.link.init_state()
        # Shared admission planner (same guards as repro.sim / repro.serving):
        # the Row Size Model keeps pathological huge-sequence batches local
        # instead of paying the reshard.
        self.admission = BatchAdmission(self.link.config.dyskew)
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- #

    def _mixed_docs(self) -> Iterator[np.ndarray]:
        """Interleave per-tenant document streams by deficit round robin:
        each pick is charged the document's token count, so token share
        (not just document count) follows the weights.  Documents are
        tagged with their owning tenant (`_TenantDoc` view); the token
        credit happens at PACK time via `_on_pack`, so a document parked
        in the carry buffer is not counted until it really lands in a
        batch."""
        cfg = self.cfg
        weights = list(cfg.tenant_weights)
        planner = FairShareAdmission(
            weights,
            FairShareConfig(quantum_rows=float(cfg.seq_len)),
        )
        streams = [
            iter(SyntheticDocs(cfg, seed_offset=1 + 7919 * i))
            for i in range(len(weights))
        ]
        pending = [next(s) for s in streams]
        while True:
            q = planner.pick_next([float(len(d)) for d in pending])
            doc = pending[q]
            pending[q] = next(streams[q])
            tagged = doc.view(_TenantDoc)
            tagged.tenant = q
            yield tagged

    def _on_pack(self, doc: np.ndarray) -> None:
        """Per-document pack callback: credit the owning tenant's token
        counter (docs from `_mixed_docs` carry a tenant tag)."""
        q = getattr(doc, "tenant", None)
        if q is not None:
            self.tenant_tokens[q] += len(doc)

    def _assemble(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        seqs = pack_documents(
            self.docs, cfg.seq_len, cfg.global_batch,
            carry=self._carry,
            on_pack=self._on_pack if cfg.tenant_weights else None,
        )
        tokens = np.stack(seqs)
        if cfg.dyskew_balance and cfg.num_shards > 1:
            lens = (tokens != 0).sum(axis=1).astype(np.float32)
            balance = not self.admission.density_guard_blocks(
                num_rows=cfg.global_batch // max(cfg.num_shards, 1),
                bytes_per_row=float(lens.sum()) * 4.0
                / max(cfg.global_batch, 1),
            )
        else:
            balance = False
        if balance:
            costs = lens**2 / float(cfg.seq_len) ** 2
            sizes = lens * 4.0
            producer = (
                np.arange(cfg.global_batch) * cfg.num_shards
                // cfg.global_batch
            ).astype(np.int32)
            if self.policy.uses_link:
                import jax.numpy as jnp

                self.link_state, plan = self.link.step(
                    self.link_state,
                    jnp.asarray(costs), jnp.asarray(sizes),
                    jnp.asarray(producer),
                )
                dest = np.asarray(plan.dest)
            else:
                # Registry policies place through the shared `assign`
                # seam: per-sequence quadratic costs, producer = the
                # shard the row-block layout would give the sequence.
                dest = self.policy.assign(
                    costs, producer, max(cfg.num_shards, 1)
                )
            # Reorder sequences so shard s receives contiguous rows: the
            # device layout maps row-blocks to DP shards.
            order = np.argsort(dest, kind="stable")
            tokens = tokens[order]
        targets = np.concatenate(
            [tokens[:, 1:], np.zeros((len(tokens), 1), np.int32)], axis=1
        )
        targets = np.where(targets == 0, -1, targets)  # mask pads
        return {"tokens": tokens, "targets": targets}

    def _worker(self):
        while not self._stop.is_set():
            batch = self._assemble()
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def start(self) -> "DataPipeline":
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            # Join: a daemon thread mid-jax-call at interpreter exit
            # aborts the process (SIGABRT in XLA teardown).
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is None:
            return self._assemble()
        return self._q.get()
