"""Optimizers: AdamW (fp32 states), Adafactor (factored second moment —
required for the 398B/1T archs where Adam states would not fit HBM), plus
learning-rate schedules and global-norm clipping.

Self-contained (no optax dependency); state trees follow the parameter
tree structure so the same sharding rules apply (optimizer state is
sharded exactly like its parameter).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # Adafactor
    factored_dim_threshold: int = 128
    # min lr fraction for cosine decay
    min_lr_frac: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


# ---------------------------- AdamW ----------------------------------- #


def adamw_init(params: Any) -> Dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
    }


def adamw_update(
    cfg: OptimizerConfig, grads: Any, state: Dict, params: Any,
    step: jax.Array,
) -> Tuple[Any, Dict]:
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


# --------------------------- Adafactor -------------------------------- #


def _factored(shape: Tuple[int, ...], threshold: int) -> bool:
    return len(shape) >= 2 and shape[-1] >= threshold and shape[-2] >= threshold


def adafactor_init(params: Any, cfg: OptimizerConfig) -> Dict:
    def init_one(p):
        if _factored(p.shape, cfg.factored_dim_threshold):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),        # row
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(init_one, params)}


def adafactor_update(
    cfg: OptimizerConfig, grads: Any, state: Dict, params: Any,
    step: jax.Array,
) -> Tuple[Any, Dict]:
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    decay = 1.0 - t ** -0.8

    def upd(g, v, p):
        g32 = jnp.square(g.astype(jnp.float32)) + 1e-30
        if "vr" in v:
            vr = decay * v["vr"] + (1 - decay) * jnp.mean(g32, axis=-1)
            vc = decay * v["vc"] + (1 - decay) * jnp.mean(g32, axis=-2)
            rfac = vr / jnp.maximum(
                jnp.mean(vr, axis=-1, keepdims=True), 1e-30
            )
            precond = jax.lax.rsqrt(
                jnp.maximum(rfac[..., None] * vc[..., None, :], 1e-30)
            )
            new_v = {"vr": vr, "vc": vc}
        else:
            vv = decay * v["v"] + (1 - decay) * g32
            precond = jax.lax.rsqrt(jnp.maximum(vv, 1e-30))
            new_v = {"v": vv}
        u = g.astype(jnp.float32) * precond
        # Update clipping (RMS ≤ 1), per Adafactor.
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        delta = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    vt = state["v"]
    flat_v = _leaves_of_state(vt, params)
    out = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_v = _unflatten_state([o[1] for o in out], vt, params)
    return new_p, {"v": new_v}


def _leaves_of_state(vtree: Any, params: Any):
    """State leaves ({'v'} or {'vr','vc'} dicts) in param-leaf order."""
    is_state_leaf = lambda x: isinstance(x, dict) and (
        "v" in x or "vr" in x
    )
    return jax.tree.leaves(vtree, is_leaf=is_state_leaf)


def _unflatten_state(new_leaves, vtree: Any, params: Any):
    is_state_leaf = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    treedef = jax.tree.structure(vtree, is_leaf=is_state_leaf)
    return jax.tree.unflatten(treedef, new_leaves)


# ---------------------------- unified --------------------------------- #


def opt_init(cfg: OptimizerConfig, params: Any) -> Dict:
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    if cfg.name == "sgd":
        return {}
    raise ValueError(cfg.name)


def opt_update(
    cfg: OptimizerConfig, grads: Any, state: Dict, params: Any,
    step: jax.Array,
) -> Tuple[Any, Dict, Dict]:
    """Returns (new_params, new_state, stats)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    if cfg.name == "adamw":
        new_p, new_s = adamw_update(cfg, grads, state, params, step)
    elif cfg.name == "adafactor":
        new_p, new_s = adafactor_update(cfg, grads, state, params, step)
    elif cfg.name == "sgd":
        lr = lr_schedule(cfg, step)
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(p.dtype),
            params, grads,
        )
        new_s = state
    else:
        raise ValueError(cfg.name)
    return new_p, new_s, {"grad_norm": gnorm, "lr": lr_schedule(cfg, step)}
