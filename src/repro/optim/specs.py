"""Optimizer-state ParamSpec trees (for dry-run shardings: optimizer state
is sharded exactly like its parameter, with factored Adafactor moments
dropping the corresponding axis)."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ParamSpec, is_spec
from repro.optim.optimizers import OptimizerConfig, _factored


def opt_state_specs(cfg: OptimizerConfig, param_specs: Any) -> Any:
    if cfg.name == "adamw":
        def f32(p: ParamSpec) -> ParamSpec:
            return ParamSpec(p.shape, p.axes, "zeros", None, jnp.float32)
        m = jax.tree.map(f32, param_specs, is_leaf=is_spec)
        return {"m": m, "v": jax.tree.map(f32, param_specs, is_leaf=is_spec)}
    if cfg.name == "adafactor":
        def fac(p: ParamSpec):
            if _factored(p.shape, cfg.factored_dim_threshold):
                return {
                    "vr": ParamSpec(p.shape[:-1], p.axes[:-1], "zeros", None,
                                    jnp.float32),
                    "vc": ParamSpec(p.shape[:-2] + p.shape[-1:],
                                    p.axes[:-2] + p.axes[-1:], "zeros", None,
                                    jnp.float32),
                }
            return {"v": ParamSpec(p.shape, p.axes, "zeros", None, jnp.float32)}
        return {"v": jax.tree.map(fac, param_specs, is_leaf=is_spec)}
    if cfg.name == "sgd":
        return {}
    raise ValueError(cfg.name)
