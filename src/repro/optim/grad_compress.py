"""Gradient compression with error feedback for cross-pod data parallelism.

At 1000+ node scale the cross-pod (DCN) gradient all-reduce dominates; we
compress gradients to int8 with per-tensor scales before the reduction and
carry the quantization residual forward (error feedback, 1-bit-Adam
style), which keeps convergence intact while cutting DCN bytes 4×
(fp32→int8) or 2× (bf16→int8).

Used by the shard_map training path (`repro.train.step` with
``grad_compression=True``): gradients are quantized, psum'd over the 'pod'
axis in int32 (sum of int8 lanes cannot overflow for <2^23 pods),
dequantized, and the residual is added to the next step's gradients.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def residual_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(
    grads: Any, residual: Any
) -> Tuple[Any, Any, Any]:
    """Returns (quantized tree, scales tree, new residual tree).

    new_residual = (g + residual) - dequant(quant(g + residual))
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    qs = jax.tree.map(lambda g, r: one(g, r)[0], grads, residual)
    ss = jax.tree.map(lambda g, r: one(g, r)[1], grads, residual)
    rs = jax.tree.map(lambda g, r: one(g, r)[2], grads, residual)
    return qs, ss, rs


def allreduce_compressed(
    grads: Any, residual: Any, axis_name: str
) -> Tuple[Any, Any]:
    """int8 all-reduce over `axis_name` with error feedback.

    Scales are max-reduced so all shards dequantize identically; the int8
    payload is what travels the wire.
    Returns (mean gradients fp32, new residual).
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        # Shared scale: max over shards so quantization grids agree.
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
        new_r = corrected - q.astype(jnp.float32) * scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * scale / n.astype(jnp.float32)
        return mean, new_r

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (
        jax.tree.unflatten(tdef, [o[0] for o in out]),
        jax.tree.unflatten(tdef, [o[1] for o in out]),
    )
