from repro.optim.optimizers import (
    OptimizerConfig,
    clip_by_global_norm,
    global_norm,
    lr_schedule,
    opt_init,
    opt_update,
)
from repro.optim.specs import opt_state_specs

__all__ = [
    "OptimizerConfig", "clip_by_global_norm", "global_norm", "lr_schedule",
    "opt_init", "opt_update", "opt_state_specs",
]
