"""Serving engine: continuous batching across replicas with a DySkew
request scheduler.

Request-level instantiation of the paper (DESIGN.md §3.4): requests are
rows, model replicas are workers, and per-replica state machines decide
when to rebalance.  The Row Size Model maps to KV-cache bytes: migrating a
long-context request's KV *is* moving a 100 GB row, so the cost gate
prices migrations at cache size over interconnect bandwidth while fresh
requests (no KV yet) are always cheap to (re)place — the eager path.

The engine here runs the scheduler against simulated replica clocks (the
same discrete-time style as repro.sim) and, when given a real Model, can
drive actual prefill/decode steps on one replica (see examples/serve_dyskew.py).

Multi-tenant serving: requests carry a ``tenant`` class index and
``ServeConfig.tenant_weights`` turns on the shared weighted fair-share
admission layer (`repro.core.admission.FairShareAdmission`) — the same
deficit-round-robin planner the multi-tenant simulator uses — pacing each
class's entry into the decode batches, with MIGRATED KV bytes (the ones
that actually crossed the interconnect) charged on the Row-Size-Model
NIC lane at the request's next admission.

Request timeline (honest accounting): a request materializes KV only by
PREFILLING — after it enters a decode batch, its prompt is processed at
``prefill_rate`` before any decode progress accrues — so ``kv_bytes``
reports the KV that actually exists (prefilled prompt + generated
tokens), fresh queued requests are free to move (the eager path), and a
migrated request is in transit for ``migration_latency + kv_bytes /
interconnect_bw`` simulated seconds before it can be scheduled again.
``migrated_gb`` therefore counts only KV that was really transferred.

SLO layer: ``ServeConfig.slo_targets`` declares per-tenant-class
deadlines (seconds from arrival); with ``deadline_aware=True`` decode
admission runs through `repro.core.admission.DeadlineAwareAdmission`
(EDF credit boost as slack runs out), and ``preemption=True`` lets an
urgent queued request displace a running slot of an over-share tenant —
the victim re-queues with its KV intact (so moving it later costs real
bytes and real transit time).  Per-tenant results then include SLO
attainment and p99 tardiness.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AdaptiveLink, AdaptiveLinkConfig, BatchAdmission, CostModelConfig
from repro.core.admission import (
    DeadlineAwareAdmission,
    DeadlineConfig,
    FairShareAdmission,
    FairShareConfig,
)
from repro.core.policy import PolicyContext, StrategyConfig, resolve_policy
from repro.core.types import DySkewConfig, Policy

#: Historical scheduler names, mapped onto the shared policy registry
#: (`repro.core.policy`): round_robin is the static per-row cycle and
#: least_loaded is the registry's 'none' policy, whose fresh-row
#: placement is least-loaded (placing a new request is not
#: redistributing).  Any registered policy name works directly.
_SCHEDULER_ALIASES = {"round_robin": "static_rr", "least_loaded": "none"}


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival: float
    tenant: int = 0          # fair-share tenant class (see ServeConfig)
    # runtime fields
    replica: int = -1
    generated: int = 0       # whole tokens emitted (integral by invariant)
    progress: float = 0.0    # fractional decode progress, in tokens
    prefilled: int = 0       # prompt tokens with materialized KV
    pf_progress: float = 0.0  # fractional prefill progress, in tokens
    available_at: float = 0.0  # in transit (migrating) until this time
    nic_debt: float = 0.0    # KV bytes moved over the NIC, not yet billed
    deadline: float = float("inf")  # absolute SLO deadline (set by engine)
    preemptions: int = 0     # times this request lost its decode slot
    done_at: float = -1.0

    @property
    def kv_len(self) -> int:
        # Only MATERIALIZED KV counts: prefilled prompt + generated
        # tokens.  A request that never prefilled carries no KV — its
        # migration is free and moves zero bytes (the seed engine charged
        # the full prompt here, billing KV that was never built).
        return self.prefilled + self.generated

    def kv_bytes(self, bytes_per_token: float) -> float:
        return self.kv_len * bytes_per_token


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    num_replicas: int = 4
    max_batch: int = 8                  # decode slots per replica
    prefill_rate: float = 80_000.0      # tokens/s per replica
    decode_rate: float = 3_000.0        # tokens/s per replica (full batch)
    kv_bytes_per_token: float = 2 * 64 * 8 * 128 * 2.0  # L*K*hd*2B (bf16)
    interconnect_bw: float = 50e9       # ICI
    migration_latency: float = 2e-3
    # Placement policy: any name in the `repro.core.policy` registry
    # (dyskew | none | static_rr | p2c | key_affinity | hillclimb | ...)
    # plus the historical aliases round_robin / least_loaded.  Unknown
    # names raise ValueError when the scheduler is built.
    scheduler: str = "dyskew"
    # Weighted fair-share admission across tenant classes (None = off):
    # requests carry a `tenant` index into these weights, and entry into
    # a replica's decode batch is paced by the shared
    # `repro.core.admission.FairShareAdmission` planner (the same layer
    # the multi-tenant simulator uses), with the KV bytes a request
    # actually moved over the interconnect as the Row Size Model
    # NIC-lane charge.
    tenant_weights: Optional[Tuple[float, ...]] = None
    # Per-tenant-class SLO targets (seconds from arrival to completion;
    # None entries = no deadline for that class).  Length must match
    # ``tenant_weights`` when both are set.
    slo_targets: Optional[Tuple[Optional[float], ...]] = None
    # Upgrade fair-share admission to the deadline-aware planner (EDF
    # credit boost; requires tenant_weights + slo_targets).
    deadline_aware: bool = False
    # Allow urgent queued requests to preempt a running decode slot of an
    # over-share tenant (requires deadline_aware).
    preemption: bool = False
    deadline_cfg: DeadlineConfig = DeadlineConfig()
    # Simulated-time budget: runs longer than this stop and REPORT the
    # truncation (the seed engine silently broke, making a stuck run
    # indistinguishable from a finished one).
    max_sim_s: float = 3600.0


class ServingScheduler:
    """Places new requests and (optionally) migrates queued ones."""

    def __init__(self, cfg: ServeConfig, seed: int = 0):
        self.cfg = cfg
        n = cfg.num_replicas
        # Resolve the placement policy through the shared registry —
        # unknown scheduler names fail HERE, not by silently falling
        # through to least-loaded.
        kind = _SCHEDULER_ALIASES.get(cfg.scheduler, cfg.scheduler)
        self.policy = StrategyConfig(kind=kind).make_policy(PolicyContext(
            num_workers=n,
            rng=np.random.default_rng(seed),
            network_bandwidth=cfg.interconnect_bw,
            per_row_serialize=cfg.migration_latency,
        ))
        self.link = AdaptiveLink(AdaptiveLinkConfig(
            dyskew=DySkewConfig(
                policy=Policy.EAGER_SNOWPARK,
                # Row Size Model: requests whose KV exceeds this are 'heavy
                # rows' — migration must clear the cost gate.
                heavy_row_bytes=64e6,
                target_batch_density=cfg.max_batch * 4.0,
                min_batch_density_frac=0.25,
            ),
            cost=CostModelConfig(
                link_bandwidth=cfg.interconnect_bw,
                per_item_overhead=cfg.migration_latency,
            ),
            num_instances=n,
        ))
        self.link_state = self.link.init_state()
        # Shared per-batch admission planner (same guards the simulator and
        # the data pipeline use): prices queued-request migrations.
        self.admission = BatchAdmission(self.link.config.dyskew)

    def place(self, req: Request, load_tokens: np.ndarray) -> int:
        """Choose a replica for a NEW request (no KV yet → free to move).

        Delegates to the policy's single-row placement: static_rr uses
        the current slot then advances (replica 0 must receive the first
        request — a seed bug skipped it), none/dyskew place least-loaded
        by outstanding token estimate (dyskew's eager zero-size row
        always clears the gate), stochastic policies draw from their
        injected RNG stream.
        """
        return int(self.policy.place_one(load_tokens))

    def rebalance(
        self,
        queued: List[Request],
        load_tokens: np.ndarray,
    ) -> Dict[int, int]:
        """DySkew pass over QUEUED (not yet running) requests.

        Returns {rid: new_replica}. Queued requests that already prefilled
        on a replica carry KV; the cost gate decides if moving pays off.
        """
        # Only link-consuming policies (class flag, same hook the
        # simulator's tick machinery asks) run the rebalance pass.
        if not self.policy.uses_link or not queued:
            return {}
        import jax.numpy as jnp

        costs = np.array(
            [r.max_new_tokens / self.cfg.decode_rate for r in queued],
            np.float32,
        )
        sizes = np.array(
            [r.kv_bytes(self.cfg.kv_bytes_per_token) for r in queued],
            np.float32,
        )
        producer = np.array([max(r.replica, 0) for r in queued], np.int32)
        self.link_state, plan = self.link.step(
            self.link_state,
            jnp.asarray(costs), jnp.asarray(sizes), jnp.asarray(producer),
        )
        dest = np.asarray(plan.dest)
        # Per-request cost gate via the shared admission planner: a queued
        # request whose KV transfer costs more than the straggler time its
        # move would save stays put (heavy-KV 'rows' must not thrash).
        moves: Dict[int, int] = {}
        n = self.cfg.num_replicas
        for r, d, cost, size in zip(queued, dest, costs, sizes):
            if int(d) == r.replica:
                continue
            dec = self.admission.admit_move(
                float(size), 1, float(cost), n,
                self.cfg.interconnect_bw, self.cfg.migration_latency,
            )
            if dec.admit:
                moves[r.rid] = int(d)
        return moves


class ServingEngine:
    """Simulated multi-replica continuous-batching engine."""

    def __init__(self, cfg: ServeConfig, seed: int = 0):
        self.cfg = cfg
        self.sched = ServingScheduler(cfg, seed=seed)
        self.rng = np.random.default_rng(seed)

    def _make_planner(self) -> Optional[FairShareAdmission]:
        """Fair-share admission over tenant classes: requests = rows, a
        decode slot = the pool resource, KV bytes = the NIC-lane charge.
        ``deadline_aware`` upgrades to the EDF-boosted planner (per-class
        ``slo_targets`` become admission deadlines).  Built fresh per run
        — the planner is stateful (deficits, in-service counts) like the
        queues it paces."""
        cfg = self.cfg
        if cfg.deadline_aware and not cfg.tenant_weights:
            raise ValueError(
                "deadline_aware requires tenant_weights (the deadline-"
                "aware planner is an upgrade of the fair-share layer)"
            )
        if cfg.preemption and not cfg.deadline_aware:
            raise ValueError(
                "preemption requires deadline_aware (victims are picked "
                "by the deadline-aware planner)"
            )
        if not cfg.tenant_weights:
            return None
        fs = FairShareConfig(
            quantum_rows=float(cfg.max_batch),
            quantum_bytes=64e6,
            heavy_row_bytes=64e6,
        )
        if cfg.deadline_aware:
            if not cfg.slo_targets:
                raise ValueError(
                    "deadline_aware requires slo_targets (otherwise the "
                    "SLO layer would be silently inert)"
                )
            if len(cfg.slo_targets) != len(cfg.tenant_weights):
                raise ValueError(
                    f"slo_targets length {len(cfg.slo_targets)} != "
                    f"tenant_weights length {len(cfg.tenant_weights)}"
                )
            return DeadlineAwareAdmission(
                list(cfg.tenant_weights),
                list(cfg.slo_targets),
                fs,
                cfg.deadline_cfg,
            )
        return FairShareAdmission(list(cfg.tenant_weights), fs)

    def run(self, requests: List[Request]) -> Dict:
        cfg = self.cfg
        n = cfg.num_replicas
        queues: List[List[Request]] = [[] for _ in range(n)]
        running: List[List[Request]] = [[] for _ in range(n)]
        t = 0.0
        done: List[Request] = []
        pending = sorted(requests, key=lambda r: r.arrival)
        if cfg.slo_targets:
            for r in pending:
                slo = (
                    cfg.slo_targets[r.tenant]
                    if r.tenant < len(cfg.slo_targets) else None
                )
                r.deadline = (
                    r.arrival + slo if slo is not None else float("inf")
                )
        i = 0
        migrations = 0
        migrated_bytes = 0.0
        migration_delay_s = 0.0
        preemptions = 0
        truncated = False
        dt = 10e-3
        planner = self._make_planner()
        dl = planner if isinstance(planner, DeadlineAwareAdmission) else None

        def load_tokens() -> np.ndarray:
            out = np.zeros(n)
            for rep in range(n):
                out[rep] = sum(
                    r.prompt_len + r.max_new_tokens - r.generated
                    for r in queues[rep] + running[rep]
                )
            return out

        def admit(r: Request) -> bool:
            if planner is None:
                return True
            # NIC lane: bill the KV bytes this request actually moved
            # over the interconnect since its last admission (set at
            # migration time) — NOT its resident KV.  A fresh request
            # and a preempted request re-entering on the same replica
            # moved nothing and charge nothing.
            nic = r.nic_debt
            if dl is None:
                ok = planner.try_admit(r.tenant, 1, nic, nic)
            else:
                ok = dl.try_admit(
                    r.tenant, 1, nic, nic, deadline=r.deadline, now=t
                )
            if ok:
                r.nic_debt = 0.0
            return ok

        while i < len(pending) or any(queues) or any(running):
            # admit arrivals
            while i < len(pending) and pending[i].arrival <= t:
                r = pending[i]
                r.replica = self.sched.place(r, load_tokens())
                queues[r.replica].append(r)
                i += 1
            # periodic DySkew rebalance of queued work (requests still in
            # transit from a previous migration cannot move again yet)
            moves = self.sched.rebalance(
                [r for q in queues for r in q if r.available_at <= t],
                load_tokens(),
            )
            if moves:
                # Detach movers first, append after: appending to a queue
                # that is iterated later in the same pass re-visits the
                # moved request and loops forever (moves to higher replicas).
                moved = []
                for rep in range(n):
                    stay = []
                    for r in queues[rep]:
                        if moves.get(r.rid, rep) != rep:
                            migrations += 1
                            # Only MATERIALIZED KV is transferred: zero
                            # for a never-prefilled request (free eager
                            # move), real bytes for preempted requests
                            # carrying prefill + generated KV — and the
                            # move costs simulated transit time either
                            # way (latency + bytes over the interconnect).
                            kv = r.kv_bytes(cfg.kv_bytes_per_token)
                            migrated_bytes += kv
                            r.nic_debt += kv
                            delay = (
                                cfg.migration_latency
                                + kv / cfg.interconnect_bw
                            )
                            r.available_at = t + delay
                            migration_delay_s += delay
                            r.replica = moves[r.rid]
                            moved.append(r)
                        else:
                            stay.append(r)
                    queues[rep] = stay
                for r in moved:
                    queues[r.replica].append(r)
            # run each replica for dt
            for rep in range(n):
                # Fill decode slots; with fair share on, each queued
                # request must clear its tenant's deficit first.  Blocked
                # requests are skipped (not head-of-line blocking) and
                # retried next step once completions earn credit.
                qi = 0
                while len(running[rep]) < cfg.max_batch and qi < len(queues[rep]):
                    r = queues[rep][qi]
                    if r.available_at > t or not admit(r):
                        qi += 1
                        continue
                    running[rep].append(queues[rep].pop(qi))
                # Slot preemption: an urgent queued request (slack inside
                # the horizon) may displace a running request of an
                # over-share tenant with a later (or no) deadline.  The
                # victim re-queues at the head with its KV intact and
                # must re-clear fair share; the planner transfers one
                # slot of credit to the urgent tenant.
                if (
                    cfg.preemption and dl is not None
                    and len(running[rep]) >= cfg.max_batch and queues[rep]
                ):
                    horizon = cfg.deadline_cfg.urgency_horizon
                    urgent = min(
                        (
                            r for r in queues[rep]
                            if r.available_at <= t
                            and r.deadline - t < horizon
                        ),
                        key=lambda r: (r.deadline, r.rid),
                        default=None,
                    )
                    # Dry-run probe: displace a victim only if the urgent
                    # admission WOULD succeed with the transferred slot
                    # of credit — otherwise the freed slot would idle and
                    # the refunded victim would be thrashed every step.
                    if urgent is not None and not dl.would_admit(
                        urgent.tenant, 1, urgent.nic_debt, urgent.nic_debt,
                        deadline=urgent.deadline, now=t, rows_advance=1.0,
                    ):
                        urgent = None
                    if urgent is not None:
                        over = {
                            q for q, _ in dl.preempt_candidates(
                                protect=(urgent.tenant,)
                            )
                        }
                        victim = max(
                            (
                                v for v in running[rep]
                                if v.tenant in over
                                and v.deadline > urgent.deadline
                            ),
                            key=lambda v: (
                                v.deadline,
                                v.max_new_tokens - v.generated,
                                v.rid,
                            ),
                            default=None,
                        )
                        if victim is not None:
                            running[rep].remove(victim)
                            victim.preemptions += 1
                            queues[rep].insert(0, victim)
                            dl.preempt_transfer(
                                victim.tenant, urgent.tenant, 1
                            )
                            preemptions += 1
                            if admit(urgent):
                                queues[rep].remove(urgent)
                                running[rep].append(urgent)
                if not running[rep]:
                    continue
                # Prefill first: prompt KV is materialized at
                # prefill_rate (FIFO across the replica's unprefilled
                # slots); only prefilled requests accrue decode progress.
                pf_budget = cfg.prefill_rate * dt
                decoders = []
                for r in running[rep]:
                    if r.prefilled < r.prompt_len:
                        if pf_budget > 0.0:
                            take = min(
                                pf_budget, r.prompt_len - r.pf_progress
                            )
                            r.pf_progress += take
                            pf_budget -= take
                            if r.pf_progress >= r.prompt_len - 1e-9:
                                r.pf_progress = float(r.prompt_len)
                            r.prefilled = min(
                                int(r.pf_progress), r.prompt_len
                            )
                    if r.prefilled >= r.prompt_len:
                        decoders.append(r)
                if not decoders:
                    continue
                # decode_rate shared across the DECODING slots
                per_slot = cfg.decode_rate * dt / len(decoders)
                still = []
                for r in running[rep]:
                    if r.prefilled < r.prompt_len:
                        still.append(r)
                        continue
                    # Tokens are integral: accumulate fractional decode
                    # progress separately and clamp `generated` so
                    # kv_len/kv_bytes keep whole-token semantics.
                    r.progress += per_slot
                    r.generated = min(int(r.progress), r.max_new_tokens)
                    if r.generated >= r.max_new_tokens:
                        r.done_at = t + dt
                        done.append(r)
                        if planner is not None:
                            planner.on_complete(r.tenant, 1)
                    else:
                        still.append(r)
                running[rep] = still
            t += dt
            if t > cfg.max_sim_s:
                # Out of simulated-time budget: stop and SAY so — the
                # seed engine silently broke here, reporting a truncated
                # run as if it had completed.
                truncated = True
                break

        lat = np.array([r.done_at - r.arrival for r in done])
        incomplete = (
            (len(pending) - i)
            + sum(len(q) for q in queues)
            + sum(len(b) for b in running)
        )
        out = {
            "completed": len(done),
            "mean_latency": float(lat.mean()) if len(lat) else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "migrations": migrations,
            "migrated_gb": migrated_bytes / float(2 ** 30),
            "migration_delay_s": migration_delay_s,
            "preemptions": preemptions,
            "truncated": truncated,
            "incomplete": incomplete,
            "makespan": t,
        }
        if planner is not None:
            per_tenant: Dict[int, Dict[str, float]] = {}
            nan = float("nan")
            slo_met_all = slo_total_all = 0
            # Unfinished requests whose deadline has already passed are
            # definitive MISSES — counting only completions would let a
            # truncated run report better attainment than a finished one.
            unfinished = (
                pending[i:]
                + [r for q in queues for r in q]
                + [r for b in running for r in b]
            )
            for tid in range(len(cfg.tenant_weights)):
                tl = np.array(
                    [r.done_at - r.arrival for r in done if r.tenant == tid]
                )
                entry: Dict[str, float] = {
                    "completed": int(len(tl)),
                    "mean_latency": float(tl.mean()) if len(tl) else 0.0,
                    "p99_latency": (
                        float(np.percentile(tl, 99)) if len(tl) else 0.0
                    ),
                }
                slo = (
                    cfg.slo_targets[tid]
                    if cfg.slo_targets and tid < len(cfg.slo_targets)
                    else None
                )
                if slo is not None:
                    overdue = sum(
                        1 for r in unfinished
                        if r.tenant == tid and r.deadline <= t
                    )
                    denom = len(tl) + overdue
                    if denom:
                        met = tl <= slo
                        entry["slo_attainment"] = float(met.sum()) / denom
                        # Tardiness is measurable only for completions.
                        entry["p99_tardiness"] = (
                            float(np.percentile(np.maximum(tl - slo, 0.0),
                                                99))
                            if len(tl) else nan
                        )
                        entry["slo_overdue_incomplete"] = overdue
                        slo_met_all += int(met.sum())
                        slo_total_all += denom
                    else:
                        entry["slo_attainment"] = nan
                        entry["p99_tardiness"] = nan
                per_tenant[tid] = entry
            out["per_tenant"] = per_tenant
            if slo_total_all:
                out["slo_attainment"] = slo_met_all / slo_total_all
        return out
