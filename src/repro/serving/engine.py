"""Serving engine: continuous batching across replicas with a DySkew
request scheduler.

Request-level instantiation of the paper (DESIGN.md §3.4): requests are
rows, model replicas are workers, and per-replica state machines decide
when to rebalance.  The Row Size Model maps to KV-cache bytes: migrating a
long-context request's KV *is* moving a 100 GB row, so the cost gate
prices migrations at cache size over interconnect bandwidth while fresh
requests (no KV yet) are always cheap to (re)place — the eager path.

The engine here runs the scheduler against simulated replica clocks (the
same discrete-time style as repro.sim) and, when given a real Model, can
drive actual prefill/decode steps on one replica (see examples/serve_dyskew.py).

Multi-tenant serving: requests carry a ``tenant`` class index and
``ServeConfig.tenant_weights`` turns on the shared weighted fair-share
admission layer (`repro.core.admission.FairShareAdmission`) — the same
deficit-round-robin planner the multi-tenant simulator uses — pacing each
class's entry into the decode batches, with KV bytes charged on the
Row-Size-Model NIC lane.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AdaptiveLink, AdaptiveLinkConfig, BatchAdmission, CostModelConfig
from repro.core.admission import FairShareAdmission, FairShareConfig
from repro.core.types import DySkewConfig, Policy


@dataclasses.dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    arrival: float
    tenant: int = 0          # fair-share tenant class (see ServeConfig)
    # runtime fields
    replica: int = -1
    generated: int = 0       # whole tokens emitted (integral by invariant)
    progress: float = 0.0    # fractional decode progress, in tokens
    done_at: float = -1.0

    @property
    def kv_len(self) -> int:
        return self.prompt_len + self.generated

    def kv_bytes(self, bytes_per_token: float) -> float:
        return self.kv_len * bytes_per_token


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    num_replicas: int = 4
    max_batch: int = 8                  # decode slots per replica
    prefill_rate: float = 80_000.0      # tokens/s per replica
    decode_rate: float = 3_000.0        # tokens/s per replica (full batch)
    kv_bytes_per_token: float = 2 * 64 * 8 * 128 * 2.0  # L*K*hd*2B (bf16)
    interconnect_bw: float = 50e9       # ICI
    migration_latency: float = 2e-3
    scheduler: str = "dyskew"           # dyskew | round_robin | least_loaded
    # Weighted fair-share admission across tenant classes (None = off):
    # requests carry a `tenant` index into these weights, and entry into
    # a replica's decode batch is paced by the shared
    # `repro.core.admission.FairShareAdmission` planner (the same layer
    # the multi-tenant simulator uses), with KV bytes as the Row Size
    # Model NIC-lane charge.
    tenant_weights: Optional[Tuple[float, ...]] = None


class ServingScheduler:
    """Places new requests and (optionally) migrates queued ones."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        n = cfg.num_replicas
        self.link = AdaptiveLink(AdaptiveLinkConfig(
            dyskew=DySkewConfig(
                policy=Policy.EAGER_SNOWPARK,
                # Row Size Model: requests whose KV exceeds this are 'heavy
                # rows' — migration must clear the cost gate.
                heavy_row_bytes=64e6,
                target_batch_density=cfg.max_batch * 4.0,
                min_batch_density_frac=0.25,
            ),
            cost=CostModelConfig(
                link_bandwidth=cfg.interconnect_bw,
                per_item_overhead=cfg.migration_latency,
            ),
            num_instances=n,
        ))
        self.link_state = self.link.init_state()
        # Shared per-batch admission planner (same guards the simulator and
        # the data pipeline use): prices queued-request migrations.
        self.admission = BatchAdmission(self.link.config.dyskew)
        self._rr = 0

    def place(self, req: Request, load_tokens: np.ndarray) -> int:
        """Choose a replica for a NEW request (no KV yet → free to move)."""
        cfg = self.cfg
        if cfg.scheduler == "round_robin":
            # Use the current slot, then advance — replica 0 must receive
            # the first request (seed bug skipped it).
            rep = self._rr
            self._rr = (rep + 1) % cfg.num_replicas
            return rep
        # least-loaded by outstanding token estimate (dyskew placement is
        # least-loaded too: eager + zero-size row always clears the gate).
        return int(np.argmin(load_tokens))

    def rebalance(
        self,
        queued: List[Request],
        load_tokens: np.ndarray,
    ) -> Dict[int, int]:
        """DySkew pass over QUEUED (not yet running) requests.

        Returns {rid: new_replica}. Queued requests that already prefilled
        on a replica carry KV; the cost gate decides if moving pays off.
        """
        if self.cfg.scheduler != "dyskew" or not queued:
            return {}
        import jax.numpy as jnp

        costs = np.array(
            [r.max_new_tokens / self.cfg.decode_rate for r in queued],
            np.float32,
        )
        sizes = np.array(
            [r.kv_bytes(self.cfg.kv_bytes_per_token) for r in queued],
            np.float32,
        )
        producer = np.array([max(r.replica, 0) for r in queued], np.int32)
        self.link_state, plan = self.link.step(
            self.link_state,
            jnp.asarray(costs), jnp.asarray(sizes), jnp.asarray(producer),
        )
        dest = np.asarray(plan.dest)
        # Per-request cost gate via the shared admission planner: a queued
        # request whose KV transfer costs more than the straggler time its
        # move would save stays put (heavy-KV 'rows' must not thrash).
        moves: Dict[int, int] = {}
        n = self.cfg.num_replicas
        for r, d, cost, size in zip(queued, dest, costs, sizes):
            if int(d) == r.replica:
                continue
            dec = self.admission.admit_move(
                float(size), 1, float(cost), n,
                self.cfg.interconnect_bw, self.cfg.migration_latency,
            )
            if dec.admit:
                moves[r.rid] = int(d)
        return moves


class ServingEngine:
    """Simulated multi-replica continuous-batching engine."""

    def __init__(self, cfg: ServeConfig, seed: int = 0):
        self.cfg = cfg
        self.sched = ServingScheduler(cfg)
        self.rng = np.random.default_rng(seed)

    def _make_planner(self) -> Optional[FairShareAdmission]:
        """Fair-share admission over tenant classes: requests = rows, a
        decode slot = the pool resource, KV bytes = the NIC-lane charge.
        Built fresh per run — the planner is stateful (deficits,
        in-service counts) like the queues it paces."""
        if not self.cfg.tenant_weights:
            return None
        return FairShareAdmission(
            list(self.cfg.tenant_weights),
            FairShareConfig(
                quantum_rows=float(self.cfg.max_batch),
                quantum_bytes=64e6,
                heavy_row_bytes=64e6,
            ),
        )

    def run(self, requests: List[Request]) -> Dict:
        cfg = self.cfg
        n = cfg.num_replicas
        queues: List[List[Request]] = [[] for _ in range(n)]
        running: List[List[Request]] = [[] for _ in range(n)]
        t = 0.0
        done: List[Request] = []
        pending = sorted(requests, key=lambda r: r.arrival)
        i = 0
        migrations = 0
        migrated_bytes = 0.0
        dt = 10e-3
        planner = self._make_planner()

        def load_tokens() -> np.ndarray:
            out = np.zeros(n)
            for rep in range(n):
                out[rep] = sum(
                    r.prompt_len + r.max_new_tokens - r.generated
                    for r in queues[rep] + running[rep]
                )
            return out

        while i < len(pending) or any(queues) or any(running):
            # admit arrivals
            while i < len(pending) and pending[i].arrival <= t:
                r = pending[i]
                r.replica = self.sched.place(r, load_tokens())
                queues[r.replica].append(r)
                i += 1
            # periodic DySkew rebalance of queued work
            moves = self.sched.rebalance(
                [r for q in queues for r in q], load_tokens()
            )
            if moves:
                # Detach movers first, append after: appending to a queue
                # that is iterated later in the same pass re-visits the
                # moved request and loops forever (moves to higher replicas).
                moved = []
                for rep in range(n):
                    stay = []
                    for r in queues[rep]:
                        if moves.get(r.rid, rep) != rep:
                            migrations += 1
                            migrated_bytes += r.kv_bytes(
                                cfg.kv_bytes_per_token
                            )
                            r.replica = moves[r.rid]
                            moved.append(r)
                        else:
                            stay.append(r)
                    queues[rep] = stay
                for r in moved:
                    queues[r.replica].append(r)
            # run each replica for dt
            for rep in range(n):
                # Fill decode slots; with fair share on, each queued
                # request must clear its tenant's deficit first.  Blocked
                # requests are skipped (not head-of-line blocking) and
                # retried next step once completions earn credit.
                qi = 0
                while len(running[rep]) < cfg.max_batch and qi < len(queues[rep]):
                    r = queues[rep][qi]
                    if planner is not None:
                        kv = r.kv_bytes(cfg.kv_bytes_per_token)
                        if not planner.try_admit(r.tenant, 1, kv, kv):
                            qi += 1
                            continue
                    running[rep].append(queues[rep].pop(qi))
                if not running[rep]:
                    continue
                # decode_rate shared across active slots
                per_slot = cfg.decode_rate * dt / len(running[rep])
                still = []
                for r in running[rep]:
                    # Tokens are integral: accumulate fractional decode
                    # progress separately and clamp `generated` so
                    # kv_len/kv_bytes keep whole-token semantics.
                    r.progress += per_slot
                    r.generated = min(int(r.progress), r.max_new_tokens)
                    if r.generated >= r.max_new_tokens:
                        r.done_at = t + dt
                        done.append(r)
                        if planner is not None:
                            planner.on_complete(r.tenant, 1)
                    else:
                        still.append(r)
                running[rep] = still
            t += dt
            if t > 3600:
                break

        lat = np.array([r.done_at - r.arrival for r in done])
        out = {
            "completed": len(done),
            "mean_latency": float(lat.mean()) if len(lat) else 0.0,
            "p99_latency": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "migrations": migrations,
            "migrated_gb": migrated_bytes / 1e9,
            "makespan": t,
        }
        if planner is not None:
            per_tenant: Dict[int, Dict[str, float]] = {}
            for tid in range(len(cfg.tenant_weights)):
                tl = np.array(
                    [r.done_at - r.arrival for r in done if r.tenant == tid]
                )
                per_tenant[tid] = {
                    "completed": int(len(tl)),
                    "mean_latency": float(tl.mean()) if len(tl) else 0.0,
                    "p99_latency": (
                        float(np.percentile(tl, 99)) if len(tl) else 0.0
                    ),
                }
            out["per_tenant"] = per_tenant
        return out
