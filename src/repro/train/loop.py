"""Training loop: data pipeline → jitted train step → checkpoint/telemetry.

Runs on any mesh (1 CPU device for the examples, a pod in production).
Integrates: DySkew data balancing, async checkpointing, fault-runtime
heartbeats, and per-step DySkew MoE telemetry.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config.base import ArchConfig
from repro.data.pipeline import DataConfig, DataPipeline
from repro.models.layers.moe import SpmdCtx
from repro.models.model_api import build
from repro.optim.optimizers import OptimizerConfig
from repro.train.step import StepConfig, make_train_step, train_state_init


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0


def train(
    cfg: ArchConfig,
    data_cfg: DataConfig,
    opt_cfg: OptimizerConfig,
    loop_cfg: LoopConfig,
    on_metrics: Optional[Callable[[int, Dict], None]] = None,
) -> Dict:
    model = build(cfg)
    ctx = SpmdCtx()
    step_fn = jax.jit(make_train_step(model, opt_cfg, StepConfig(), ctx))
    state = train_state_init(model, opt_cfg, jax.random.PRNGKey(loop_cfg.seed), ctx)

    ckpt = None
    start_step = 0
    if loop_cfg.checkpoint_dir:
        ckpt = CheckpointManager(loop_cfg.checkpoint_dir)
        if ckpt.latest_step() is not None:
            state = ckpt.restore(state)
            start_step = int(state["step"])

    pipe = DataPipeline(data_cfg).start()
    history = []
    t0 = time.time()
    for step in range(start_step, loop_cfg.steps):
        batch = next(pipe)
        state, metrics = step_fn(state, batch)
        if (step + 1) % loop_cfg.log_every == 0 or step == start_step:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step + 1
            m["wall_s"] = round(time.time() - t0, 1)
            history.append(m)
            if on_metrics:
                on_metrics(step + 1, m)
        if ckpt and (step + 1) % loop_cfg.checkpoint_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(loop_cfg.steps, state, blocking=True)
    pipe.stop()
    return {"state": state, "history": history}
