"""Training / serving step builders.

``make_train_step`` returns the jit-able function the launcher and the
multi-pod dry-run lower: forward + backward + (optionally compressed)
gradient reduction + optimizer update + DySkew link-state advance, with
optional microbatched gradient accumulation (lax.scan) for activation-
memory control.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers.moe import SpmdCtx
from repro.models.model_api import Model
from repro.models.perf_flags import get_flags
from repro.optim.optimizers import OptimizerConfig, opt_init, opt_update
from repro.optim.specs import opt_state_specs


@dataclasses.dataclass(frozen=True)
class StepConfig:
    num_microbatches: int = 1
    grad_compression: bool = False   # int8+error-feedback cross-pod reduce


def train_state_init(
    model: Model, opt_cfg: OptimizerConfig, key: jax.Array,
    ctx: SpmdCtx = SpmdCtx(),
) -> Dict:
    params = model.init(key)
    state = {
        "params": params,
        "opt": opt_init(opt_cfg, params),
        "step": jnp.zeros((), jnp.int32),
    }
    dk = model.dyskew_init(ctx)
    if dk is not None:
        state["dyskew"] = dk
    return state


def train_state_specs(
    model: Model, opt_cfg: OptimizerConfig
) -> Dict:
    """ParamSpec tree mirroring train_state_init (dry-run shardings).
    DySkew states and the step counter are small → handled as replicated
    abstract leaves by the dry-run."""
    pspecs = model.specs()
    return {
        "params": pspecs,
        "opt": opt_state_specs(opt_cfg, pspecs),
    }


def make_train_step(
    model: Model,
    opt_cfg: OptimizerConfig,
    step_cfg: StepConfig = StepConfig(),
    ctx: SpmdCtx = SpmdCtx(),
    param_pspecs: Optional[Dict] = None,
) -> Callable[[Dict, Dict], Tuple[Dict, Dict]]:
    """Returns train_step(state, batch) -> (new_state, metrics)."""

    def loss_fn(params, batch, dyskew):
        if get_flags().cast_before_gather:
            # H2: cast fp32 masters to bf16 while still FSDP-sharded, so
            # the per-layer weight all-gathers move half the bytes. The
            # sharding constraint pins the convert's OUTPUT to the FSDP
            # layout — otherwise sharding propagation marks it replicated
            # and GSPMD gathers the fp32 input instead.
            def cast(p, spec=None):
                if p.dtype != jnp.float32:
                    return p
                c = p.astype(jnp.bfloat16)
                if spec is not None:
                    c = jax.lax.with_sharding_constraint(c, spec)
                return c

            if param_pspecs is not None:
                params = jax.tree.map(cast, params, param_pspecs)
            else:
                params = jax.tree.map(cast, params)
        loss, aux = model.loss(params, batch, dyskew=dyskew, ctx=ctx)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]
        dyskew = state.get("dyskew")

        nm = step_cfg.num_microbatches
        if nm == 1:
            (loss, aux), grads = grad_fn(params, batch, dyskew)
            if get_flags().constrain_grads and param_pspecs is not None:
                # H8: pin gradient shardings to the parameter layout so the
                # batch-axis reduction lowers as reduce-scatter.
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(g, s),
                    grads, param_pspecs,
                )
            new_dyskew = aux.get("dyskew")
            metrics = aux["metrics"]
        else:
            # Gradient accumulation: scan over microbatches; DySkew links
            # tick once per microbatch (finer-grained adaptation).
            def micro(carry, mb):
                acc, dk = carry
                (loss, aux), g = grad_fn(params, mb, dk)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g
                )
                return (acc, aux.get("dyskew", dk)), (loss, aux["metrics"])

            mbatch = jax.tree.map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, new_dyskew), (losses, mmetrics) = jax.lax.scan(
                micro, (zeros, dyskew), mbatch
            )
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), mmetrics)

        new_params, new_opt, stats = opt_update(
            opt_cfg, grads, state["opt"], params, state["step"]
        )
        new_state = dict(
            state,
            params=new_params,
            opt=new_opt,
            step=state["step"] + 1,
        )
        if new_dyskew is not None:
            new_state["dyskew"] = new_dyskew
        metrics = dict(metrics, **stats, loss=loss)
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model, ctx: SpmdCtx = SpmdCtx()):
    def prefill_step(params, state, inputs):
        logits, new_state = model.prefill(params, inputs, state, ctx=ctx)
        return logits[:, -1:], new_state

    return prefill_step


def make_decode_step(model: Model, ctx: SpmdCtx = SpmdCtx()):
    def decode_step(params, state, token):
        logits, new_state = model.decode_step(params, state, token, ctx=ctx)
        return logits, new_state

    return decode_step
