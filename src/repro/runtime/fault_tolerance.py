"""Fault-tolerant training runtime: heartbeats, failure detection,
straggler mitigation, elastic recovery.

Scale design (1000+ nodes): a lightweight coordinator tracks per-host
heartbeats; detection reuses the paper's skew machinery —

  * the **idle-time model** flags hosts that stopped reporting
    (failure candidates),
  * the **sync-time-slope model** flags hosts whose step time is
    accelerating away from siblings (stragglers) *before* they fail —
    DySkew's Eq. (2) applied to step latencies instead of rows,
  * N-strikes hysteresis suppresses transient network blips exactly as it
    suppresses transient row-count skew.

On detection the runtime (a) excludes the host, (b) rebuilds the mesh from
survivors (elastic), (c) restores from the latest checkpoint (the
CheckpointManager's elastic restore reshards to the new mesh).  In this
container the hosts are simulated actors driven by an injectable clock so
every policy is unit-testable.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import skew_models
from repro.core.types import DySkewConfig, SkewModelKind, link_metrics_zeros


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    heartbeat_interval: float = 10.0      # s
    missed_beats_dead: int = 3            # idle-time grace (ticks)
    straggler_theta: float = 0.5          # Eq. (2) θ over step-time slopes
    n_strikes: int = 3
    slope_window: int = 8
    min_hosts: int = 2                    # refuse to shrink below


@dataclasses.dataclass
class HostState:
    host_id: int
    last_beat: float = 0.0
    cum_step_time: float = 0.0
    alive: bool = True


class FaultTolerantRuntime:
    """Coordinator-side failure/straggler detector + elastic remesh."""

    def __init__(self, num_hosts: int, cfg: FaultConfig = FaultConfig()):
        self.cfg = cfg
        self.hosts: Dict[int, HostState] = {
            h: HostState(h) for h in range(num_hosts)
        }
        self.strikes = np.zeros(num_hosts, np.int32)
        self.metrics = {
            k: np.array(v) for k, v in link_metrics_zeros(
                num_hosts, cfg.slope_window
            ).items()
        }
        self.excluded: Set[int] = set()
        self.events: List[Tuple[float, str, int]] = []

    # ---------------- heartbeat ingestion ---------------- #

    def heartbeat(self, host: int, now: float, step_time: float) -> None:
        hs = self.hosts[host]
        hs.last_beat = now
        hs.cum_step_time += step_time

    # ---------------- periodic evaluation ---------------- #

    def tick(self, now: float) -> Dict[str, List[int]]:
        """Run one detection tick. Returns {'failed': [...], 'stragglers': [...]}."""
        cfg = self.cfg
        n = len(self.hosts)
        active = [h for h in sorted(self.hosts) if h not in self.excluded]

        rows = np.zeros(n, np.float32)
        sync = np.zeros(n, np.float32)
        signal = np.zeros(n, bool)
        for h, hs in self.hosts.items():
            fresh = (now - hs.last_beat) < cfg.heartbeat_interval * 1.5
            rows[h] = 1.0 if fresh else 0.0
            signal[h] = fresh
            sync[h] = hs.cum_step_time

        import jax.numpy as jnp

        self.metrics = skew_models.update_metrics(
            {k: jnp.asarray(v) for k, v in self.metrics.items()},
            rows_this_tick=jnp.asarray(rows),
            sync_time_this_tick=jnp.asarray(
                sync - np.asarray(self.metrics["sync_window"])[:, -1]
            ),
            batch_density=jnp.asarray(rows),
            bytes_per_row=jnp.zeros(n),
            signal_this_tick=jnp.asarray(signal),
        )
        self.metrics = {k: np.array(v) for k, v in self.metrics.items()}

        failed = [
            h for h in active
            if self.metrics["idle_ticks"][h] >= cfg.missed_beats_dead
        ]

        # Straggler: Eq. (2) on cumulative step-time slopes with N-strikes.
        slopes = np.asarray(
            skew_models.sync_slope(jnp.asarray(self.metrics["sync_window"]))
        )
        mask = np.array([h in active for h in range(n)])
        others_mean = np.where(
            mask.sum() > 1,
            (slopes[mask].sum() - slopes) / max(mask.sum() - 1, 1),
            np.inf,
        )
        skewed = mask & (slopes * cfg.straggler_theta >= others_mean) & (
            slopes > 1e-9
        )
        self.strikes = np.where(skewed, self.strikes + 1, 0).astype(np.int32)
        stragglers = [
            h for h in active
            if self.strikes[h] >= cfg.n_strikes and h not in failed
        ]

        for h in failed:
            self.events.append((now, "failed", h))
        for h in stragglers:
            self.events.append((now, "straggler", h))
        return {"failed": failed, "stragglers": stragglers}

    # ---------------- elastic membership ------------------ #

    def exclude(self, hosts: List[int]) -> List[int]:
        """Remove hosts; returns the surviving host list (new mesh members)."""
        for h in hosts:
            if len(self.hosts) - len(self.excluded) <= self.cfg.min_hosts:
                break
            self.excluded.add(h)
            self.hosts[h].alive = False
            self.strikes[h] = 0
        return self.survivors()

    def survivors(self) -> List[int]:
        return [h for h in sorted(self.hosts) if h not in self.excluded]

    def rejoin(self, host: int, now: float) -> None:
        """A replaced/recovered host joins back (elastic scale-up)."""
        self.excluded.discard(host)
        hs = self.hosts[host]
        hs.alive = True
        hs.last_beat = now
        self.metrics["idle_ticks"][host] = 0.0
        # Clear the detection history, or the host flaps: leftover
        # strikes plus the pre-exclusion accelerating sync window would
        # re-flag it as a straggler on its first tick back.  Flattening
        # the window to the current cumulative step time makes the slope
        # zero AND keeps tick()'s `sync - window[:, -1]` delta correct
        # for the next heartbeat.
        self.strikes[host] = 0
        self.metrics["sync_window"][host, :] = hs.cum_step_time

    def mesh_shape(self, chips_per_host: int = 4) -> Tuple[int, int]:
        """The elastic mesh over the CURRENT survivor set — what the
        remesh after an exclusion/rejoin produces.  The simulator logs
        this at every membership change (``last_fault_stats['mesh_log']``)
        so fault scenarios record the mesh trajectory alongside recovery
        accounting."""
        return elastic_mesh_shape(
            len(self.survivors()), chips_per_host
        )


def elastic_mesh_shape(num_hosts: int, chips_per_host: int = 4) -> Tuple[int, int]:
    """Largest (data, model) mesh from surviving hosts: model axis fixed at
    16 where possible, data axis from whatever host count survived."""
    if num_hosts <= 0 or chips_per_host <= 0:
        # 0 hosts used to reach `chips // model` with model == 0
        # (ZeroDivisionError); an empty mesh is a caller error.
        raise ValueError(
            f"mesh needs at least one host and one chip per host, got "
            f"num_hosts={num_hosts}, chips_per_host={chips_per_host}"
        )
    chips = num_hosts * chips_per_host
    model = 16 if chips >= 16 else chips
    data = max(chips // model, 1)
    return (data, model)
