"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh) cell, in seconds:

  compute    = HLO_FLOPs_global    / (chips × peak_FLOP/s)
  memory     = HLO_bytes_global    / (chips × HBM_bw)
  collective = collective_bytes_gl / (chips × link_bw)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module, so
global quantities are per-device × chips (verified in tests); collective
bytes are parsed from the partitioned HLO text by summing operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _line_collective_kind(line: str) -> Optional[str]:
    """Kind of the collective DEFINED on this line (result = kind(...))."""
    for kind in _COLLECTIVE_KINDS:
        if re.search(rf"[ )}}] ?{kind}(-start)?\(", line):
            return kind
    return None


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _result_bytes(line: str, kind: str) -> int:
    """Sum shape literals in the result segment (before the op keyword).
    Optimized HLO prints operands without type prefixes, so the result
    shape(s) are the only literals on the line besides metadata."""
    cut = re.search(rf"{kind}(-start)?\(", line)
    head = line[: cut.start()] if cut else line
    if "=" in head:
        head = head.split("=", 1)[1]
    return sum(shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(head))


def collective_bytes_per_device(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Per-device ICI wire bytes of every collective, ring-model estimates:

      all-reduce         2·O·(S-1)/S     (O = operand = result)
      all-gather         O·(S-1)  = R·(S-1)/S
      reduce-scatter     O·(S-1)/S = R·(S-1)
      all-to-all         O·(S-1)/S
      collective-permute O

    Returns (total, per-kind breakdown).
    """
    total = 0.0
    by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        kind = _line_collective_kind(line)
        if kind is None:
            continue
        R = _result_bytes(line, kind)
        S = max(_group_size(line), 1)
        if kind == "all-reduce":
            wire = 2.0 * R * (S - 1) / S
        elif kind == "all-gather":
            wire = R * (S - 1) / S
        elif kind == "reduce-scatter":
            wire = R * (S - 1)
        elif kind == "all-to-all":
            wire = R * (S - 1) / S
        else:  # collective-permute
            wire = float(R)
        total += wire
        by_kind[kind] += int(wire)
    return int(total), by_kind


_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([^\s(]+)\s*\(.*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*\),\s*condition=%?([^\s,]+),\s*body=%?([^\s,]+)"
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def collective_bytes_loop_aware(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Per-device collective wire bytes with while-loop trip counts.

    XLA prints each while body once; collectives inside a scanned layer
    stack would be undercounted by num_layers×.  We reconstruct the
    computation graph from the HLO text, estimate each loop's trip count
    as the largest integer constant in its condition computation (XLA scan
    conditions compare the induction variable against the length), and
    multiply nested collective bytes accordingly.
    """
    comps: Dict[str, Dict] = {}
    entry = None
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _COMP_HEADER_RE.match(raw)
        if m and "{" in raw:
            cur = m.group(2)
            comps[cur] = {"coll": [], "whiles": [], "consts": []}
            if m.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        w = _WHILE_RE.search(line)
        if w:
            comps[cur]["whiles"].append((w.group(1), w.group(2)))
            continue
        kind = _line_collective_kind(line)
        if kind is not None:
            R = _result_bytes(line, kind)
            S = max(_group_size(line), 1)
            if kind == "all-reduce":
                wire = 2.0 * R * (S - 1) / S
            elif kind == "all-gather":
                wire = R * (S - 1) / S
            elif kind == "reduce-scatter":
                wire = R * (S - 1)
            elif kind == "all-to-all":
                wire = R * (S - 1) / S
            else:
                wire = float(R)
            comps[cur]["coll"].append((kind, wire))
        for c in _CONST_RE.findall(line):
            comps[cur]["consts"].append(int(c))

    def trip_count(cond_name: str) -> int:
        consts = comps.get(cond_name, {}).get("consts", [])
        return max([c for c in consts if 0 < c < 10_000_000] or [1])

    def total(comp_name: str, seen=()) -> Dict[str, float]:
        if comp_name not in comps or comp_name in seen:
            return {}
        out: Dict[str, float] = {}
        for kind, wire in comps[comp_name]["coll"]:
            out[kind] = out.get(kind, 0.0) + wire
        for cond, body in comps[comp_name]["whiles"]:
            n = trip_count(cond)
            inner = total(body, seen + (comp_name,))
            for kind, wire in inner.items():
                out[kind] = out.get(kind, 0.0) + n * wire
        return out

    if entry is None:
        return collective_bytes_per_device(hlo_text)
    by_kind_f = total(entry)
    by_kind = {k: int(by_kind_f.get(k, 0)) for k in _COLLECTIVE_KINDS}
    return int(sum(by_kind_f.values())), by_kind


@dataclasses.dataclass
class RooflineTerms:
    chips: int
    flops_global: float
    hbm_bytes_global: float
    collective_bytes_global: float
    by_kind: Dict[str, int]
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * hw.PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_global / (self.chips * hw.HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_global / (self.chips * hw.ICI_LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        if self.flops_global <= 0:
            return 0.0
        return self.model_flops / self.flops_global

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant term is to being 100% of the step time
        bound: useful work over the sum of terms (upper-bound fraction of
        roofline achievable if terms do not overlap)."""
        tot = self.t_compute + self.t_memory + self.t_collective
        if tot <= 0:
            return 0.0
        return max(self.t_compute, self.t_memory, self.t_collective) / tot

    def as_dict(self) -> Dict:
        return {
            "chips": self.chips,
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "collective_bytes_global": self.collective_bytes_global,
            "collective_by_kind": self.by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(
    n_active_params: int, tokens: int, kind: str
) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference forward)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens


def analyze(
    compiled,
    chips: int,
    model_flops: float,
    jaxpr_cost: Optional[Dict[str, float]] = None,
) -> RooflineTerms:
    """Build roofline terms from a compiled executable.

    flops/bytes come from the loop-aware jaxpr analyzer when provided
    (XLA cost_analysis undercounts while bodies); collectives come from the
    loop-aware HLO parser.
    """
    if jaxpr_cost is not None:
        flops_global = float(jaxpr_cost["flops"])
        bytes_global = float(jaxpr_cost["bytes"])
    else:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        flops_global = float(cost.get("flops", 0.0)) * chips
        bytes_global = float(cost.get("bytes accessed", 0.0)) * chips
    hlo = compiled.as_text()
    coll_dev, by_kind = collective_bytes_loop_aware(hlo)
    return RooflineTerms(
        chips=chips,
        flops_global=flops_global,
        hbm_bytes_global=bytes_global,
        collective_bytes_global=float(coll_dev) * chips,
        by_kind=by_kind,
        model_flops=model_flops,
    )
