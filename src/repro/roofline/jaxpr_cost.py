"""Jaxpr-based cost model: exact FLOPs and an HBM-traffic proxy with
correct loop accounting.

XLA's ``cost_analysis`` counts a while-loop body ONCE regardless of trip
count (verified in tests), which silently undercounts scanned-layer models
by ~num_layers×.  The jaxpr still carries every ``scan`` length, so this
module traverses the closed jaxpr recursively, multiplying nested costs by
scan lengths:

  flops — dot_general counted exactly (2·M·N·K from dimension_numbers);
          conv via im2col equivalence; everything else ≈ 1 flop/output elt.
  bytes — Σ (eqn input + output nbytes): an UNFUSED upper-bound proxy for
          HBM traffic.  Real TPU executables fuse elementwise chains, so
          absolute values overestimate; ratios across configurations (the
          hillclimb signal) are meaningful.  Documented in EXPERIMENTS.md.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.extend import core as jcore


def _aval_bytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _aval_elems(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _dot_flops(eqn) -> int:
    (lhs, rhs) = (eqn.invars[0].aval, eqn.invars[1].aval)
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    contract = math.prod(lhs.shape[d] for d in lc) or 1
    batch = math.prod(lhs.shape[d] for d in lb) or 1
    lhs_free = math.prod(
        lhs.shape[d] for d in range(len(lhs.shape)) if d not in lc and d not in lb
    ) or 1
    rhs_free = math.prod(
        rhs.shape[d] for d in range(len(rhs.shape)) if d not in rc and d not in rb
    ) or 1
    return 2 * batch * lhs_free * rhs_free * contract


def _conv_flops(eqn) -> int:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 × output elements × (kernel spatial × in-features)
    kernel = math.prod(rhs.shape[:-1]) if rhs.shape else 1
    return 2 * _aval_elems(out) * max(kernel // max(rhs.shape[-1], 1), 1)


_SUBJAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr", "cond_jaxpr", "body_jaxpr")


def _jaxpr_of(p):
    return p.jaxpr if hasattr(p, 'jaxpr') else p


def analyze_jaxpr(jaxpr) -> Dict[str, float]:
    """Returns {'flops', 'bytes'} for a (closed) jaxpr, loop-aware."""
    jaxpr = _jaxpr_of(jaxpr)
    flops = 0.0
    byts = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            inner = analyze_jaxpr(eqn.params["jaxpr"])
            length = eqn.params["length"]
            flops += inner["flops"] * length
            byts += inner["bytes"] * length
            continue
        if name == "while":
            # Not produced by our models (we use scan); count once.
            inner = analyze_jaxpr(eqn.params["body_jaxpr"])
            flops += inner["flops"]
            byts += inner["bytes"]
            continue
        if name == "cond":
            branches = [analyze_jaxpr(b) for b in eqn.params["branches"]]
            flops += max(b["flops"] for b in branches)
            byts += max(b["bytes"] for b in branches)
            continue
        handled_sub = False
        for key in _SUBJAXPR_PARAMS:
            if key in eqn.params:
                inner = analyze_jaxpr(eqn.params[key])
                flops += inner["flops"]
                byts += inner["bytes"]
                handled_sub = True
                break
        if handled_sub:
            continue
        if name == "dot_general":
            flops += _dot_flops(eqn)
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
        else:
            flops += sum(_aval_elems(o.aval) for o in eqn.outvars)
        byts += sum(
            _aval_bytes(v.aval) for v in eqn.invars if hasattr(v, "aval")
        )
        byts += sum(_aval_bytes(o.aval) for o in eqn.outvars)
    return {"flops": flops, "bytes": byts}


def trace_cost(fn, *args_abstract) -> Dict[str, float]:
    """Trace ``fn`` with abstract args and return global flops/bytes."""
    closed = jax.make_jaxpr(fn)(*args_abstract)
    return analyze_jaxpr(closed)
