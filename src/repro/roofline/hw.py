"""TPU v5e hardware constants (the dry-run's performance model targets)."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_LINK_BW = 50e9              # bytes/s per link (≈, as assigned)
CHIPS_SINGLE_POD = 256
CHIPS_MULTI_POD = 512
HBM_BYTES = 16 * 1024**3        # 16 GiB per chip
