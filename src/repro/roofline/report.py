"""Render the dry-run record directory into the EXPERIMENTS.md roofline
tables (and pick hillclimb candidates)."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List


def load_records(dirname: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        out.append(json.load(open(f)))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    out.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x*1e6:.1f}µs"
    if x < 0.1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.3f}s"


def fmt_bytes(x: float) -> str:
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def roofline_table(records: List[Dict], mesh: str = "single") -> str:
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| MODEL/HLO flops | coll bytes (global) | mem/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r.get("status") != "OK":
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | — | — | — | "
                f"{r['status'].split(':')[0]} |"
            )
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| **{t['bottleneck']}** | {t['useful_flops_ratio']:.3f} "
            f"| {fmt_bytes(t['collective_bytes_global'])} "
            f"| {r['memory']['per_device_total_gb']:.2f}GB "
            f"| {'✓' if r['memory']['fits_hbm'] else 'OVER'} |"
        )
    return "\n".join(rows)


def pick_hillclimb_candidates(records: List[Dict]) -> Dict[str, Dict]:
    """Worst roofline fraction, most collective-bound, most
    technique-representative (MoE train cell with the largest expert count)."""
    ok = [r for r in records if r.get("status") == "OK" and r["mesh"] == "single"]

    def frac(r):
        t = r["roofline"]
        tot = t["t_compute_s"] + t["t_memory_s"] + t["t_collective_s"]
        return t["t_compute_s"] / tot if tot else 0.0

    worst = min(ok, key=lambda r: (frac(r) if r["roofline"]["t_compute_s"] > 0
                                   else 1.0))
    coll = max(ok, key=lambda r: r["roofline"]["t_collective_s"])
    moe_train = [
        r for r in ok
        if r["shape"] == "train_4k" and "moe" in r["arch"] or
        r["arch"].startswith("kimi")
    ]
    rep = max(moe_train, key=lambda r: r["roofline"]["t_collective_s"]) \
        if moe_train else coll
    return {"worst_fraction": worst, "most_collective": coll,
            "technique_representative": rep}


if __name__ == "__main__":
    import sys

    recs = load_records(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    print("## Single-pod (16×16 = 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Multi-pod (2×16×16 = 512 chips)\n")
    print(roofline_table(recs, "multi"))
    picks = pick_hillclimb_candidates(recs)
    print("\nHillclimb candidates:")
    for k, r in picks.items():
        print(f"  {k}: {r['arch']} × {r['shape']}")
