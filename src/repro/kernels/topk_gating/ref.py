"""Pure-jnp oracle for fused top-k gating."""

import jax
import jax.numpy as jnp


def topk_gating_ref(logits: jax.Array, k: int):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx.astype(jnp.int32)
