"""Pallas kernel: fused softmax + top-k routing.

The MoE router's softmax→top-k→renormalize sequence runs on every token of
every MoE layer; fusing it avoids three HBM round-trips of the (T, E)
probability matrix.

Tiling: 1-D grid over token blocks; each instance holds a (BLOCK_T, E)
logits tile in VMEM, computes a numerically-stable softmax on the VPU,
then peels off the top-k entries with k iterative argmax+mask passes
(k ≤ 8 everywhere in the assignment, so unrolling is cheap and avoids a
sort network).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gating_kernel(logits_ref, w_ref, idx_ref, *, k: int):
    logits = logits_ref[...].astype(jnp.float32)        # (BLOCK_T, E)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)

    remaining = p
    tot = jnp.zeros((p.shape[0],), jnp.float32)
    ws, idxs = [], []
    for _ in range(k):
        top = jnp.argmax(remaining, axis=-1)            # (BLOCK_T,)
        wv = jnp.max(remaining, axis=-1)
        ws.append(wv)
        idxs.append(top)
        tot = tot + wv
        onehot = (
            jnp.arange(p.shape[-1], dtype=top.dtype)[None, :] == top[:, None]
        )
        remaining = jnp.where(onehot, -1.0, remaining)

    w = jnp.stack(ws, axis=-1)                          # (BLOCK_T, k)
    w = w / jnp.maximum(tot[:, None], 1e-9)             # renormalize
    idx = jnp.stack(idxs, axis=-1).astype(jnp.int32)
    w_ref[...] = w
    idx_ref[...] = idx


@functools.partial(jax.jit, static_argnames=("k", "block_t", "interpret"))
def topk_gating(
    logits: jax.Array,    # (T, E)
    *,
    k: int,
    block_t: int = 512,
    interpret: bool = True,
):
    """Returns (weights (T,k) fp32 renormalized, indices (T,k) int32)."""
    T, E = logits.shape
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)
    return pl.pallas_call(
        functools.partial(_gating_kernel, k=k),
        grid=(T // block_t,),
        in_specs=[pl.BlockSpec((block_t, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
