"""Jit'd public wrapper for fused top-k gating."""

import jax

from repro.kernels.topk_gating.kernel import topk_gating
from repro.kernels.topk_gating.ref import topk_gating_ref


def gating(logits, k: int, *, use_kernel: bool = True, **kw):
    if not use_kernel:
        return topk_gating_ref(logits, k)
    interpret = jax.default_backend() != "tpu"
    return topk_gating(logits, k=k, interpret=interpret, **kw)
