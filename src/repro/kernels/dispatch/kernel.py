"""Pallas kernel: routing-plan gather — the redistribution data movement.

Given activations ``x`` (T, D), a slot→source-token map ``src`` (S,) and a
slot validity mask, produce the dispatch buffer (S, D) with invalid slots
zeroed.  This is the hot inner loop of DySkew's redistribution on TPU: the
(G, E, C, d) MoE dispatch buffer and the serving-side request migration
buffers are both built from this primitive.

Tiling: grid over (slot blocks × feature blocks).  Each program instance
holds one (BLOCK_S, BLOCK_D) output tile and the full (T, BLOCK_D) stripe
of ``x`` in VMEM; rows are fetched with dynamic slices.  BLOCK_D is chosen
so the stripe fits VMEM (T·BLOCK_D·2 bytes ≤ ~4 MB for bf16); the MXU is
not involved (pure data movement) so lane alignment (128) is what matters.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dispatch_kernel(src_ref, valid_ref, x_ref, out_ref):
    """One (BLOCK_S, BLOCK_D) output tile.

    src_ref:   (BLOCK_S,) int32 — source row per slot
    valid_ref: (BLOCK_S,) int32 — 1 if the slot is filled
    x_ref:     (T, BLOCK_D)     — feature stripe of the source tokens
    out_ref:   (BLOCK_S, BLOCK_D)
    """
    block_s = out_ref.shape[0]

    def body(i, _):
        idx = src_ref[i]
        v = valid_ref[i]
        row = x_ref[pl.dslice(idx, 1), :]
        row = row * v.astype(row.dtype)
        out_ref[pl.dslice(i, 1), :] = row
        return 0

    jax.lax.fori_loop(0, block_s, body, 0)


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_d", "interpret")
)
def dispatch_gather(
    x: jax.Array,        # (T, D)
    src: jax.Array,      # (S,) int32 in [0, T)
    valid: jax.Array,    # (S,) bool/int
    *,
    block_s: int = 256,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Returns (S, D) dispatch buffer; invalid slots are zero."""
    T, D = x.shape
    S = src.shape[0]
    block_s = min(block_s, S)
    block_d = min(block_d, D)
    assert S % block_s == 0 and D % block_d == 0, (S, block_s, D, block_d)
    grid = (S // block_s, D // block_d)

    return pl.pallas_call(
        _dispatch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s,), lambda i, j: (i,)),
            pl.BlockSpec((block_s,), lambda i, j: (i,)),
            pl.BlockSpec((T, block_d), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_s, block_d), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((S, D), x.dtype),
        interpret=interpret,
    )(src.astype(jnp.int32), valid.astype(jnp.int32), x)
