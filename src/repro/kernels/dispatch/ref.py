"""Pure-jnp oracle for the dispatch gather."""

import jax
import jax.numpy as jnp


def dispatch_gather_ref(x: jax.Array, src: jax.Array, valid: jax.Array) -> jax.Array:
    """(T,D) × (S,) × (S,) → (S,D); invalid slots zeroed."""
    rows = x[src.astype(jnp.int32)]
    return rows * valid.astype(x.dtype)[:, None]
