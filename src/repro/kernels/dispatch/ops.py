"""Jit'd public wrapper for the dispatch-gather kernel."""

import jax

from repro.kernels.dispatch.kernel import dispatch_gather
from repro.kernels.dispatch.ref import dispatch_gather_ref


def dispatch(x, src, valid, *, use_kernel: bool = True, **kw):
    """Routing-plan gather. Kernel path (interpret on CPU, compiled on TPU)
    or the jnp reference."""
    if not use_kernel:
        return dispatch_gather_ref(x, src, valid)
    interpret = jax.default_backend() != "tpu"
    return dispatch_gather(x, src, valid, interpret=interpret, **kw)
