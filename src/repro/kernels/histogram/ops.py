"""Jit'd public wrapper for the load-histogram kernel."""

import jax

from repro.kernels.histogram.kernel import load_histogram
from repro.kernels.histogram.ref import load_histogram_ref


def histogram(ids, num_dest: int, *, use_kernel: bool = True, **kw):
    if not use_kernel:
        return load_histogram_ref(ids, num_dest)
    interpret = jax.default_backend() != "tpu"
    return load_histogram(ids, num_dest=num_dest, interpret=interpret, **kw)
