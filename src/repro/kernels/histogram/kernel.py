"""Pallas kernel: destination-load histogram (bincount).

Every DySkew decision consumes per-destination load counts — expert loads
in the MoE dispatch, per-shard token counts in the data path.  This kernel
computes ``counts[e] = |{i : ids[i] == e}|`` for E destinations.

Tiling: 1-D grid over id blocks; all grid steps accumulate into the same
(E,) output block (Pallas guarantees sequential grid order on TPU, so the
read-modify-write accumulation is safe).  Each block materializes a
(BLOCK_N, E) one-hot tile in VMEM — for E ≤ 512 and BLOCK_N = 1024 that is
≤ 2 MB fp32, well within budget, and the compare+reduce maps onto the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(ids_ref, out_ref, *, num_dest: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                                # (BLOCK_N,)
    onehot = (
        ids[:, None] == jnp.arange(num_dest, dtype=ids.dtype)[None, :]
    ).astype(jnp.float32)
    out_ref[...] += jnp.sum(onehot, axis=0)


@functools.partial(jax.jit, static_argnames=("num_dest", "block_n", "interpret"))
def load_histogram(
    ids: jax.Array,       # (N,) int32 in [0, num_dest)
    *,
    num_dest: int,
    block_n: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """Returns (num_dest,) float32 counts."""
    N = ids.shape[0]
    block_n = min(block_n, N)
    assert N % block_n == 0, (N, block_n)
    return pl.pallas_call(
        functools.partial(_hist_kernel, num_dest=num_dest),
        grid=(N // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_dest,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_dest,), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32))
