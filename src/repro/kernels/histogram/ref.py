"""Pure-jnp oracle for the load histogram."""

import jax
import jax.numpy as jnp


def load_histogram_ref(ids: jax.Array, num_dest: int) -> jax.Array:
    return jnp.zeros((num_dest,), jnp.float32).at[ids.astype(jnp.int32)].add(1.0)
