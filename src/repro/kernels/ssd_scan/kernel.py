"""Pallas kernel: Mamba-2 inter-chunk state recurrence.

The chunked SSD algorithm reduces each chunk to a (H, P, N) state
contribution plus a per-head decay; chaining them is a strictly sequential
recurrence over chunks:

    h_c = decay_c ⊙ h_{c-1} + s_c            (prefix of h fed back to chunk c)

This kernel computes all prefix states in one pass.  Tiling: grid over
(head blocks × P blocks); each program instance keeps its (C, BLOCK_H,
BLOCK_P, N) slice of the contributions in VMEM and walks the C chunks with
a fori_loop — the recurrence is latency-bound, so the win is keeping the
whole walk on-chip instead of C round-trips to HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_scan_kernel(states_ref, decay_ref, out_ref):
    """states_ref: (C, BH, BP, N); decay_ref: (C, BH); out_ref like states.

    out[c] = prefix state BEFORE chunk c (h_{c-1} in the recurrence).
    """
    C = states_ref.shape[0]
    h0 = jnp.zeros(states_ref.shape[1:], jnp.float32)

    def body(c, h):
        out_ref[pl.dslice(c, 1)] = h[None]
        d = decay_ref[c]                               # (BH,)
        s = states_ref[c]                              # (BH, BP, N)
        return h * d[:, None, None] + s.astype(jnp.float32)

    jax.lax.fori_loop(0, C, body, h0)


@functools.partial(jax.jit, static_argnames=("block_h", "block_p", "interpret"))
def ssd_state_scan(
    states: jax.Array,    # (C, H, P, N) per-chunk contributions
    decay: jax.Array,     # (C, H) per-chunk decays (exp of summed dA)
    *,
    block_h: int = 8,
    block_p: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Returns (C, H, P, N): the state entering each chunk."""
    C, H, P, N = states.shape
    block_h = min(block_h, H)
    block_p = min(block_p, P)
    assert H % block_h == 0 and P % block_p == 0
    grid = (H // block_h, P // block_p)
    return pl.pallas_call(
        _ssd_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, block_h, block_p, N), lambda i, j: (0, i, j, 0)),
            pl.BlockSpec((C, block_h), lambda i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec(
            (C, block_h, block_p, N), lambda i, j: (0, i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((C, H, P, N), jnp.float32),
        interpret=interpret,
    )(states, decay.astype(jnp.float32))
