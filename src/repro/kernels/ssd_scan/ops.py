"""Jit'd public wrapper for the SSD state scan."""

import jax

from repro.kernels.ssd_scan.kernel import ssd_state_scan
from repro.kernels.ssd_scan.ref import ssd_state_scan_ref


def state_scan(states, decay, *, use_kernel: bool = True, **kw):
    if not use_kernel:
        return ssd_state_scan_ref(states, decay)
    interpret = jax.default_backend() != "tpu"
    return ssd_state_scan(states, decay, interpret=interpret, **kw)
