"""Pure-jnp oracle for the SSD inter-chunk state recurrence."""

import jax
import jax.numpy as jnp


def ssd_state_scan_ref(states: jax.Array, decay: jax.Array) -> jax.Array:
    """(C,H,P,N), (C,H) → (C,H,P,N) prefix states (state entering chunk c)."""

    def body(h, inp):
        s, d = inp
        out = h
        h_new = h * d[:, None, None] + s.astype(jnp.float32)
        return h_new, out

    h0 = jnp.zeros(states.shape[1:], jnp.float32)
    _, prefix = jax.lax.scan(body, h0, (states, decay.astype(jnp.float32)))
    return prefix
