"""Pallas TPU kernels for the technique's hot data-movement paths.

dispatch     — routing-plan gather (the redistribution data movement)
histogram    — destination load counts (skew-model input, every step)
topk_gating  — fused softmax + top-k routing
ssd_scan     — Mamba-2 inter-chunk state recurrence

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU interpret fallback), ref.py (pure-jnp oracle).
"""
