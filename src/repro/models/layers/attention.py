"""Grouped-query attention with chunked (flash-style) softmax and KV cache.

The chunked path bounds the score-matrix working set to
(q_chunk × kv_chunk) per head group so 32k-token prefill fits VMEM-scale
memory budgets; XLA fuses the streaming softmax accumulators.  Decode
attends a single query step against the cache.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers.basic import apply_rope
from repro.models.param import spec
from repro.models.perf_flags import get_flags

NEG_INF = -1e30


def quantize_kv(x: jax.Array):
    """Symmetric int8 quantization per (batch, pos, kv-head) vector.
    x: (B, S, K, hd) → (int8 values, fp32 scales (B, S, K))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def attention_specs(cfg: ArchConfig) -> Dict:
    d, hd = cfg.d_model, cfg.head_dim_
    H, K = cfg.num_heads, cfg.num_kv_heads
    out = {
        "wq": spec((d, H, hd), ("embed", "heads", None)),
        "wk": spec((d, K, hd), ("embed", "kv_heads", None)),
        "wv": spec((d, K, hd), ("embed", "kv_heads", None)),
        "wo": spec((H, hd, d), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = spec((H, hd), ("heads", None), init="zeros")
        out["bk"] = spec((K, hd), ("kv_heads", None), init="zeros")
        out["bv"] = spec((K, hd), ("kv_heads", None), init="zeros")
    return out


def _project_qkv(p: Dict, x: jax.Array, cfg: ArchConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return q, k, v


def chunked_attention(
    q: jax.Array,              # (B, Sq, K, G, hd) grouped query heads
    k: jax.Array,              # (B, Skv, K, hd)
    v: jax.Array,              # (B, Skv, K, hd)
    *,
    causal: bool,
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    kv_len: Optional[jax.Array] = None,  # valid kv prefix length
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    k_scale: Optional[jax.Array] = None,   # (B, Skv, K) for int8 caches
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    """Streaming-softmax attention over kv chunks. Returns (B,Sq,K,G,hd).
    int8 k/v are dequantized per chunk inside the scan (bounded temps)."""
    B, Sq, K, G, hd = q.shape
    Skv = k.shape[1]
    scale = hd ** -0.5
    q = q * scale

    def _pick_chunk(n: int, target: int) -> int:
        # Largest divisor of n that is <= target (sequence lengths like
        # whisper's 1500 are not powers of two).
        for c in range(min(target, n), 0, -1):
            if n % c == 0:
                return c
        return n

    q_chunk = _pick_chunk(Sq, q_chunk)
    kv_chunk = _pick_chunk(Skv, kv_chunk)
    nq = Sq // q_chunk
    nkv = Skv // kv_chunk

    qc = q.reshape(B, nq, q_chunk, K, G, hd)
    kc = k.reshape(B, nkv, kv_chunk, K, hd)
    vc = v.reshape(B, nkv, kv_chunk, K, hd)
    ksc = (
        k_scale.reshape(B, nkv, kv_chunk, K) if k_scale is not None else
        jnp.zeros((B, nkv, kv_chunk, 0), jnp.float32)
    )
    vsc = (
        v_scale.reshape(B, nkv, kv_chunk, K) if v_scale is not None else
        jnp.zeros((B, nkv, kv_chunk, 0), jnp.float32)
    )

    q_pos_base = jnp.asarray(q_offset, jnp.int32)
    # H1 (perf): with a static q_offset and causal masking, kv chunks
    # beyond the diagonal are fully masked — the triangular schedule skips
    # them with per-q-chunk static trip counts (exact flop accounting).
    causal_skip = (
        get_flags().causal_skip and causal and isinstance(q_offset, int)
    )

    def one_q_chunk(qi, qblk, nkv_active=None):
        # qblk: (B, q_chunk, K, G, hd)
        q_pos = q_pos_base + qi * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kblk, vblk, ksblk, vsblk = inputs
            if kblk.dtype == jnp.int8:
                kblk = kblk.astype(qblk.dtype) * ksblk[..., None].astype(qblk.dtype)
                vblk = vblk.astype(qblk.dtype) * vsblk[..., None].astype(qblk.dtype)
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
            s = jnp.einsum("bqkgh,bckh->bkgqc", qblk, kblk).astype(jnp.float32)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
            if kv_len is not None:
                mask = jnp.logical_and(mask, (k_pos < kv_len)[None, :])
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(qblk.dtype), vblk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, hd), qblk.dtype)
        n_act = nkv if nkv_active is None else nkv_active
        ks = jnp.arange(n_act, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kc[:, :n_act], 1, 0),
             jnp.moveaxis(vc[:, :n_act], 1, 0),
             jnp.moveaxis(ksc[:, :n_act], 1, 0),
             jnp.moveaxis(vsc[:, :n_act], 1, 0)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (B, q_chunk, K, G, hd)

    if causal_skip:
        outs = []
        for qi in range(nq):
            last_pos = q_offset + (qi + 1) * q_chunk - 1
            n_act = min(nkv, last_pos // kv_chunk + 1)
            outs.append(one_q_chunk(
                jnp.asarray(qi, jnp.int32), qc[:, qi], nkv_active=n_act
            ))
        return jnp.stack(outs, axis=1).reshape(B, Sq, K, G, hd)

    outs = jax.lax.map(
        lambda args: one_q_chunk(*args),
        (jnp.arange(nq, dtype=jnp.int32), jnp.moveaxis(qc, 1, 0)),
    )  # (nq, B, q_chunk, K, G, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, K, G, hd)


def decode_attention(
    q: jax.Array,             # (B, 1, K, G, hd)
    k_cache: jax.Array,       # (B, S, K, hd) — model dtype or int8
    v_cache: jax.Array,
    kv_len: jax.Array,        # scalar/int — valid cache length (inclusive)
    k_scale: Optional[jax.Array] = None,   # (B, S, K) for int8 caches
    v_scale: Optional[jax.Array] = None,
) -> jax.Array:
    hd = q.shape[-1]
    kc = k_cache.astype(q.dtype) if k_cache.dtype == jnp.int8 else k_cache
    s = jnp.einsum("bqkgh,bckh->bkgqc", q * hd ** -0.5, kc)
    s = s.astype(jnp.float32)
    if k_scale is not None:
        # scores scale linearly in k: apply the per-(pos, head) scale after
        # the int8 dot (keeps the cache int8 end-to-end).
        s = s * jnp.transpose(k_scale, (0, 2, 1))[:, :, None, None, :]
    pos = jnp.arange(k_cache.shape[1])
    s = jnp.where((pos < kv_len)[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    vc = v_cache.astype(q.dtype) if v_cache.dtype == jnp.int8 else v_cache
    if v_scale is not None:
        p = p * jnp.transpose(v_scale, (0, 2, 1))[:, :, None, None, :].astype(p.dtype)
    out = jnp.einsum("bkgqc,bckh->bqkgh", p, vc)
    return out


def attention_apply(
    p: Dict,
    x: jax.Array,             # (B, S, d)
    *,
    cfg: ArchConfig,
    positions: jax.Array,     # (S,) or (B, S)
    causal: bool = True,
    cache: Optional[Dict] = None,  # {'k','v'[,'k_scale','v_scale']}
    cache_index: Optional[jax.Array] = None,              # write offset
    kv: Optional[jax.Array] = None,   # cross-attention source (B, Skv, d)
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (output (B,S,d), updated cache or None)."""
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    G = H // K

    if kv is None:
        q, k, v = _project_qkv(p, x, cfg)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", kv, p["wk"].astype(kv.dtype))
        v = jnp.einsum("bsd,dhk->bshk", kv, p["wv"].astype(kv.dtype))
        if cfg.qkv_bias:
            q = q + p["bq"].astype(x.dtype)
            k = k + p["bk"].astype(x.dtype)
            v = v + p["bv"].astype(x.dtype)

    if kv is None:  # self-attention gets RoPE
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = apply_rope(q, pos_b, cfg.rope_theta, cfg.rope_style)
        k_pos = pos_b
        k = apply_rope(k, k_pos, cfg.rope_theta, cfg.rope_style)

    qg = q.reshape(B, S, K, G, hd)

    new_cache = None
    if cache is not None:
        flags = get_flags()
        if flags.constrain_kv and flags.kv_pspec is not None:
            # H3: match the cache's (batch, seq→model) layout BEFORE the
            # dynamic_update_slice so GSPMD reshards the small fresh K/V
            # instead of involuntarily rematerializing the cache.
            k = jax.lax.with_sharding_constraint(k, flags.kv_pspec)
            v = jax.lax.with_sharding_constraint(v, flags.kv_pspec)
        quantized = "k_scale" in cache
        idx = cache_index if cache_index is not None else 0
        if quantized:
            kq, ks_new = quantize_kv(k)
            vq, vs_new = quantize_kv(v)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], kq, idx, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], vq, idx, axis=1)
            k_scale = jax.lax.dynamic_update_slice_in_dim(
                cache["k_scale"], ks_new, idx, axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(
                cache["v_scale"], vs_new, idx, axis=1)
            new_cache = {"k": k_cache, "v": v_cache,
                         "k_scale": k_scale, "v_scale": v_scale}
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k, idx, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v, idx, axis=1)
            k_scale = v_scale = None
            new_cache = {"k": k_cache, "v": v_cache}
        kv_len = (cache_index if cache_index is not None else 0) + S
        flags = get_flags()
        if (
            S > 1 and flags.causal_skip and flags.kv_pspec is not None
        ):
            # H1 companion: materialize the (seq-sharded) cache locally
            # ONCE per layer before the unrolled triangular q-chunk loop —
            # otherwise every chunk re-gathers its slice (9× collective
            # blowup measured on qwen prefill_32k).
            from jax.sharding import PartitionSpec as _P

            gather_spec = _P(flags.kv_pspec[0], None, None, None)
            k_cache = jax.lax.with_sharding_constraint(k_cache, gather_spec)
            v_cache = jax.lax.with_sharding_constraint(v_cache, gather_spec)
        if S == 1:
            out = decode_attention(qg, k_cache, v_cache, kv_len,
                                   k_scale=k_scale, v_scale=v_scale)
        else:
            out = chunked_attention(
                qg, k_cache, v_cache, causal=causal, q_offset=idx,
                kv_len=kv_len, q_chunk=q_chunk, kv_chunk=kv_chunk,
                k_scale=k_scale, v_scale=v_scale,
            )
    else:
        out = chunked_attention(
            qg, k, v, causal=causal, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )

    out = out.reshape(B, S, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


# ------------------------------- MLP ---------------------------------- #

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None) -> Dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": spec((d, f), ("embed", "mlp")),
            "w_up": spec((d, f), ("embed", "mlp")),
            "w_down": spec((f, d), ("mlp", "embed")),
        }
    return {
        "w_up": spec((d, f), ("embed", "mlp")),
        "w_down": spec((f, d), ("mlp", "embed")),
    }


def mlp_apply(p: Dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.mlp_act in ("swiglu", "geglu"):
        inner = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = inner(x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype)
        )
    else:
        from repro.models.layers.basic import act

        h = act(cfg.mlp_act, x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)
