"""Mixture-of-Experts with DySkew adaptive dispatch.

This is the paper's technique mapped to its TPU-native habitat: token →
expert routing under expert parallelism is exactly the 'rows → workers'
problem of Snowpark UDFs — arbitrary routing skew, opaque downstream cost,
and a fixed set of parallel consumers (the EP shards).

Mapping (DESIGN.md §2/§3):
  row            → token
  worker         → expert-parallel shard (model axis)
  link instance  → per-EP-shard state machine, carried across train steps
  legacy static  → uniform per-expert capacity (drops overflow, GShard)
  DySkew         → load-proportional effective capacity inside a fixed
                   buffer: idle shards' unused capacity is reassigned to
                   hot experts when the state machines commit to
                   redistribution (EAGER for training, LATE selectable)

Shapes are fully static: the dispatch buffer is (groups, E, C_buf, d) with
C_buf = headroom × uniform capacity; the *effective* per-expert capacity is
data, not shape.  Dispatch is gather-based (sort by expert, rank within
segment), so with batch sharded over ('pod','data') and experts over
'model', GSPMD tiles expert compute on the 2-D mesh without resharding the
buffer; only the expert outputs are gathered back per data shard.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.core import state_machine
from repro.core.types import DySkewConfig, Policy, link_state_init
from repro.models.param import spec
from repro.models.perf_flags import get_flags


@dataclasses.dataclass(frozen=True)
class SpmdCtx:
    """Static SPMD layout facts the layers need at trace time."""

    num_groups: int = 1        # data-parallel shards (token groups)
    num_ep_shards: int = 1     # expert-parallel shards (model axis)
    # Mesh axis names for activation sharding constraints (empty = no
    # constraints; requires an active mesh context when non-empty).
    batch_axes: tuple = ()
    model_axis: str = ""


def moe_dyskew_config(adaptive: bool) -> DySkewConfig:
    """EAGER = adaptive capacity from step 0 (the Snowpark policy);
    NEVER = the static uniform-capacity baseline."""
    return DySkewConfig(
        policy=Policy.EAGER_SNOWPARK if adaptive else Policy.NEVER,
        n_strikes=2,
        theta=0.7,
        # Token 'rows' are uniform d_model-sized vectors: the batch-density
        # heavy-row guard must never fire here.
        min_batch_density_frac=0.0,
        heavy_row_bytes=float("inf"),
    )


def moe_specs(cfg: ArchConfig) -> Dict:
    assert cfg.moe is not None
    d, E, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.expert_ff
    # Expert weights use a dedicated logical axis for their d_model dim:
    # by default it follows the FSDP rule ('embed'); the H10 hillclimb
    # change maps it to None (replicated) so the expert einsums contract
    # over an unsharded d and no data-axis partial reductions appear.
    return {
        "router": spec((d, E), ("embed", "experts"), scale=0.02),
        "w_gate": spec((E, d, f), ("experts", "expert_embed", None)),
        "w_up": spec((E, d, f), ("experts", "expert_embed", None)),
        "w_down": spec((E, f, d), ("experts", None, "expert_embed")),
    }


def moe_state_init(cfg: ArchConfig, ctx: SpmdCtx) -> Dict:
    """Carried DySkew state for ONE MoE layer (stack across layers outside)."""
    assert cfg.moe is not None
    dk = moe_dyskew_config(cfg.moe.adaptive)
    return {
        "link": link_state_init(ctx.num_ep_shards, dk),
        "ema_loads": jnp.full(
            (cfg.moe.num_experts,), 1.0 / cfg.moe.num_experts, jnp.float32
        ),
    }


def capacities(cfg: ArchConfig, tokens_per_group: int) -> Tuple[int, int]:
    """(uniform effective capacity, buffer capacity with DySkew headroom)."""
    moe = cfg.moe
    c_static = max(
        1,
        int(moe.capacity_factor * tokens_per_group * moe.top_k / moe.num_experts),
    )
    headroom = 2 if moe.adaptive else 1
    return c_static, c_static * headroom


def moe_apply(
    p: Dict,
    x: jax.Array,                    # (B, S, d)
    *,
    cfg: ArchConfig,
    state: Dict,                     # from moe_state_init
    ctx: SpmdCtx = SpmdCtx(),
) -> Tuple[jax.Array, Dict, Dict]:
    """Returns (y, new_state, metrics)."""
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    G = ctx.num_groups
    T = B * S
    assert T % G == 0, (T, G)
    Tg = T // G
    N = Tg * k
    c_static, c_buf = capacities(cfg, Tg)

    xt = x.reshape(G, Tg, d)

    # ---- Router ------------------------------------------------------- #
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)              # (G, Tg, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # ---- Sibling-observable load metrics (per EP shard) --------------- #
    flat_e = gate_e.reshape(G, N)
    counts = jnp.zeros((G, E), jnp.float32).at[
        jnp.arange(G)[:, None], flat_e
    ].add(1.0)
    # Global expert loads: the sum over (sharded) groups — GSPMD inserts the
    # cross-shard reduction ('state machines observe sibling instances').
    loads_e = counts.sum(axis=0)                           # (E,)
    per_shard = loads_e.reshape(ctx.num_ep_shards, E // ctx.num_ep_shards)
    shard_loads = per_shard.sum(axis=-1)                   # (n_ep,)

    # ---- DySkew state machines (one per EP shard) --------------------- #
    dk = moe_dyskew_config(moe.adaptive)
    bytes_per_row = jnp.full_like(shard_loads, 2.0 * d)
    new_link, distribute = state_machine.tick(
        state["link"],
        dk,
        rows_this_tick=shard_loads,
        sync_time_this_tick=shard_loads,   # cost ∝ tokens (uniform experts)
        batch_density=shard_loads,
        bytes_per_row=bytes_per_row,
        signal_this_tick=shard_loads > 0,
    )
    ema = 0.9 * state["ema_loads"] + 0.1 * loads_e / jnp.maximum(loads_e.sum(), 1.0)
    new_state = {"link": new_link, "ema_loads": ema}

    # ---- Effective capacity: the redistribution decision --------------- #
    # Static mode: uniform c_static. Distributing: load-proportional caps
    # inside the same total budget (idle capacity flows to hot experts).
    adaptive_caps = jnp.clip(
        jnp.round(ema * E * c_static), 1, c_buf
    ).astype(jnp.int32)
    n_ep = ctx.num_ep_shards
    shard_distribute = distribute.astype(jnp.int32)        # (n_ep,)
    expert_shard = jnp.arange(E) // (E // n_ep)
    use_adaptive = shard_distribute[expert_shard] > 0      # (E,)
    cap_e = jnp.where(use_adaptive, adaptive_caps, c_static)

    # ---- Sorted gather dispatch ---------------------------------------- #
    order = jnp.argsort(flat_e, axis=-1, stable=True)      # (G, N)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    seg_start = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.float32), jnp.cumsum(counts, axis=-1)[:, :-1]],
        axis=-1,
    )
    ranks = jnp.arange(N)[None, :] - jnp.take_along_axis(
        seg_start.astype(jnp.int32), sorted_e, axis=-1
    )
    keep = ranks < cap_e[sorted_e]
    slot_sorted = jnp.where(keep, sorted_e * c_buf + ranks, E * c_buf)

    g_idx = jnp.arange(G)[:, None]
    tok_sorted = order // k
    src = jnp.zeros((G, E * c_buf + 1), jnp.int32).at[g_idx, slot_sorted].set(
        tok_sorted.astype(jnp.int32), mode="drop"
    )
    filled = jnp.zeros((G, E * c_buf + 1), jnp.float32).at[
        g_idx, slot_sorted
    ].add(1.0, mode="drop")
    valid = (filled[:, : E * c_buf] > 0).astype(x.dtype)

    buf = jnp.take_along_axis(
        xt, src[:, : E * c_buf, None], axis=1
    ) * valid[..., None]
    buf = buf.reshape(G, E, c_buf, d)

    # ---- Expert computation (tiled on the (data × model) mesh) -------- #
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(x.dtype))
    ) * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(x.dtype))
    y_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    y_flat = y_buf.reshape(G, E * c_buf, d)

    if get_flags().moe_scatter_combine:
        # ---- H9 combine: scatter-add by token with weights placed on the
        # slots, producing per-EP-shard partials that reduce over the model
        # axis (T·d wire) instead of gathering the whole (E, C, d) buffer
        # (E·C·d wire) to every data shard.
        w_sorted = jnp.take_along_axis(
            gate_w.reshape(G, N), order, axis=-1
        ) * keep
        w_slot = jnp.zeros((G, E * c_buf + 1), jnp.float32).at[
            g_idx, slot_sorted
        ].add(w_sorted.astype(jnp.float32), mode="drop")
        contrib = y_flat * w_slot[:, : E * c_buf, None].astype(x.dtype)
        y = jnp.zeros((G, Tg, d), x.dtype).at[
            g_idx, src[:, : E * c_buf]
        ].add(contrib, mode="drop")
    else:
        # ---- Combine (unrolled over k to bound gather temporaries) ----- #
        slot_unsorted = jnp.zeros((G, N), jnp.int32).at[g_idx, order].set(
            slot_sorted
        )
        keep_unsorted = jnp.zeros((G, N), bool).at[g_idx, order].set(keep)
        slot_tk = slot_unsorted.reshape(G, Tg, k)
        keep_tk = keep_unsorted.reshape(G, Tg, k)
        y = jnp.zeros((G, Tg, d), x.dtype)
        for j in range(k):
            sj = jnp.minimum(slot_tk[:, :, j], E * c_buf - 1)
            yj = jnp.take_along_axis(y_flat, sj[:, :, None], axis=1)
            wj = (gate_w[:, :, j] * keep_tk[:, :, j]).astype(x.dtype)
            y = y + yj * wj[:, :, None]

    # ---- Telemetry ------------------------------------------------------ #
    dropped = 1.0 - keep.astype(jnp.float32).mean()
    imbalance = shard_loads.max() / jnp.maximum(shard_loads.mean(), 1.0)
    # Standard load-balancing auxiliary loss (Switch/GShard): E·Σ f_e·P_e.
    frac_tokens = loads_e / jnp.maximum(loads_e.sum(), 1.0)
    mean_prob = probs.mean(axis=(0, 1))
    aux_loss = E * jnp.sum(frac_tokens * mean_prob)
    metrics = {
        "moe_dropped_frac": dropped,
        "moe_shard_imbalance": imbalance,
        "moe_distribute_frac": distribute.astype(jnp.float32).mean(),
        "moe_aux_loss": aux_loss,
    }
    return y.reshape(B, S, d), new_state, metrics
