"""Mamba-2 (SSD — state-space duality) layer.

Train/prefill uses the chunked SSD algorithm (arXiv:2405.21060): quadratic
attention-like computation inside fixed-size chunks, linear recurrent state
passing between chunks (lax.scan), so memory is O(chunk²) per step instead
of O(S²).  Decode is the O(1) recurrent update.  A Pallas kernel for the
chunk computation lives in repro.kernels.ssd_scan; this module is the
reference path used by the models.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers.basic import norm_apply
from repro.models.param import spec
from repro.models.perf_flags import get_flags


def _dims(cfg: ArchConfig):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.d_inner(d)
    nh = mc.num_heads(d)
    hd = mc.head_dim
    g = max(nh // 8, 1)            # B/C groups (GQA-style state sharing)
    n = mc.d_state
    return d, di, nh, hd, g, n


def mamba_specs(cfg: ArchConfig) -> Dict:
    d, di, nh, hd, g, n = _dims(cfg)
    w = cfg.mamba.conv_width
    return {
        "w_z": spec((d, di), ("embed", "mlp")),
        "w_x": spec((d, di), ("embed", "mlp")),
        "w_B": spec((d, g, n), ("embed", None, None)),
        "w_C": spec((d, g, n), ("embed", None, None)),
        "w_dt": spec((d, nh), ("embed", "ssm_heads")),
        "dt_bias": spec((nh,), ("ssm_heads",), init="zeros"),
        "A_log": spec((nh,), ("ssm_heads",), init="zeros"),
        "D": spec((nh,), ("ssm_heads",), init="ones"),
        "conv_x": spec((w, di), ("conv", "mlp"), scale=0.5),
        "conv_B": spec((w, g, n), ("conv", None, None), scale=0.5),
        "conv_C": spec((w, g, n), ("conv", None, None), scale=0.5),
        "norm_scale": spec((di,), (None,), init="ones"),
        "w_out": spec((di, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """Depthwise causal conv along axis 1. x: (B, S, C), kernel: (W, C)."""
    w = kernel.shape[0]
    pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(w):
        out = out + pad[:, i : i + x.shape[1], :] * kernel[i]
    return out


def ssd_chunked(
    x: jax.Array,     # (B, S, H, P)
    dt: jax.Array,    # (B, S, H)  (post-softplus)
    A: jax.Array,     # (H,)  (negative)
    Bm: jax.Array,    # (B, S, G, N)
    Cm: jax.Array,    # (B, S, G, N)
    chunk: int,
    h0: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    L = chunk

    xc = x.reshape(Bsz, nc, L, H, P)
    dtc = dt.reshape(Bsz, nc, L, H)
    Bc = Bm.reshape(Bsz, nc, L, G, N)
    Cc = Cm.reshape(Bsz, nc, L, G, N)
    head_group = jnp.arange(H) // hpg

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def body(hstate, inp):
        xk, dtk, Bk, Ck = inp          # (B,L,H,P), (B,L,H), (B,L,G,N)
        da = (dtk * A).astype(jnp.float32)          # (B,L,H)
        da_cs = jnp.cumsum(da, axis=1)              # (B,L,H)
        da_total = da_cs[:, -1, :]                  # (B,H)

        # Intra-chunk (quadratic within chunk):
        CB = jnp.einsum("blgn,bmgn->bglm", Ck, Bk).astype(jnp.float32)
        CBh = CB[:, head_group]                     # (B,H,L,L)
        # Clamp the exponent: entries with i<j are masked out below, but
        # an inf forward value would still poison the backward pass
        # (0 * inf = NaN through the where).
        decay = jnp.exp(
            jnp.minimum(da_cs[:, :, None, :] - da_cs[:, None, :, :], 0.0)
        )                                           # (B,L,M,H) i>=j valid
        mask = jnp.tril(jnp.ones((L, L), bool))
        Smat = (
            CBh
            * jnp.transpose(decay, (0, 3, 1, 2))
            * dtk.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        )
        Smat = jnp.where(mask[None, None], Smat, 0.0)
        y_intra = jnp.einsum(
            "bhlm,bmhp->blhp", Smat, xk.astype(jnp.float32)
        )

        # Inter-chunk: contribution of the incoming state.
        Ch = Ck[:, :, head_group % G]               # (B,L,H,N)
        state_decay = jnp.exp(da_cs)                # (B,L,H)
        y_state = jnp.einsum(
            "blhn,bhpn->blhp", Ch * state_decay[..., None], hstate
        )

        # New state.
        w_in = jnp.exp(da_total[:, None, :] - da_cs) * dtk.astype(jnp.float32)
        Bh = Bk[:, :, head_group % G]               # (B,L,H,N)
        h_new = hstate * jnp.exp(da_total)[:, :, None, None] + jnp.einsum(
            "blhn,blhp->bhpn", Bh * w_in[..., None], xk.astype(jnp.float32)
        )
        return h_new, (y_intra + y_state).astype(x.dtype)

    hT, ys = jax.lax.scan(
        body,
        h0,
        (
            jnp.moveaxis(xc, 1, 0),
            jnp.moveaxis(dtc, 1, 0),
            jnp.moveaxis(Bc, 1, 0),
            jnp.moveaxis(Cc, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    return y, hT


def mamba_apply(
    p: Dict,
    xin: jax.Array,                 # (B, S, d)
    *,
    cfg: ArchConfig,
    state: Optional[Dict] = None,   # decode state {"ssm", "conv_x", "conv_B", "conv_C"}
) -> Tuple[jax.Array, Optional[Dict]]:
    """Full-sequence (train/prefill) when state is None; single-step decode
    otherwise. Returns (y (B,S,d), new_state or None)."""
    d, di, nh, hd, g, n = _dims(cfg)
    mc = cfg.mamba
    B, S, _ = xin.shape
    dtype = xin.dtype

    z = xin @ p["w_z"].astype(dtype)                      # (B,S,di)
    xproj = xin @ p["w_x"].astype(dtype)                  # (B,S,di)
    Bproj = jnp.einsum("bsd,dgn->bsgn", xin, p["w_B"].astype(dtype))
    Cproj = jnp.einsum("bsd,dgn->bsgn", xin, p["w_C"].astype(dtype))
    dt = xin @ p["w_dt"].astype(dtype)                    # (B,S,nh)

    flags = get_flags()
    if flags.constrain_mamba_acts and flags.act_pspec is not None:
        # H11: pin projection outputs to the batch-sharded layout so GSPMD
        # gathers the (small) FSDP weights instead of all-reducing the
        # (B,S,d_inner) partial products.
        z = jax.lax.with_sharding_constraint(z, flags.act_pspec)
        xproj = jax.lax.with_sharding_constraint(xproj, flags.act_pspec)
        dt = jax.lax.with_sharding_constraint(dt, flags.act_pspec)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if state is None or S > 1:
        # Full-sequence path (train, or prefill seeding a decode state).
        # Conv left-context comes from the carried window (zeros at pos 0).
        w = mc.conv_width

        def conv_full(xs, kernel, window):
            if window is not None:
                pad = jnp.concatenate([window.astype(dtype), xs], axis=1)
                out = jnp.zeros_like(xs)
                for i in range(w):
                    out = out + pad[:, i : i + S, :] * kernel[i]
                return out
            return _causal_conv(xs, kernel)

        st = state or {}
        xconv = jax.nn.silu(
            conv_full(xproj, p["conv_x"].astype(dtype), st.get("conv_x"))
        )
        Bco = jax.nn.silu(
            conv_full(
                Bproj.reshape(B, S, g * n),
                p["conv_B"].reshape(-1, g * n).astype(dtype),
                st.get("conv_B"),
            )
        ).reshape(B, S, g, n)
        Cco = jax.nn.silu(
            conv_full(
                Cproj.reshape(B, S, g * n),
                p["conv_C"].reshape(-1, g * n).astype(dtype),
                st.get("conv_C"),
            )
        ).reshape(B, S, g, n)
        xh = xconv.reshape(B, S, nh, hd)
        y, hT = ssd_chunked(
            xh, dt, A, Bco, Cco, min(mc.chunk, S), h0=st.get("ssm")
        )
        y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
        if state is not None:
            # Carry conv windows (last w-1 pre-activation inputs) + state.
            def tail(win, xs):
                full = jnp.concatenate([win.astype(dtype), xs], axis=1)
                return full[:, -(w - 1):, :]

            new_state = {
                "ssm": hT,
                "conv_x": tail(state["conv_x"], xproj),
                "conv_B": tail(state["conv_B"], Bproj.reshape(B, S, g * n)),
                "conv_C": tail(state["conv_C"], Cproj.reshape(B, S, g * n)),
            }
        else:
            new_state = None
    else:
        # Decode: roll conv windows, recurrent SSM update. S == 1.
        w = mc.conv_width

        def conv_step(window, xt, kernel):
            # window: (B, w-1, C); xt: (B, 1, C)
            full = jnp.concatenate([window, xt], axis=1)      # (B, w, C)
            out = jnp.einsum("bwc,wc->bc", full, kernel.astype(dtype))
            return full[:, 1:], out[:, None]

        cw_x, xconv = conv_step(state["conv_x"], xproj, p["conv_x"])
        cw_B, Bco = conv_step(
            state["conv_B"], Bproj.reshape(B, 1, g * n),
            p["conv_B"].reshape(w, g * n),
        )
        cw_C, Cco = conv_step(
            state["conv_C"], Cproj.reshape(B, 1, g * n),
            p["conv_C"].reshape(w, g * n),
        )
        xconv = jax.nn.silu(xconv)
        Bco = jax.nn.silu(Bco).reshape(B, g, n)
        Cco = jax.nn.silu(Cco).reshape(B, g, n)
        xh = xconv.reshape(B, nh, hd)

        hpg = nh // g
        head_group = jnp.arange(nh) // hpg
        dt1 = dt[:, 0]                                       # (B,nh)
        da = jnp.exp(dt1 * A)                                # (B,nh)
        Bh = Bco[:, head_group % g]                          # (B,nh,n)
        Ch = Cco[:, head_group % g]
        h_prev = state["ssm"]                                # (B,nh,hd,n)
        h_new = h_prev * da[..., None, None] + jnp.einsum(
            "bhn,bhp->bhpn", Bh * dt1[..., None], xh.astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
        y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
        y = y[:, None]                                       # (B,1,nh,hd)
        new_state = {"ssm": h_new, "conv_x": cw_x, "conv_B": cw_B, "conv_C": cw_C}

    y = y.reshape(B, S, di).astype(dtype)
    y = y * jax.nn.silu(z)
    y = norm_apply({"scale": p["norm_scale"]}, y, "rmsnorm")
    return y @ p["w_out"].astype(dtype), new_state


def mamba_state_init(cfg: ArchConfig, batch: int, dtype) -> Dict:
    d, di, nh, hd, g, n = _dims(cfg)
    w = cfg.mamba.conv_width
    return {
        "ssm": jnp.zeros((batch, nh, hd, n), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_B": jnp.zeros((batch, w - 1, g * n), dtype),
        "conv_C": jnp.zeros((batch, w - 1, g * n), dtype),
    }
