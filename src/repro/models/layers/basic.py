"""Norms, activations, embeddings, positional encodings."""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.param import spec


# ----------------------------- norms --------------------------------- #

def norm_specs(d: int, kind: str) -> Dict:
    if kind == "rmsnorm":
        return {"scale": spec((d,), (None,), init="ones")}
    return {
        "scale": spec((d,), (None,), init="ones"),
        "bias": spec((d,), (None,), init="zeros"),
    }


def norm_apply(p: Dict, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "silu":
        return jax.nn.silu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


# --------------------------- embeddings ------------------------------- #

def embedding_specs(vocab_padded: int, d: int) -> Dict:
    return {"table": spec((vocab_padded, d), ("vocab", "embed"), scale=1.0)}


def embed_apply(p: Dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def logits_apply(
    p: Dict, x: jax.Array, true_vocab: int
) -> jax.Array:
    """Tied/untied output head; pad-vocab logits masked to -inf."""
    table = p["table"].astype(x.dtype)
    logits = jnp.einsum("...d,vd->...v", x, table)
    vpad = table.shape[0]
    if vpad != true_vocab:
        mask = jnp.arange(vpad) < true_vocab
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    return logits


# ------------------------------ RoPE ---------------------------------- #

def rope_freqs(head_dim: int, theta: float, style: str) -> jax.Array:
    """Inverse frequencies. 'half' (ChatGLM 2-d RoPE) rotates only the
    first half of the head dim; 'full' rotates everything."""
    rot = head_dim if style == "full" else head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(
    x: jax.Array,            # (..., S, n, head_dim)
    positions: jax.Array,    # (..., S) int32
    theta: float,
    style: str,
) -> jax.Array:
    if style == "none":
        return x
    hd = x.shape[-1]
    rot = hd if style == "full" else hd // 2
    inv = rope_freqs(hd, theta, style)                       # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    if rot == hd:
        return rotated.astype(x.dtype)
    return jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
