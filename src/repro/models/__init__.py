"""Model zoo: unified transformer covering all assigned architectures."""
