"""Unified decoder-only transformer covering dense / MoE / SSM / hybrid
families (plus the VLM prefix-embedding variant).

Layers are grouped into *blocks* of ``period`` layers (period = lcm of the
attention interleave and the MoE every-other layout, e.g. 8 for Jamba) and
the block stack is driven by ``jax.lax.scan`` with per-leaf stacking on the
leading axis — one compiled block body regardless of depth, which keeps
512-device dry-run compiles tractable and bounds activation memory
together with ``jax.checkpoint``.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers import basic
from repro.models.layers.attention import (
    attention_apply,
    attention_specs,
    mlp_apply,
    mlp_specs,
)
from repro.models.layers.mamba2 import (
    mamba_apply,
    mamba_specs,
    mamba_state_init,
)
from repro.models.layers.moe import (
    SpmdCtx,
    moe_apply,
    moe_specs,
    moe_state_init,
)
from repro.models.param import ParamSpec, is_spec, spec
from repro.models.perf_flags import get_flags


def block_period(cfg: ArchConfig) -> int:
    period = cfg.attn_period
    if cfg.moe is not None and cfg.moe.layout == "every_other":
        period = int(math.lcm(period, 2))
    return period


def num_blocks(cfg: ArchConfig) -> int:
    period = block_period(cfg)
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return cfg.num_layers // period


# ------------------------------------------------------------------ #
# Parameter specs
# ------------------------------------------------------------------ #


def layer_specs(cfg: ArchConfig, layer_idx: int) -> Dict:
    """Specs for one layer (mixer + ffn + norms)."""
    out: Dict[str, Any] = {
        "norm1": basic.norm_specs(cfg.d_model, cfg.norm),
        "norm2": basic.norm_specs(cfg.d_model, cfg.norm),
    }
    if cfg.is_attention_layer(layer_idx) and cfg.num_heads > 0:
        out["attn"] = attention_specs(cfg)
    else:
        out["mamba"] = mamba_specs(cfg)
    if cfg.is_moe_layer(layer_idx):
        out["moe"] = moe_specs(cfg)
    elif cfg.d_ff > 0:
        out["ffn"] = mlp_specs(cfg)
    else:
        out.pop("norm2")
    return out


def _stack_specs(tree: Any, n: int) -> Any:
    def f(p: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + p.shape, (None,) + p.axes, p.init, p.scale, p.dtype)
    return jax.tree.map(f, tree, is_leaf=is_spec)


def model_specs(cfg: ArchConfig) -> Dict:
    period = block_period(cfg)
    nb = num_blocks(cfg)
    block = {f"l{j}": layer_specs(cfg, j) for j in range(period)}
    out = {
        "embed": basic.embedding_specs(cfg.padded_vocab, cfg.d_model),
        "blocks": _stack_specs(block, nb),
        "final_norm": basic.norm_specs(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = {
            "table": spec((cfg.padded_vocab, cfg.d_model), ("vocab", "embed"),
                          scale=0.02)
        }
    return out


# ------------------------------------------------------------------ #
# Runtime state (DySkew MoE links, KV caches, SSM states)
# ------------------------------------------------------------------ #


def moe_layer_positions(cfg: ArchConfig) -> Tuple[int, ...]:
    period = block_period(cfg)
    return tuple(j for j in range(period) if cfg.is_moe_layer(j))


def attn_layer_positions(cfg: ArchConfig) -> Tuple[int, ...]:
    period = block_period(cfg)
    return tuple(
        j for j in range(period)
        if cfg.is_attention_layer(j) and cfg.num_heads > 0
    )


def mamba_layer_positions(cfg: ArchConfig) -> Tuple[int, ...]:
    period = block_period(cfg)
    return tuple(
        j for j in range(period)
        if not (cfg.is_attention_layer(j) and cfg.num_heads > 0)
    )


def dyskew_states_init(cfg: ArchConfig, ctx: SpmdCtx) -> Dict:
    """Stacked per-block DySkew link state for every MoE position."""
    nb = num_blocks(cfg)
    out = {}
    for j in moe_layer_positions(cfg):
        one = moe_state_init(cfg, ctx)
        out[f"l{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (nb,) + a.shape), one
        )
    return out


def decode_state_init(
    cfg: ArchConfig, batch: int, max_seq: int, dtype
) -> Dict:
    """KV caches + SSM states + position counter for decode."""
    nb = num_blocks(cfg)
    K, hd = cfg.num_kv_heads, cfg.head_dim_
    out: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
    for j in attn_layer_positions(cfg):
        entry = {
            "k": jnp.zeros((nb, batch, max_seq, K, hd), kv_dt),
            "v": jnp.zeros((nb, batch, max_seq, K, hd), kv_dt),
        }
        if cfg.kv_cache_dtype == "int8":
            entry["k_scale"] = jnp.zeros((nb, batch, max_seq, K), jnp.float32)
            entry["v_scale"] = jnp.zeros((nb, batch, max_seq, K), jnp.float32)
        out[f"kv_l{j}"] = entry
    for j in mamba_layer_positions(cfg):
        one = mamba_state_init(cfg, batch, dtype)
        out[f"ssm_l{j}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (nb,) + a.shape).astype(a.dtype), one
        )
    return out


# ------------------------------------------------------------------ #
# Forward pass
# ------------------------------------------------------------------ #


def _apply_layer(
    lp: Dict,
    x: jax.Array,
    j: int,
    *,
    cfg: ArchConfig,
    ctx: SpmdCtx,
    positions: jax.Array,
    cache: Optional[Dict],
    cache_index: Optional[jax.Array],
    moe_state: Optional[Dict],
    metrics: Dict,
):
    """One layer: pre-norm mixer + pre-norm ffn with residuals."""
    new_cache = None
    new_moe_state = None
    h = basic.norm_apply(lp["norm1"], x, cfg.norm)
    if "attn" in lp:
        attn_out, new_cache = attention_apply(
            lp["attn"], h, cfg=cfg, positions=positions,
            cache=cache, cache_index=cache_index,
        )
        x = x + attn_out
    else:
        mamba_out, new_ssm = mamba_apply(
            lp["mamba"], h, cfg=cfg,
            state=cache,  # for mamba positions, 'cache' is the ssm state
        )
        new_cache = new_ssm
        x = x + mamba_out

    if "moe" in lp:
        h = basic.norm_apply(lp["norm2"], x, cfg.norm)
        # Stateless callers (e.g. serving without carried DySkew state) get
        # a fresh INIT-state link: uniform capacity on the first tick.
        stateless = moe_state is None
        ms = moe_state_init(cfg, ctx) if stateless else moe_state
        moe_out, new_moe_state, moe_metrics = moe_apply(
            lp["moe"], h, cfg=cfg, state=ms, ctx=ctx
        )
        if stateless:
            new_moe_state = None
        for k, v in moe_metrics.items():
            metrics[k] = metrics.get(k, 0.0) + v
        x = x + moe_out
    elif "ffn" in lp:
        h = basic.norm_apply(lp["norm2"], x, cfg.norm)
        x = x + mlp_apply(lp["ffn"], h, cfg)
    return x, new_cache, new_moe_state


def forward(
    params: Dict,
    tokens: jax.Array,               # (B, S) int32
    *,
    cfg: ArchConfig,
    ctx: SpmdCtx = SpmdCtx(),
    dyskew: Optional[Dict] = None,   # stacked MoE link states
    decode_state: Optional[Dict] = None,
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, d) VLM/audio stub
) -> Tuple[jax.Array, Dict]:
    """Returns (logits (B,S,V), aux) where aux carries new dyskew states,
    new decode state, and scalar metrics."""
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.dtype)

    flags = get_flags()
    if flags.constrain_activations and ctx.batch_axes:
        from jax.sharding import PartitionSpec as _P

        def constrain(t):
            # (B, S, d): batch over dp axes, rest replicated.
            return jax.lax.with_sharding_constraint(
                t, _P(ctx.batch_axes, None, None)
            )
    else:
        def constrain(t):
            return t

    x = basic.embed_apply(params["embed"], tokens, dtype)
    x = constrain(x)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        pos = jnp.arange(S)[None, :, None]
        pref = jnp.pad(
            prefix_embeds.astype(dtype), ((0, 0), (0, S - P), (0, 0))
        )
        x = jnp.where(pos < P, pref, x)

    if decode_state is not None:
        if S > 1:
            # Prefill is always from position 0 (single-shot prompt
            # ingestion); the static offset lets the causal-skip schedule
            # drop fully-masked kv chunks.
            start = 0
            cache_index = 0
        else:
            start = decode_state["pos"]
            cache_index = start
        positions = start + jnp.arange(S, dtype=jnp.int32)
    else:
        positions = jnp.arange(S, dtype=jnp.int32)
        cache_index = None

    period = block_period(cfg)
    nb = num_blocks(cfg)
    attn_pos = attn_layer_positions(cfg)
    mamba_pos = mamba_layer_positions(cfg)
    moe_pos = moe_layer_positions(cfg)

    def block_body(x, scanned):
        bp = scanned["params"]
        metrics: Dict[str, jax.Array] = {}
        out_caches = {}
        out_moe = {}
        for j in range(period):
            if j in attn_pos:
                cache_j = scanned.get(f"kv_l{j}")
            elif j in mamba_pos and decode_state is not None:
                cache_j = scanned.get(f"ssm_l{j}")
            else:
                cache_j = None
            moe_state_j = scanned.get(f"moe_l{j}")
            x, new_cache, new_moe = _apply_layer(
                bp[f"l{j}"], x, j, cfg=cfg, ctx=ctx, positions=positions,
                cache=cache_j, cache_index=cache_index,
                moe_state=moe_state_j, metrics=metrics,
            )
            x = constrain(x)
            if new_cache is not None:
                key = f"kv_l{j}" if j in attn_pos else f"ssm_l{j}"
                out_caches[key] = new_cache
            if new_moe is not None:
                out_moe[f"moe_l{j}"] = new_moe
        return x, {"caches": out_caches, "moe": out_moe, "metrics": metrics}

    scanned_in: Dict[str, Any] = {"params": params["blocks"]}
    if decode_state is not None:
        for j in attn_pos:
            scanned_in[f"kv_l{j}"] = decode_state[f"kv_l{j}"]
        for j in mamba_pos:
            scanned_in[f"ssm_l{j}"] = decode_state[f"ssm_l{j}"]
    if dyskew is not None:
        for j in moe_pos:
            scanned_in[f"moe_l{j}"] = dyskew[f"l{j}"]

    body = block_body
    if cfg.remat:
        body = jax.checkpoint(
            block_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    x, stacked_out = jax.lax.scan(body, x, scanned_in)

    x = basic.norm_apply(params["final_norm"], x, cfg.norm)
    head = params.get("lm_head", params["embed"])
    logits = basic.logits_apply(head, x, cfg.vocab_size)

    aux: Dict[str, Any] = {
        "metrics": {
            k: v.mean() for k, v in stacked_out["metrics"].items()
        } if stacked_out["metrics"] else {},
    }
    if dyskew is not None:
        aux["dyskew"] = {
            j_key.replace("moe_", ""): v
            for j_key, v in stacked_out["moe"].items()
        }
    if decode_state is not None:
        new_state = dict(decode_state)
        for key, v in stacked_out["caches"].items():
            new_state[key] = v
        new_state["pos"] = decode_state["pos"] + S
        aux["decode_state"] = new_state
    return logits, aux


# ------------------------------------------------------------------ #
# Losses
# ------------------------------------------------------------------ #


def lm_loss(
    logits: jax.Array,       # (B, S, V)
    targets: jax.Array,      # (B, S) int32, -1 = masked
    z_loss: float = 1e-4,
) -> jax.Array:
    V = logits.shape[-1]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    return (nll.sum() + zl.sum()) / denom
