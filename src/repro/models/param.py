"""Parameter specs with logical sharding axes.

Every model parameter is declared as a ``ParamSpec`` carrying its shape and
*logical* axis names ("embed", "heads", "mlp", "experts", "vocab", ...).
A rule table maps logical axes to mesh axes (MaxText-style), with automatic
fallback to replication when a dimension is not divisible by the assigned
mesh-axis product — this is what lets e.g. MQA's single KV head or
whisper's 8 heads compile on a 16-way model axis.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]
MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"            # normal | zeros | ones
    scale: Optional[float] = None   # default: 1/sqrt(fan_in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape: Sequence[int], axes: Sequence[Optional[str]], *,
         init: str = "normal", scale: Optional[float] = None,
         dtype: Any = jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


#: Default logical→mesh rules for the production meshes.
#: 'fsdp' axes shard the big non-model dimension of every weight.
def default_rules(multi_pod: bool) -> Dict[str, MeshAxes]:
    fsdp: MeshAxes = ("pod", "data") if multi_pod else ("data",)
    return {
        "embed": fsdp,        # d_model dim of weights (FSDP)
        "expert_embed": fsdp, # d_model dim of expert weights (H10: None)
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "ssm_heads": "model",
        "conv": None,
        None: None,
    }


def _axis_size(mesh: Mesh, mesh_axes: MeshAxes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        return mesh.shape[mesh_axes]
    return math.prod(mesh.shape[a] for a in mesh_axes)


def resolve_pspec(
    p: ParamSpec, mesh: Mesh, rules: Dict[str, MeshAxes]
) -> P:
    """Logical axes → PartitionSpec with divisibility fallback."""
    out = []
    used: set = set()
    for dim, ax in zip(p.shape, p.axes):
        mesh_axes = rules.get(ax, None)
        if mesh_axes is None:
            out.append(None)
            continue
        names = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        # A mesh axis may appear at most once in a PartitionSpec.
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        size = math.prod(mesh.shape[n] for n in names) if names else 1
        if names and dim % size == 0:
            out.append(names if len(names) > 1 else names[0])
            used.update(names)
        else:
            out.append(None)
    return P(*out)


def tree_pspecs(tree: Any, mesh: Mesh, rules: Dict[str, MeshAxes]) -> Any:
    return jax.tree.map(
        lambda p: resolve_pspec(p, mesh, rules), tree, is_leaf=is_spec
    )


def tree_shardings(tree: Any, mesh: Mesh, rules: Dict[str, MeshAxes]) -> Any:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, resolve_pspec(p, mesh, rules)),
        tree, is_leaf=is_spec,
    )


def tree_abstract(tree: Any, dtype_override: Any = None) -> Any:
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    def f(p: ParamSpec):
        return jax.ShapeDtypeStruct(
            p.shape, dtype_override if dtype_override is not None else p.dtype
        )
    return jax.tree.map(f, tree, is_leaf=is_spec)


def tree_materialize(tree: Any, key: jax.Array, dtype_override: Any = None) -> Any:
    """Real initialization for smoke tests / small-scale training."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        dt = dtype_override if dtype_override is not None else p.dtype
        if p.init == "zeros":
            out.append(jnp.zeros(p.shape, dt))
        elif p.init == "ones":
            out.append(jnp.ones(p.shape, dt))
        else:
            fan_in = p.shape[0] if len(p.shape) >= 2 else max(p.shape[-1], 1)
            scale = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
            out.append(scale * jax.random.normal(k, p.shape, dt))
    return jax.tree.unflatten(treedef, out)


def tree_num_params(tree: Any) -> int:
    return sum(
        math.prod(p.shape)
        for p in jax.tree.leaves(tree, is_leaf=is_spec)
    )
