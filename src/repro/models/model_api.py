"""Unified model API: specs / init / loss / prefill / decode per family.

Everything the launchers, trainers and the dry-run need, behind one
interface, for all ten assigned architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models import encdec, transformer
from repro.models.layers.mamba2 import _dims as mamba_dims
from repro.models.layers.moe import SpmdCtx
from repro.models.param import (
    spec,
    tree_abstract,
    tree_materialize,
    tree_num_params,
)

MOE_AUX_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ---------------- parameters ---------------- #

    def specs(self) -> Dict:
        if self.cfg.family == "encdec":
            return encdec.model_specs(self.cfg)
        return transformer.model_specs(self.cfg)

    def init(self, key: jax.Array, dtype=None) -> Dict:
        dt = dtype if dtype is not None else jnp.dtype(self.cfg.dtype)
        return tree_materialize(self.specs(), key, dtype_override=dt)

    def abstract_params(self, dtype=None) -> Dict:
        dt = dtype if dtype is not None else jnp.dtype(self.cfg.dtype)
        return tree_abstract(self.specs(), dtype_override=dt)

    def num_params(self) -> int:
        return tree_num_params(self.specs())

    # ---------------- training ------------------ #

    def loss(
        self,
        params: Dict,
        batch: Dict[str, jax.Array],
        *,
        dyskew: Optional[Dict] = None,
        ctx: SpmdCtx = SpmdCtx(),
    ) -> Tuple[jax.Array, Dict]:
        """batch: tokens (B,S), targets (B,S), optional frames/patches."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_out = encdec.encode(params, batch["frames"], cfg)
            logits, aux = encdec.forward(
                params, batch["tokens"], cfg=cfg, enc_out=enc_out
            )
        else:
            logits, aux = transformer.forward(
                params, batch["tokens"], cfg=cfg, ctx=ctx, dyskew=dyskew,
                prefix_embeds=batch.get("patches"),
            )
        loss = transformer.lm_loss(logits, batch["targets"])
        metrics = dict(aux.get("metrics", {}))
        if "moe_aux_loss" in metrics:
            loss = loss + MOE_AUX_COEF * metrics["moe_aux_loss"]
        metrics["loss"] = loss
        aux = dict(aux, metrics=metrics)
        return loss, aux

    # ---------------- serving ------------------- #

    def decode_state_init(self, batch: int, max_seq: int) -> Dict:
        dt = jnp.dtype(self.cfg.dtype)
        if self.cfg.family == "encdec":
            return encdec.decode_state_init(self.cfg, batch, max_seq, dt)
        return transformer.decode_state_init(self.cfg, batch, max_seq, dt)

    def decode_state_specs(self, batch: int, max_seq: int) -> Dict:
        """ParamSpec tree mirroring decode_state_init (for dry-run
        shardings); shapes asserted against the real init in tests."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        K, hd = cfg.num_kv_heads, cfg.head_dim_
        int8 = cfg.kv_cache_dtype == "int8"
        kv_dt = jnp.int8 if int8 else dt

        def kv_specs(nb: int, seq: int) -> Dict:
            shape = (nb, batch, seq, K, hd)
            axes = (None, "batch", "kv_seq", "kv_heads", None)
            out = {
                "k": spec(shape, axes, dtype=kv_dt),
                "v": spec(shape, axes, dtype=kv_dt),
            }
            if int8:
                out["k_scale"] = spec(shape[:-1], axes[:-1], dtype=jnp.float32)
                out["v_scale"] = spec(shape[:-1], axes[:-1], dtype=jnp.float32)
            return out

        out: Dict[str, Any] = {
            "pos": spec((), (), dtype=jnp.int32, init="zeros")
        }
        if cfg.family == "encdec":
            nb = cfg.num_layers
            out["kv_self"] = kv_specs(nb, max_seq)
            out["kv_cross"] = kv_specs(nb, cfg.encoder_len)
            return out

        nb = transformer.num_blocks(cfg)
        for j in transformer.attn_layer_positions(cfg):
            out[f"kv_l{j}"] = kv_specs(nb, max_seq)
        if cfg.mamba is not None:
            d, di, nh, hd_m, g, n = mamba_dims(cfg)
            w = cfg.mamba.conv_width
            for j in transformer.mamba_layer_positions(cfg):
                out[f"ssm_l{j}"] = {
                    "ssm": spec((nb, batch, nh, hd_m, n),
                                (None, "batch", "ssm_heads", None, None),
                                dtype=jnp.float32),
                    "conv_x": spec((nb, batch, w - 1, di),
                                   (None, "batch", None, "mlp"), dtype=dt),
                    "conv_B": spec((nb, batch, w - 1, g * n),
                                   (None, "batch", None, None), dtype=dt),
                    "conv_C": spec((nb, batch, w - 1, g * n),
                                   (None, "batch", None, None), dtype=dt),
                }
        return out

    def prefill(
        self,
        params: Dict,
        inputs: Dict[str, jax.Array],
        state: Dict,
        *,
        ctx: SpmdCtx = SpmdCtx(),
        dyskew: Optional[Dict] = None,
    ) -> Tuple[jax.Array, Dict]:
        """Process the prompt, filling caches. Returns (logits, new_state)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            enc_out = encdec.encode(params, inputs["frames"], cfg)
            logits, aux = encdec.forward(
                params, inputs["tokens"], cfg=cfg, enc_out=enc_out,
                decode_state=state,
            )
        else:
            logits, aux = transformer.forward(
                params, inputs["tokens"], cfg=cfg, ctx=ctx, dyskew=dyskew,
                decode_state=state, prefix_embeds=inputs.get("patches"),
            )
        return logits, aux["decode_state"]

    def decode_step(
        self,
        params: Dict,
        state: Dict,
        token: jax.Array,               # (B, 1) int32
        *,
        ctx: SpmdCtx = SpmdCtx(),
        dyskew: Optional[Dict] = None,
    ) -> Tuple[jax.Array, Dict]:
        """One decode step. Returns (logits (B,1,V), new_state)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, aux = encdec.forward(
                params, token, cfg=cfg, enc_out=None, decode_state=state
            )
        else:
            logits, aux = transformer.forward(
                params, token, cfg=cfg, ctx=ctx, dyskew=dyskew,
                decode_state=state,
            )
        return logits, aux["decode_state"]

    def dyskew_init(self, ctx: SpmdCtx = SpmdCtx()) -> Optional[Dict]:
        if self.cfg.moe is None or self.cfg.family == "encdec":
            return None
        return transformer.dyskew_states_init(self.cfg, ctx)


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)
