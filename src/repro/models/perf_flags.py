"""Performance-tuning flags (the §Perf hillclimb knobs).

Defaults are the straightforward baseline implementation; each flag is one
hypothesis→change pair recorded in EXPERIMENTS.md §Perf.  Flags live in a
contextvar so the dry-run can A/B compile without touching model code.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    # H1: skip fully-masked kv chunks in causal attention (triangular
    # schedule with per-q-chunk static trip counts) — targets the ~2×
    # causal flop waste visible in useful_flops_ratio.
    causal_skip: bool = False
    # H2: cast FSDP-sharded fp32 master params to bf16 BEFORE the implicit
    # all-gather (explicit pre-cast site) — targets gather bytes in
    # collective-bound train cells.
    cast_before_gather: bool = False
    # H3: constrain freshly-computed K/V to the cache's sharding before the
    # dynamic_update_slice — targets GSPMD 'involuntary full
    # rematerialization' resharding in prefill cells.
    constrain_kv: bool = False
    # H4 (decode): flash-decode style seq-sharded attention combine.
    flash_decode_combine: bool = False
    # H3 support: PartitionSpec for freshly-computed K/V (set by launchers
    # under a mesh context; None disables the constraint).
    kv_pspec: object = None
    # H5: constrain activations to batch-sharded layout at layer boundaries
    # (stops GSPMD from replicating activations over the data axis and
    # all-reducing giant activation tensors).
    constrain_activations: bool = False
    # H8: constrain gradients to the parameter sharding right after the
    # backward pass so cross-batch reduction lowers to reduce-scatter
    # (half the wire bytes of the all-reduce GSPMD otherwise picks).
    constrain_grads: bool = False
    # H9: MoE combine via scatter-add + model-axis psum of (tokens × d)
    # partials, instead of gathering the full E-sharded (E, C, d) expert
    # output buffer to every data shard.
    moe_scatter_combine: bool = False
    # H11: pin mamba projection outputs to batch-sharded layout (set
    # act_pspec) — otherwise GSPMD all-reduces the (B,S,d_inner) partials
    # of the FSDP-sharded projections instead of gathering weights.
    constrain_mamba_acts: bool = False
    # PartitionSpec for (B, S, ·) activations (set by launchers).
    act_pspec: object = None


_FLAGS: contextvars.ContextVar[PerfFlags] = contextvars.ContextVar(
    "perf_flags", default=PerfFlags()
)


def get_flags() -> PerfFlags:
    return _FLAGS.get()


@contextlib.contextmanager
def use_flags(flags: PerfFlags):
    token = _FLAGS.set(flags)
    try:
        yield
    finally:
        _FLAGS.reset(token)
