"""Encoder-decoder transformer (Whisper-style backbone).

The audio frontend (log-mel + conv subsampling) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
(B, T_enc, d).  Positions are sinusoidal (keeps parameter shapes
independent of the lowered sequence length; Whisper's learned decoder
positions are a documented deviation in DESIGN.md).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers import basic
from repro.models.layers.attention import (
    attention_apply,
    attention_specs,
    mlp_apply,
    mlp_specs,
)
from repro.models.param import ParamSpec, is_spec
from repro.models.transformer import _stack_specs


def sinusoidal(positions: jax.Array, d: int, dtype) -> jax.Array:
    """(S,) → (S, d) standard sin/cos embedding."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _enc_layer_specs(cfg: ArchConfig) -> Dict:
    return {
        "norm1": basic.norm_specs(cfg.d_model, cfg.norm),
        "attn": attention_specs(cfg),
        "norm2": basic.norm_specs(cfg.d_model, cfg.norm),
        "ffn": mlp_specs(cfg),
    }


def _dec_layer_specs(cfg: ArchConfig) -> Dict:
    return {
        "norm1": basic.norm_specs(cfg.d_model, cfg.norm),
        "attn": attention_specs(cfg),
        "norm_x": basic.norm_specs(cfg.d_model, cfg.norm),
        "cross": attention_specs(cfg),
        "norm2": basic.norm_specs(cfg.d_model, cfg.norm),
        "ffn": mlp_specs(cfg),
    }


def model_specs(cfg: ArchConfig) -> Dict:
    out = {
        "embed": basic.embedding_specs(cfg.padded_vocab, cfg.d_model),
        "enc_blocks": _stack_specs({"l0": _enc_layer_specs(cfg)}, cfg.encoder_layers),
        "enc_final_norm": basic.norm_specs(cfg.d_model, cfg.norm),
        "blocks": _stack_specs({"l0": _dec_layer_specs(cfg)}, cfg.num_layers),
        "final_norm": basic.norm_specs(cfg.d_model, cfg.norm),
    }
    return out


def encode(params: Dict, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, T_enc, d) stubbed frame embeddings → (B, T_enc, d)."""
    dtype = jnp.dtype(cfg.dtype)
    T = frames.shape[1]
    x = frames.astype(dtype) + sinusoidal(jnp.arange(T), cfg.d_model, dtype)
    positions = jnp.arange(T, dtype=jnp.int32)

    def body(x, bp):
        lp = bp["l0"]
        h = basic.norm_apply(lp["norm1"], x, cfg.norm)
        a, _ = attention_apply(lp["attn"], h, cfg=cfg, positions=positions,
                               causal=False)
        x = x + a
        h = basic.norm_apply(lp["norm2"], x, cfg.norm)
        return x + mlp_apply(lp["ffn"], h, cfg), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return basic.norm_apply(params["enc_final_norm"], x, cfg.norm)


def decode_state_init(
    cfg: ArchConfig, batch: int, max_seq: int, dtype
) -> Dict:
    nb = cfg.num_layers
    K, hd = cfg.num_kv_heads, cfg.head_dim_
    T = cfg.encoder_len
    return {
        "pos": jnp.zeros((), jnp.int32),
        "kv_self": {
            "k": jnp.zeros((nb, batch, max_seq, K, hd), dtype),
            "v": jnp.zeros((nb, batch, max_seq, K, hd), dtype),
        },
        # Cross K/V computed once from the encoder output at prefill.
        "kv_cross": {
            "k": jnp.zeros((nb, batch, T, K, hd), dtype),
            "v": jnp.zeros((nb, batch, T, K, hd), dtype),
        },
    }


def forward(
    params: Dict,
    tokens: jax.Array,                    # (B, S)
    *,
    cfg: ArchConfig,
    enc_out: Optional[jax.Array] = None,  # (B, T, d); None during decode
    decode_state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """Decoder forward. During prefill pass enc_out (cross K/V get built and
    cached); during decode pass decode_state only."""
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    if decode_state is not None:
        start = decode_state["pos"]
    else:
        start = jnp.zeros((), jnp.int32)
    positions = start + jnp.arange(S, dtype=jnp.int32)
    x = basic.embed_apply(params["embed"], tokens, dtype)
    x = x + sinusoidal(positions, cfg.d_model, dtype)

    scanned: Dict[str, Any] = {"params": params["blocks"]}
    use_cache = decode_state is not None
    if use_cache:
        scanned["kv_self"] = decode_state["kv_self"]
        scanned["kv_cross"] = decode_state["kv_cross"]

    def body(x, sc):
        lp = sc["params"]["l0"]
        h = basic.norm_apply(lp["norm1"], x, cfg.norm)
        out_caches = {}
        if use_cache:
            a, new_self = attention_apply(
                lp["attn"], h, cfg=cfg, positions=positions,
                cache=sc["kv_self"], cache_index=start,
            )
            out_caches["kv_self"] = new_self
        else:
            a, _ = attention_apply(lp["attn"], h, cfg=cfg, positions=positions)
        x = x + a

        h = basic.norm_apply(lp["norm_x"], x, cfg.norm)
        if enc_out is not None:
            # Build cross K/V from the encoder output (prefill).
            c, cross_kv = attention_apply(
                lp["cross"], h, cfg=cfg, positions=positions, causal=False,
                kv=enc_out,
                cache=sc["kv_cross"] if use_cache else None,
                cache_index=jnp.zeros((), jnp.int32) if use_cache else None,
            )
            if cross_kv is not None:
                out_caches["kv_cross"] = cross_kv
        else:
            # Decode: attend against the cached cross K/V.
            kc, vc = sc["kv_cross"]["k"], sc["kv_cross"]["v"]
            from repro.models.layers.attention import decode_attention

            H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
            q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(h.dtype))
            qg = q.reshape(B, S, K, H // K, hd)
            att = decode_attention(qg, kc, vc, kc.shape[1])
            att = att.reshape(B, S, H, hd)
            c = jnp.einsum("bshk,hkd->bsd", att, lp["cross"]["wo"].astype(h.dtype))
            out_caches["kv_cross"] = sc["kv_cross"]
        x = x + c

        h = basic.norm_apply(lp["norm2"], x, cfg.norm)
        x = x + mlp_apply(lp["ffn"], h, cfg)
        return x, out_caches

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, stacked = jax.lax.scan(body, x, scanned)
    x = basic.norm_apply(params["final_norm"], x, cfg.norm)
    logits = basic.logits_apply(params["embed"], x, cfg.vocab_size)

    aux: Dict[str, Any] = {"metrics": {}}
    if use_cache:
        new_state = dict(decode_state)
        new_state["kv_self"] = stacked["kv_self"]
        new_state["kv_cross"] = stacked["kv_cross"]
        new_state["pos"] = decode_state["pos"] + S
        aux["decode_state"] = new_state
    return logits, aux
