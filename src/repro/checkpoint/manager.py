"""Checkpointing: async, atomic, elastic.

Design for 1000+ nodes (adapted to this container's single process):
  * every host writes only its own shards (here: the full addressable
    tree), as .npz files under a step directory;
  * writes go to a temp directory that is atomically renamed on success —
    a crash mid-write can never corrupt the latest checkpoint;
  * saving is asynchronous (background thread) so the training loop only
    blocks on the previous save's completion (double-buffered);
  * restore is *elastic*: arrays are saved with their logical (global)
    shapes + the param-tree structure, so a checkpoint taken on one mesh
    restores onto any other mesh — resharding happens at device_put time
    against the new mesh's shardings;
  * retention: keep the newest K checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------- save -------------------------- #

    def save(self, step: int, state: Any, blocking: bool = False) -> None:
        """Async atomic save. Blocks only if a previous save is running."""
        self.wait()
        # Snapshot to host memory on the caller's thread (cheap, correct).
        host_state = jax.tree.map(np.asarray, jax.device_get(state))
        treedef = jax.tree.structure(state)

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            leaves = _flatten_with_paths(host_state)
            np.savez(
                os.path.join(tmp, "shard_host0.npz"),
                **{k: v for k, v in leaves},
            )
            meta = {
                "step": step,
                "time": time.time(),
                "keys": [k for k, _ in leaves],
                "treedef": str(treedef),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            os.rename(tmp, final)          # atomic commit
            self._gc()

        t = threading.Thread(target=_write, daemon=True)
        t.start()
        self._pending = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------ restore ------------------------ #

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> Any:
        """Restore into the structure of ``like``.

        ``shardings``: optional pytree of NamedShardings for the *current*
        mesh — this is the elastic path: the checkpoint's global arrays are
        device_put against whatever mesh the job restarted with.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:08d}")
        data = np.load(os.path.join(path, "shard_host0.npz"))
        keys = [k for k, _ in _flatten_with_paths(like)]
        leaves = [data[k] for k in keys]
        restored = jax.tree.unflatten(jax.tree.structure(like), leaves)
        if shardings is not None:
            restored = jax.tree.map(
                lambda x, s: jax.device_put(x, s), restored, shardings
            )
        return restored
