"""Band tests for the paper's headline claims (reduced sizes for speed).

Exact magnitudes depend on the (proprietary) workloads; these tests pin the
qualitative claims from DESIGN.md §8:
  * Fig. 4: Q10/Q19 large gains, other queries < ±8 %,
  * §III.B: heavy-row regression ≥10× unguarded, recovered when guarded,
  * §III.B: forced-remote regression on a small cluster,
  * Fig. 5 mechanics: Never-policy queries move nothing; eager UDF queries
    apply redistribution.
"""

import numpy as np
import pytest

from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import ClusterConfig, Simulator, StrategyConfig
from repro.sim.replay import dyskew_strategy, improvement, legacy_strategy
from repro.sim.workload import (
    QueryProfile,
    generate_query,
    heavy_rows_case,
    tpcxbb_suite,
)


class TestFig4Bands:
    @pytest.fixture(scope="class")
    def results(self):
        cluster = ClusterConfig(num_nodes=4)
        out = {}
        suite = {p.name: p for p in tpcxbb_suite()}
        for i, name in enumerate(["q05", "q10", "q19", "q22"]):
            prof = suite[name]
            batches = generate_query(prof, cluster.num_workers, seed=100 + i)
            leg = Simulator(cluster, legacy_strategy(prof), i).run_query(batches)
            dk = Simulator(cluster, dyskew_strategy(prof), i).run_query(batches)
            out[name] = improvement(leg.latency, dk.latency)
        return out

    def test_q10_large_gain(self, results):
        assert 0.30 <= results["q10"] <= 0.60  # paper: +43 %

    def test_q19_large_gain(self, results):
        assert 0.20 <= results["q19"] <= 0.50  # paper: +36 %

    def test_balanced_queries_unchanged(self, results):
        assert abs(results["q05"]) < 0.08
        assert abs(results["q22"]) < 0.08


class TestHeavyRowBands:
    def test_regression_and_recovery(self):
        cluster = ClusterConfig(num_nodes=4)
        prof = heavy_rows_case(row_gb=1.0, n_rows=48)
        batches = generate_query(prof, cluster.num_workers, seed=0)
        none = Simulator(cluster, StrategyConfig(kind="none"), 0).run_query(batches)
        ung = Simulator(cluster, StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, cost_gate=0.0,
                                min_batch_density_frac=0.0),
            enable_density_guard=False, enable_cost_gate=False,
        ), 0).run_query(batches)
        grd = Simulator(cluster, StrategyConfig(kind="dyskew"), 0).run_query(batches)
        assert ung.latency > 10.0 * none.latency   # paper: up to 20x
        assert grd.latency < 1.1 * none.latency


class TestPolicySemantics:
    def test_never_policy_moves_nothing(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(name="nv", n_rows=4000, mean_row_cost=1e-3,
                            partition_alpha=1.0, hot_fraction=0.3,
                            policy=Policy.NEVER)
        batches = generate_query(prof, cluster.num_workers, seed=0)
        r = Simulator(cluster, dyskew_strategy(prof), 0).run_query(batches)
        assert r.rows_redistributed == 0
        assert not r.redistribution_applied

    def test_eager_udf_applies(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(name="ea", n_rows=4000, mean_row_cost=1e-3,
                            policy=Policy.EAGER_SNOWPARK)
        batches = generate_query(prof, cluster.num_workers, seed=0)
        r = Simulator(cluster, dyskew_strategy(prof), 0).run_query(batches)
        assert r.redistribution_applied

    def test_constrained_query_legacy_falls_back_to_none(self):
        prof = QueryProfile(name="lc", locality_constrained=True)
        assert legacy_strategy(prof).kind == "none"
        st = dyskew_strategy(prof)
        assert st.kind == "dyskew"
        assert st.dyskew.policy == Policy.LATE
