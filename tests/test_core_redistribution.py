"""Tests for routing planners + in-graph cost gate + AdaptiveLink."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import admission
from repro.core import redistribution as rd
from repro.core.adaptive_link import AdaptiveLink, AdaptiveLinkConfig
from repro.core.types import DySkewConfig, Policy


class TestPlanners:
    def test_round_robin_cycles(self):
        dest = rd.round_robin(8, 4)
        np.testing.assert_array_equal(np.asarray(dest), [0, 1, 2, 3, 0, 1, 2, 3])

    def test_round_robin_eligibility(self):
        elig = jnp.array([True, False, True, False])
        dest = np.asarray(rd.round_robin(6, 4, eligible=elig))
        assert set(dest.tolist()) <= {0, 2}
        np.testing.assert_array_equal(dest, [0, 2, 0, 2, 0, 2])

    def test_lpt_beats_round_robin_on_skewed_costs(self):
        costs = jnp.array([10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
        rr = rd.makespan(rd.round_robin(8, 4), costs, 4)
        lpt_dest, _ = rd.lpt_greedy(costs, 4)
        lpt = rd.makespan(lpt_dest, costs, 4)
        assert float(lpt) <= float(rr)
        assert float(lpt) == 10.0  # heavy item alone on one instance

    def test_lpt_respects_base_loads(self):
        costs = jnp.array([5.0, 5.0])
        base = jnp.array([100.0, 0.0, 0.0, 100.0])
        dest, loads = rd.lpt_greedy(costs, 4, base_loads=base)
        assert set(np.asarray(dest).tolist()) == {1, 2}

    def test_lpt_eligibility(self):
        costs = jnp.ones((6,))
        elig = jnp.array([False, True, True, False])
        dest, _ = rd.lpt_greedy(costs, 4, eligible=elig)
        assert set(np.asarray(dest).tolist()) <= {1, 2}

    def test_zigzag_near_lpt(self):
        key = jax.random.PRNGKey(0)
        costs = jax.random.exponential(key, (64,)) + 0.01
        zz_dest, _ = rd.zigzag(costs, 8)
        lpt_dest, _ = rd.lpt_greedy(costs, 8)
        zz = float(rd.makespan(zz_dest, costs, 8))
        lpt = float(rd.makespan(lpt_dest, costs, 8))
        lower = float(jnp.sum(costs)) / 8
        # zigzag within 30% of exact greedy (both near the mean lower bound).
        assert zz <= 1.3 * max(lpt, lower)

    def test_zigzag_prefers_lightly_loaded(self):
        costs = jnp.array([8.0])
        base = jnp.array([10.0, 0.0, 5.0, 7.0])
        dest, _ = rd.zigzag(costs, 4, base_loads=base)
        assert int(dest[0]) == 1

    def test_local_assignment(self):
        dest = rd.local_assignment(5, 3)
        assert np.all(np.asarray(dest) == 3)

    def test_eligibility_mask_self_skip(self):
        m = rd.eligibility_mask(4, 2, self_skip=True)
        np.testing.assert_array_equal(np.asarray(m), [True, True, False, True])
        m = rd.eligibility_mask(4, 2, self_skip=False)
        assert bool(jnp.all(m))


class TestCostModel:
    def test_cheap_move_admitted(self):
        cfg = admission.CostModelConfig(
            link_bandwidth=50e9, per_item_overhead=1e-6
        )
        before = jnp.array([10.0, 0.0])
        after = jnp.array([5.0, 5.0])
        ok, saved, t = admission.admit_redistribution(
            before, after, jnp.array(1e6), jnp.array(100), cfg
        )
        assert bool(ok)
        assert float(saved) == pytest.approx(5.0)

    def test_heavy_row_rejected(self):
        # The §III.B pathology: 100 GB row, tiny balance benefit.
        cfg = admission.CostModelConfig(link_bandwidth=50e9)
        before = jnp.array([1.1, 1.0])
        after = jnp.array([1.05, 1.05])
        ok, saved, t = admission.admit_redistribution(
            before, after, jnp.array(100e9), jnp.array(1), cfg
        )
        assert not bool(ok)
        assert float(t) == pytest.approx(2.0, rel=0.01)  # 100GB / 50GB/s

    def test_balance_benefit_clamped_and_polymorphic(self):
        # numpy and jax operands run through the SAME implementation.
        for xp in (np, jnp):
            worse = admission.balance_benefit(
                xp.asarray([1.0, 1.0]), xp.asarray([2.0, 0.5])
            )
            assert float(worse) == 0.0
            gain = admission.balance_benefit(
                xp.asarray([4.0, 0.0]), xp.asarray([2.0, 2.0])
            )
            assert float(gain) == pytest.approx(2.0)


class TestAdaptiveLink:
    def _mk(self, policy=Policy.EAGER_SNOWPARK, n=4, **kw):
        cfg = AdaptiveLinkConfig(
            dyskew=DySkewConfig(policy=policy, **kw), num_instances=n
        )
        return AdaptiveLink(cfg)

    def test_eager_balances_skewed_items(self):
        link = self._mk()
        state = link.init_state()
        # All 16 items start on producer 0 with equal cost.
        costs = jnp.ones((16,))
        sizes = jnp.full((16,), 1e3)
        producer = jnp.zeros((16,), jnp.int32)
        state, plan = link.step(state, costs, sizes, producer)
        loads = np.zeros(4)
        np.add.at(loads, np.asarray(plan.dest), 1.0)
        assert loads.max() == 4  # perfectly balanced 16/4

    def test_never_policy_keeps_local(self):
        link = self._mk(policy=Policy.NEVER)
        state = link.init_state()
        costs = jnp.ones((16,))
        producer = jnp.zeros((16,), jnp.int32)
        state, plan = link.step(state, costs, jnp.ones((16,)), producer)
        assert np.all(np.asarray(plan.dest) == 0)

    def test_late_policy_waits_for_strikes(self):
        link = self._mk(policy=Policy.LATE, n_strikes=3, theta=0.5)
        state = link.init_state()
        costs = jnp.ones((12,))
        producer = jnp.zeros((12,), jnp.int32)
        for i in range(4):
            state, plan = link.step(state, costs, jnp.ones((12,)), producer)
            if i < 3:
                assert np.all(np.asarray(plan.dest) == 0), f"tick {i}"
        # DRAINING consumed a tick; next tick distributes.
        state, plan = link.step(state, costs, jnp.ones((12,)), producer)
        assert len(set(np.asarray(plan.dest).tolist())) > 1

    def test_cost_gate_blocks_giant_rows(self):
        link = self._mk()
        state = link.init_state()
        costs = jnp.ones((4,))            # 1s of compute each
        sizes = jnp.full((4,), 200e9)     # 200 GB each → 4s transfer each
        producer = jnp.zeros((4,), jnp.int32)
        state, plan = link.step(state, costs, sizes, producer)
        assert np.all(np.asarray(plan.dest) == 0)
        assert float(plan.est_bytes_moved) == 0.0

    def test_self_skip_ablation_avoids_self(self):
        link = self._mk(self_skip=True)
        state = link.init_state()
        costs = jnp.ones((8,))
        producer = jnp.zeros((8,), jnp.int32)
        state, plan = link.step(state, costs, jnp.ones((8,)), producer)
        dest = np.asarray(plan.dest)
        assert not np.any(dest == 0)  # forced remote: self excluded

    def test_no_self_skip_uses_local(self):
        link = self._mk(self_skip=False)
        state = link.init_state()
        costs = jnp.ones((8,))
        producer = jnp.zeros((8,), jnp.int32)
        state, plan = link.step(state, costs, jnp.ones((8,)), producer)
        assert np.any(np.asarray(plan.dest) == 0)

    def test_padding_items_never_move(self):
        link = self._mk()
        state = link.init_state()
        costs = jnp.ones((8,))
        producer = jnp.zeros((8,), jnp.int32)
        valid = jnp.array([True] * 4 + [False] * 4)
        state, plan = link.step(state, costs, jnp.ones((8,)), producer, valid)
        assert np.all(np.asarray(plan.dest)[4:] == 0)

    def test_jit_compatible(self):
        link = self._mk()
        state = link.init_state()

        @jax.jit
        def run(state, costs, sizes, producer):
            return link.step(state, costs, sizes, producer)

        state2, plan = run(
            state, jnp.ones((16,)), jnp.ones((16,)), jnp.zeros((16,), jnp.int32)
        )
        assert plan.dest.shape == (16,)
