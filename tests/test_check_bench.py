"""Tests for tools/check_bench.py, the BENCH_<n>.json schema validator.

Each test builds a tiny record tree under tmp_path so the validator's
judgements are exercised without touching the repo's real trajectory
(which ``test_real_records_validate`` pins green separately).
"""

import copy
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)

from tools import check_bench  # noqa: E402


def _record(created=100.0, wall=1.5):
    return {
        "schema": 1,
        "created_unix": created,
        "quick": True,
        "only": "",
        "total_wall_s": wall,
        "benches": [
            {
                "suite": "benchmarks.bench_sim",
                "status": "ok",
                "wall_s": wall,
                "rows": [
                    {"name": "drain_128", "us_per_call": 12.5,
                     "derived": {"speedup": 3.0}},
                ],
            },
        ],
    }


def _write(tmp_path, name, data):
    (tmp_path / name).write_text(json.dumps(data))


class TestValidateRecord:
    def test_well_formed_record_passes(self):
        assert check_bench.validate_record(_record(), "BENCH_1.json") == []

    def test_missing_top_level_keys(self):
        rec = _record()
        del rec["total_wall_s"]
        del rec["quick"]
        errs = check_bench.validate_record(rec, "x")
        assert any("total_wall_s" in e for e in errs)
        assert any("quick" in e for e in errs)

    def test_wrong_schema_version(self):
        rec = _record()
        rec["schema"] = 2
        errs = check_bench.validate_record(rec, "x")
        assert any("schema" in e for e in errs)

    def test_ran_bench_must_bill_wall_time(self):
        rec = _record()
        del rec["benches"][0]["wall_s"]
        rec["total_wall_s"] = 0.0
        errs = check_bench.validate_record(rec, "x")
        assert any("wall_s" in e for e in errs)

    def test_skipped_bench_needs_no_wall_or_rows(self):
        rec = _record()
        rec["benches"].append({"suite": "benchmarks.bench_gpu",
                               "status": "skipped"})
        assert check_bench.validate_record(rec, "x") == []

    def test_failed_bench_still_bills_wall_time(self):
        rec = _record()
        rec["benches"].append({"suite": "benchmarks.bench_bad",
                               "status": "failed"})
        errs = check_bench.validate_record(rec, "x")
        assert any("status=failed" in e and "wall_s" in e for e in errs)

    def test_near_miss_unit_suffix_in_derived_key_is_flagged(self):
        rec = _record()
        rec["benches"][0]["rows"][0]["derived"]["p99_sec"] = 0.5
        errs = check_bench.validate_record(rec, "x")
        assert any("p99_sec" in e and "_s" in e for e in errs)

    def test_near_miss_unit_suffix_in_row_name_is_flagged(self):
        rec = _record()
        rec["benches"][0]["rows"][0]["name"] = "drain_gib"
        errs = check_bench.validate_record(rec, "x")
        assert any("drain_gib" in e and "_kb" not in e for e in errs)

    def test_vocabulary_unit_suffixes_pass(self):
        rec = _record()
        rec["benches"][0]["rows"][0]["derived"].update(
            {"p99_s": 0.5, "moved_bytes": 10, "kv_gb": 1.0,
             "deficit_rows": 3, "no_unit_at_all": 1}
        )
        assert check_bench.validate_record(rec, "x") == []

    def test_bad_row_shapes(self):
        rec = _record()
        rec["benches"][0]["rows"].append({"name": "", "us_per_call": -1.0,
                                          "derived": []})
        errs = check_bench.validate_record(rec, "x")
        assert any("non-empty string" in e for e in errs)
        assert any("us_per_call" in e for e in errs)
        assert any("derived" in e for e in errs)

    def test_null_us_per_call_is_legal(self):
        # The writer nulls NaN (allow_nan=False) — e.g. Jain's index of
        # a class with zero completions.
        rec = _record()
        rec["benches"][0]["rows"][0]["us_per_call"] = None
        assert check_bench.validate_record(rec, "x") == []

    def test_total_wall_must_match_bench_sum(self):
        rec = _record(wall=2.0)
        rec["total_wall_s"] = 99.0
        errs = check_bench.validate_record(rec, "x")
        assert any("sum of bench" in e for e in errs)


class TestCheckFiles:
    def test_contiguous_sequence_passes(self, tmp_path):
        _write(tmp_path, "BENCH_3.json", _record(created=10.0))
        _write(tmp_path, "BENCH_4.json", _record(created=20.0))
        checked, errs = check_bench.check_files(str(tmp_path))
        assert checked == ["BENCH_3.json", "BENCH_4.json"]
        assert errs == []

    def test_hole_in_numbering_is_flagged(self, tmp_path):
        _write(tmp_path, "BENCH_3.json", _record(created=10.0))
        _write(tmp_path, "BENCH_5.json", _record(created=20.0))
        _, errs = check_bench.check_files(str(tmp_path))
        assert any("BENCH_4.json" in e and "holes" in e for e in errs)

    def test_backwards_created_unix_is_flagged(self, tmp_path):
        _write(tmp_path, "BENCH_3.json", _record(created=20.0))
        _write(tmp_path, "BENCH_4.json", _record(created=10.0))
        _, errs = check_bench.check_files(str(tmp_path))
        assert any("out of order" in e for e in errs)

    def test_unparseable_json_is_flagged(self, tmp_path):
        (tmp_path / "BENCH_3.json").write_text("{not json")
        _, errs = check_bench.check_files(str(tmp_path))
        assert any("unreadable" in e for e in errs)

    def test_misnamed_record_is_flagged(self, tmp_path):
        _write(tmp_path, "BENCH_03x.json", _record())
        _, errs = check_bench.check_files(str(tmp_path))
        assert any("does not match" in e for e in errs)

    def test_main_exit_codes(self, tmp_path, capsys):
        _write(tmp_path, "BENCH_1.json", _record())
        assert check_bench.main(["--root", str(tmp_path)]) == 0
        assert "OK" in capsys.readouterr().out
        bad = _record()
        bad["schema"] = 99
        _write(tmp_path, "BENCH_2.json", bad)
        assert check_bench.main(["--root", str(tmp_path)]) == 1
        assert "FAILED" in capsys.readouterr().out


def test_real_records_validate():
    """The repo's actual trajectory must satisfy its own schema."""
    checked, errs = check_bench.check_files(ROOT)
    assert errs == []
    assert checked, "no BENCH_*.json records found at repo root"
