"""Equivalence + planner tests for the array-backed simulator core.

The array-backed engine (`repro.sim.engine.Simulator`) is a data-structure
rewrite of the seed list-of-tuples engine (`repro.sim.legacy`): same event
semantics, same float operations in the same order.  These tests pin the
two against each other on seeded workloads — the trajectories are chaotic
(a one-ulp rounding difference amplifies through routing decisions), so a
passing tight tolerance here means the rewrite is genuinely faithful, not
merely close.

Also covered: the shared `repro.core.admission` planner the engine (and
serving/data paths) delegate their per-batch guards to, and the
multi-tenant engine's conservation/degradation properties.
"""

import numpy as np
import pytest

from repro.core.admission import (
    BatchAdmission,
    straggler_savings,
    transfer_seconds,
)
from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import (
    ClusterConfig,
    MultiQuerySimulator,
    Simulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.legacy import LegacySimulator
from repro.sim.replay import (
    default_strategies,
    dyskew_strategy,
    legacy_strategy,
    scan_arrival_gap,
    staggered_tenants,
)
from repro.sim.workload import (
    QueryProfile,
    generate_query,
    heavy_rows_case,
    multi_tenant_suite,
    self_skip_case,
)

TOL = dict(rtol=1e-9, atol=0.0)


def _compare(cluster, prof, strategy, seed, gap=None):
    batches = generate_query(prof, cluster.num_workers, seed=seed)
    if gap is None:
        gap = scan_arrival_gap(prof, cluster)
    new = Simulator(cluster, strategy, seed).run_query(batches, gap)
    old = LegacySimulator(cluster, strategy, seed).run_query(batches, gap)
    np.testing.assert_allclose(new.latency, old.latency, **TOL)
    np.testing.assert_allclose(new.utilization, old.utilization, **TOL)
    np.testing.assert_allclose(
        new.bytes_moved_remote, old.bytes_moved_remote, **TOL
    )
    assert new.rows_redistributed == old.rows_redistributed
    assert new.redistribution_applied == old.redistribution_applied
    np.testing.assert_allclose(new.per_worker_busy, old.per_worker_busy, **TOL)
    return new, old


class TestEngineEquivalence:
    """Array-backed engine reproduces the legacy engine's QueryResult."""

    @pytest.mark.parametrize("kind", ["none", "static_rr", "dyskew"])
    @pytest.mark.parametrize("seed", [0, 3])
    def test_skewed_workload_all_strategies(self, kind, seed):
        cluster = ClusterConfig(num_nodes=4)
        prof = QueryProfile(
            name="eq", n_rows=3000, mean_row_cost=1e-3, cost_sigma=1.2,
            partition_alpha=1.0, hot_fraction=0.2,
        )
        _compare(cluster, prof, default_strategies()[kind], seed)

    def test_heavy_rows_guarded(self):
        cluster = ClusterConfig(num_nodes=4)
        _compare(cluster, heavy_rows_case(row_gb=1.0, n_rows=48),
                 default_strategies()["dyskew"], 0, gap=1e-4)

    def test_self_skip_ablation(self):
        cluster = ClusterConfig(num_nodes=2)
        st = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, self_skip=True),
        )
        _compare(cluster, self_skip_case(), st, 0)

    def test_ab_resolution_strategies(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="eq2", n_rows=2000, mean_row_cost=2e-3, cost_sigma=0.8,
            partition_alpha=0.4, locality_constrained=True,
        )
        for resolve in (legacy_strategy, dyskew_strategy):
            _compare(cluster, prof, resolve(prof), 1)


class TestAdmissionPlanner:
    """Unit tests for the shared repro.core admission guards."""

    def _planner(self, **kw):
        return BatchAdmission(DySkewConfig(policy=Policy.EAGER_SNOWPARK, **kw))

    # -- cost gate ---------------------------------------------------- #

    def test_cost_gate_blocks_heavy_cheap_rows(self):
        p = self._planner()
        # 1 GB moved to save ~1 ms of straggler time: refuse.
        dec = p.admit_move(
            bytes_moved=1e9, rows_moved=8, est_row_cost=1e-4,
            num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6,
        )
        assert not dec.admit and dec.reason == "cost_gate"
        assert dec.est_transfer > dec.est_saved

    def test_cost_gate_admits_expensive_small_rows(self):
        p = self._planner()
        dec = p.admit_move(
            bytes_moved=64_000, rows_moved=128, est_row_cost=5e-3,
            num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6,
        )
        assert dec.admit and dec.reason == "ok"

    def test_cost_gate_threshold_scales(self):
        # Raising cost_gate makes the same move harder to admit.
        loose = self._planner(cost_gate=0.1)
        strict = self._planner(cost_gate=10.0)
        args = dict(bytes_moved=1e6, rows_moved=32, est_row_cost=1e-4,
                    num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6)
        assert loose.admit_move(**args).admit
        assert not strict.admit_move(**args).admit

    def test_cost_gate_disabled_admits_everything(self):
        p = BatchAdmission(
            DySkewConfig(policy=Policy.EAGER_SNOWPARK),
            enable_cost_gate=False,
        )
        dec = p.admit_move(1e12, 4, 1e-9, 8, 1.25e9, 2e-6)
        assert dec.admit

    def test_transfer_and_savings_formulas(self):
        assert transfer_seconds(1e9, 10, 1e9, 1e-3) == pytest.approx(1.01)
        # Savings scale with (1 - 1/n): nothing saved on a 1-worker cluster.
        assert straggler_savings(1e-3, 100, 1) == 0.0
        assert straggler_savings(1e-3, 100, 4) == pytest.approx(0.075)

    # -- density guard (Row Size Model) -------------------------------- #

    def test_density_guard_blocks_sparse_heavy_batches(self):
        p = self._planner()
        cfg = p.cfg
        assert p.density_guard_blocks(
            num_rows=2, bytes_per_row=cfg.heavy_row_bytes * 10,
            idle_sibling_frac=0.0,
        )

    def test_density_guard_ignores_small_light_batches(self):
        # End-of-stream remainder batches (few rows, small bytes) must NOT
        # trip the guard.
        p = self._planner()
        assert not p.density_guard_blocks(
            num_rows=2, bytes_per_row=128.0, idle_sibling_frac=0.0
        )

    def test_density_guard_yields_to_idle_siblings(self):
        p = self._planner()
        cfg = p.cfg
        assert not p.density_guard_blocks(
            num_rows=2, bytes_per_row=cfg.heavy_row_bytes * 10,
            idle_sibling_frac=1.0,
        )

    def test_density_guard_lazy_idle_callable(self):
        p = self._planner()
        calls = []

        def frac():
            calls.append(1)
            return 0.0

        # Cheap size checks fail → the expensive sibling scan is skipped.
        assert not p.density_guard_blocks(10_000, 8.0, frac)
        assert not calls
        assert p.density_guard_blocks(2, p.cfg.heavy_row_bytes * 10, frac)
        assert calls

    # -- self-skip eligibility ----------------------------------------- #

    def test_no_self_skip_everyone_eligible(self):
        mask = self._planner().eligible_destinations(8, producer=3)
        assert mask.all()

    def test_self_skip_excludes_producer(self):
        mask = self._planner(self_skip=True).eligible_destinations(8, 3)
        assert not mask[3] and mask.sum() == 7

    def test_self_skip_excludes_whole_node(self):
        c = ClusterConfig(num_nodes=2, interpreters_per_node=4)
        mask = self._planner(self_skip=True).eligible_destinations(
            c.num_workers, producer=1, node_of=c.node_of
        )
        assert not mask[:4].any() and mask[4:].all()


class TestMultiTenantEquivalence:
    """The unified event loop, driven through the MULTI-tenant API, pinned
    bit-tight against `sim/legacy.py`-derived traces.

    The legacy engine is single-query, so the pins cover the two regimes
    where it still predicts the multi-tenant loop exactly: a lone tenant
    (N=1 must be indistinguishable from the seed engine), and concurrent
    tenants that provably cannot interact (disjoint producers, no
    redistribution), where each tenant must reproduce its solo legacy
    trace even though all events interleave through one heap.
    """

    def _assert_equal(self, new, old):
        np.testing.assert_allclose(new.latency, old.latency, **TOL)
        np.testing.assert_allclose(new.utilization, old.utilization, **TOL)
        np.testing.assert_allclose(
            new.bytes_moved_remote, old.bytes_moved_remote, **TOL
        )
        assert new.rows_redistributed == old.rows_redistributed
        np.testing.assert_allclose(
            new.per_worker_busy, old.per_worker_busy, **TOL
        )

    @pytest.mark.parametrize("kind", ["none", "static_rr", "dyskew"])
    def test_single_tenant_bit_exact_vs_legacy(self, kind):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="mt_eq", n_rows=2000, mean_row_cost=1e-3, cost_sigma=1.1,
            partition_alpha=0.8, hot_fraction=0.15,
        )
        st = default_strategies()[kind]
        batches = generate_query(prof, cluster.num_workers, seed=2)
        gap = scan_arrival_gap(prof, cluster)
        multi = MultiQuerySimulator(cluster).run(
            [TenantQuery("solo", batches, st, 0.0, gap)]
        )[0]
        old = LegacySimulator(cluster, st, 0).run_query(batches, gap)
        self._assert_equal(multi, old)

    def test_disjoint_tenants_bit_exact_vs_legacy(self):
        """Two concurrent 'none'-strategy tenants on disjoint producers
        share the heap/rings data structures but no resources; each must
        match its solo legacy trace bit-for-bit."""
        cluster = ClusterConfig(num_nodes=2)
        n = cluster.num_workers
        st = StrategyConfig(kind="none")
        prof = QueryProfile(
            name="disjoint", n_rows=1500, mean_row_cost=1e-3, cost_sigma=0.9,
        )
        gap = scan_arrival_gap(prof, cluster)
        full = generate_query(prof, n, seed=9)
        half = n // 2
        streams_a = [s if p < half else [] for p, s in enumerate(full)]
        streams_b = [s if p >= half else [] for p, s in enumerate(full)]
        multi = MultiQuerySimulator(cluster).run([
            TenantQuery("a", streams_a, st, 0.0, gap),
            TenantQuery("b", streams_b, st, 0.0, gap),
        ])
        for streams, res in zip((streams_a, streams_b), multi):
            solo = LegacySimulator(cluster, st, 0).run_query(streams, gap)
            self._assert_equal(res, solo)


class TestMultiQuerySimulator:
    def _tenants(self, cluster, num=4, resolve=dyskew_strategy, seed=0):
        profiles = multi_tenant_suite(num, seed=41)
        return staggered_tenants(profiles, cluster, resolve, seed=seed)

    def test_conservation_per_tenant(self):
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._tenants(cluster)
        results = MultiQuerySimulator(cluster).run(tenants)
        assert len(results) == len(tenants)
        for t, r in zip(tenants, results):
            total_cost = sum(b.costs.sum() for s in t.streams for b in s)
            np.testing.assert_allclose(
                r.per_worker_busy.sum(), total_cost, rtol=1e-9
            )
            assert r.latency > 0

    def test_determinism(self):
        cluster = ClusterConfig(num_nodes=2)
        r1 = MultiQuerySimulator(cluster).run(self._tenants(cluster))
        r2 = MultiQuerySimulator(cluster).run(self._tenants(cluster))
        for a, b in zip(r1, r2):
            assert a.latency == b.latency
            assert a.rows_redistributed == b.rows_redistributed

    def test_contention_slows_tenants_vs_solo(self):
        """A tenant sharing the cluster can't beat its solo run."""
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._tenants(cluster)
        shared = MultiQuerySimulator(cluster).run(tenants)
        for t, r in zip(tenants, shared):
            solo = MultiQuerySimulator(cluster).run(
                [TenantQuery(t.name, t.streams, t.strategy, 0.0,
                             t.arrival_gap)]
            )[0]
            assert r.latency >= solo.latency * 0.999

    def test_dyskew_beats_legacy_under_concurrency(self):
        cluster = ClusterConfig(num_nodes=4)
        profiles = multi_tenant_suite(6, seed=43)
        leg = MultiQuerySimulator(cluster).run(
            staggered_tenants(profiles, cluster, legacy_strategy, seed=0)
        )
        dk = MultiQuerySimulator(cluster).run(
            staggered_tenants(profiles, cluster, dyskew_strategy, seed=0)
        )
        assert np.mean([r.latency for r in dk]) < np.mean(
            [r.latency for r in leg]
        )

    def test_single_tenant_matches_simulator(self):
        """One tenant on the shared engine == the single-query engine
        EXACTLY: `Simulator.run_query` is the N=1 case of the unified
        loop, not a separate implementation."""
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="solo", n_rows=2000, mean_row_cost=1e-3, cost_sigma=1.0,
            partition_alpha=0.8, hot_fraction=0.2,
        )
        st = default_strategies()["dyskew"]
        batches = generate_query(prof, cluster.num_workers, seed=5)
        gap = scan_arrival_gap(prof, cluster)
        solo = Simulator(cluster, st, 0).run_query(batches, gap)
        multi = MultiQuerySimulator(cluster).run(
            [TenantQuery("solo", batches, st, 0.0, gap)]
        )[0]
        assert multi.latency == solo.latency
        assert multi.rows_redistributed == solo.rows_redistributed
        np.testing.assert_array_equal(
            multi.per_worker_busy, solo.per_worker_busy
        )
