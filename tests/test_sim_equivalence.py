"""Equivalence + planner tests for the array-backed simulator core.

The array-backed engine (`repro.sim.engine.Simulator`) is a data-structure
rewrite of the seed list-of-tuples engine (`repro.sim.legacy`): same event
semantics, same float operations in the same order.  These tests pin the
two against each other on seeded workloads — the trajectories are chaotic
(a one-ulp rounding difference amplifies through routing decisions), so a
passing tight tolerance here means the rewrite is genuinely faithful, not
merely close.

Also covered: the shared `repro.core.admission` planner the engine (and
serving/data paths) delegate their per-batch guards to, and the
multi-tenant engine's conservation/degradation properties.
"""

import numpy as np
import pytest

from repro.core.admission import (
    BatchAdmission,
    straggler_savings,
    transfer_seconds,
)
from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    Simulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.legacy import LegacySimulator
from repro.sim.replay import (
    default_strategies,
    dyskew_strategy,
    legacy_strategy,
    scan_arrival_gap,
    staggered_tenants,
)
from repro.sim.workload import (
    QueryProfile,
    generate_query,
    heavy_rows_case,
    multi_tenant_suite,
    self_skip_case,
)

TOL = dict(rtol=1e-9, atol=0.0)


def _compare(cluster, prof, strategy, seed, gap=None):
    batches = generate_query(prof, cluster.num_workers, seed=seed)
    if gap is None:
        gap = scan_arrival_gap(prof, cluster)
    new = Simulator(cluster, strategy, seed).run_query(batches, gap)
    old = LegacySimulator(cluster, strategy, seed).run_query(batches, gap)
    np.testing.assert_allclose(new.latency, old.latency, **TOL)
    np.testing.assert_allclose(new.utilization, old.utilization, **TOL)
    np.testing.assert_allclose(
        new.bytes_moved_remote, old.bytes_moved_remote, **TOL
    )
    assert new.rows_redistributed == old.rows_redistributed
    assert new.redistribution_applied == old.redistribution_applied
    np.testing.assert_allclose(new.per_worker_busy, old.per_worker_busy, **TOL)
    return new, old


class TestEngineEquivalence:
    """Array-backed engine reproduces the legacy engine's QueryResult."""

    @pytest.mark.parametrize("kind", ["none", "static_rr", "dyskew"])
    @pytest.mark.parametrize("seed", [0, 3])
    def test_skewed_workload_all_strategies(self, kind, seed):
        cluster = ClusterConfig(num_nodes=4)
        prof = QueryProfile(
            name="eq", n_rows=3000, mean_row_cost=1e-3, cost_sigma=1.2,
            partition_alpha=1.0, hot_fraction=0.2,
        )
        _compare(cluster, prof, default_strategies()[kind], seed)

    def test_heavy_rows_guarded(self):
        cluster = ClusterConfig(num_nodes=4)
        _compare(cluster, heavy_rows_case(row_gb=1.0, n_rows=48),
                 default_strategies()["dyskew"], 0, gap=1e-4)

    def test_self_skip_ablation(self):
        cluster = ClusterConfig(num_nodes=2)
        st = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, self_skip=True),
        )
        _compare(cluster, self_skip_case(), st, 0)

    def test_ab_resolution_strategies(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="eq2", n_rows=2000, mean_row_cost=2e-3, cost_sigma=0.8,
            partition_alpha=0.4, locality_constrained=True,
        )
        for resolve in (legacy_strategy, dyskew_strategy):
            _compare(cluster, prof, resolve(prof), 1)


class TestAdmissionPlanner:
    """Unit tests for the shared repro.core admission guards."""

    def _planner(self, **kw):
        return BatchAdmission(DySkewConfig(policy=Policy.EAGER_SNOWPARK, **kw))

    # -- cost gate ---------------------------------------------------- #

    def test_cost_gate_blocks_heavy_cheap_rows(self):
        p = self._planner()
        # 1 GB moved to save ~1 ms of straggler time: refuse.
        dec = p.admit_move(
            bytes_moved=1e9, rows_moved=8, est_row_cost=1e-4,
            num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6,
        )
        assert not dec.admit and dec.reason == "cost_gate"
        assert dec.est_transfer > dec.est_saved

    def test_cost_gate_admits_expensive_small_rows(self):
        p = self._planner()
        dec = p.admit_move(
            bytes_moved=64_000, rows_moved=128, est_row_cost=5e-3,
            num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6,
        )
        assert dec.admit and dec.reason == "ok"

    def test_cost_gate_threshold_scales(self):
        # Raising cost_gate makes the same move harder to admit.
        loose = self._planner(cost_gate=0.1)
        strict = self._planner(cost_gate=10.0)
        args = dict(bytes_moved=1e6, rows_moved=32, est_row_cost=1e-4,
                    num_instances=8, bandwidth=1.25e9, per_row_overhead=2e-6)
        assert loose.admit_move(**args).admit
        assert not strict.admit_move(**args).admit

    def test_cost_gate_disabled_admits_everything(self):
        p = BatchAdmission(
            DySkewConfig(policy=Policy.EAGER_SNOWPARK),
            enable_cost_gate=False,
        )
        dec = p.admit_move(1e12, 4, 1e-9, 8, 1.25e9, 2e-6)
        assert dec.admit

    def test_transfer_and_savings_formulas(self):
        assert transfer_seconds(1e9, 10, 1e9, 1e-3) == pytest.approx(1.01)
        # Savings scale with (1 - 1/n): nothing saved on a 1-worker cluster.
        assert straggler_savings(1e-3, 100, 1) == 0.0
        assert straggler_savings(1e-3, 100, 4) == pytest.approx(0.075)

    # -- density guard (Row Size Model) -------------------------------- #

    def test_density_guard_blocks_sparse_heavy_batches(self):
        p = self._planner()
        cfg = p.cfg
        assert p.density_guard_blocks(
            num_rows=2, bytes_per_row=cfg.heavy_row_bytes * 10,
            idle_sibling_frac=0.0,
        )

    def test_density_guard_ignores_small_light_batches(self):
        # End-of-stream remainder batches (few rows, small bytes) must NOT
        # trip the guard.
        p = self._planner()
        assert not p.density_guard_blocks(
            num_rows=2, bytes_per_row=128.0, idle_sibling_frac=0.0
        )

    def test_density_guard_yields_to_idle_siblings(self):
        p = self._planner()
        cfg = p.cfg
        assert not p.density_guard_blocks(
            num_rows=2, bytes_per_row=cfg.heavy_row_bytes * 10,
            idle_sibling_frac=1.0,
        )

    def test_density_guard_lazy_idle_callable(self):
        p = self._planner()
        calls = []

        def frac():
            calls.append(1)
            return 0.0

        # Cheap size checks fail → the expensive sibling scan is skipped.
        assert not p.density_guard_blocks(10_000, 8.0, frac)
        assert not calls
        assert p.density_guard_blocks(2, p.cfg.heavy_row_bytes * 10, frac)
        assert calls

    # -- self-skip eligibility ----------------------------------------- #

    def test_no_self_skip_everyone_eligible(self):
        mask = self._planner().eligible_destinations(8, producer=3)
        assert mask.all()

    def test_self_skip_excludes_producer(self):
        mask = self._planner(self_skip=True).eligible_destinations(8, 3)
        assert not mask[3] and mask.sum() == 7

    def test_self_skip_excludes_whole_node(self):
        c = ClusterConfig(num_nodes=2, interpreters_per_node=4)
        mask = self._planner(self_skip=True).eligible_destinations(
            c.num_workers, producer=1, node_of=c.node_of
        )
        assert not mask[:4].any() and mask[4:].all()


class TestMultiTenantEquivalence:
    """The unified event loop, driven through the MULTI-tenant API, pinned
    bit-tight against `sim/legacy.py`-derived traces.

    The legacy engine is single-query, so the pins cover the two regimes
    where it still predicts the multi-tenant loop exactly: a lone tenant
    (N=1 must be indistinguishable from the seed engine), and concurrent
    tenants that provably cannot interact (disjoint producers, no
    redistribution), where each tenant must reproduce its solo legacy
    trace even though all events interleave through one heap.
    """

    def _assert_equal(self, new, old):
        np.testing.assert_allclose(new.latency, old.latency, **TOL)
        np.testing.assert_allclose(new.utilization, old.utilization, **TOL)
        np.testing.assert_allclose(
            new.bytes_moved_remote, old.bytes_moved_remote, **TOL
        )
        assert new.rows_redistributed == old.rows_redistributed
        np.testing.assert_allclose(
            new.per_worker_busy, old.per_worker_busy, **TOL
        )

    @pytest.mark.parametrize("kind", ["none", "static_rr", "dyskew"])
    def test_single_tenant_bit_exact_vs_legacy(self, kind):
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="mt_eq", n_rows=2000, mean_row_cost=1e-3, cost_sigma=1.1,
            partition_alpha=0.8, hot_fraction=0.15,
        )
        st = default_strategies()[kind]
        batches = generate_query(prof, cluster.num_workers, seed=2)
        gap = scan_arrival_gap(prof, cluster)
        multi = MultiQuerySimulator(cluster).run(
            [TenantQuery("solo", batches, st, 0.0, gap)]
        )[0]
        old = LegacySimulator(cluster, st, 0).run_query(batches, gap)
        self._assert_equal(multi, old)

    def test_disjoint_tenants_bit_exact_vs_legacy(self):
        """Two concurrent 'none'-strategy tenants on disjoint producers
        share the heap/rings data structures but no resources; each must
        match its solo legacy trace bit-for-bit."""
        cluster = ClusterConfig(num_nodes=2)
        n = cluster.num_workers
        st = StrategyConfig(kind="none")
        prof = QueryProfile(
            name="disjoint", n_rows=1500, mean_row_cost=1e-3, cost_sigma=0.9,
        )
        gap = scan_arrival_gap(prof, cluster)
        full = generate_query(prof, n, seed=9)
        half = n // 2
        streams_a = [s if p < half else [] for p, s in enumerate(full)]
        streams_b = [s if p >= half else [] for p, s in enumerate(full)]
        multi = MultiQuerySimulator(cluster).run([
            TenantQuery("a", streams_a, st, 0.0, gap),
            TenantQuery("b", streams_b, st, 0.0, gap),
        ])
        for streams, res in zip((streams_a, streams_b), multi):
            solo = LegacySimulator(cluster, st, 0).run_query(streams, gap)
            self._assert_equal(res, solo)


class TestClosedFormDrain:
    """The closed-form drain (exit the heap once every arrival has been
    routed; finish workers by prefix sums, recover tick counts in closed
    form) must be bit-identical to replaying the heap to exhaustion —
    and must never engage while an arrival (hence a mask-consuming
    routing decision) is still pending."""

    def _mixed_tenants(self, cluster):
        # Mixed strategies on one shared cluster: an eagerly distributing
        # tenant, a distribute-late tenant whose link TRANSITIONS
        # mid-run, a static round-robin and a 'none' tenant.
        profiles = multi_tenant_suite(4, seed=47)
        tenants = staggered_tenants(profiles, cluster, dyskew_strategy,
                                    seed=1)
        tenants[1].strategy = StrategyConfig(kind="static_rr")
        tenants[3].strategy = StrategyConfig(kind="none")
        return tenants

    def test_bit_identical_on_mixed_strategy_trace(self):
        cluster = ClusterConfig(num_nodes=2)
        heap = MultiQuerySimulator(
            cluster, closed_form_drain=False
        ).run(self._mixed_tenants(cluster))
        sim = MultiQuerySimulator(cluster)
        fast = sim.run(self._mixed_tenants(cluster))
        assert sim.last_event_counts["drain_entered"] == 1
        assert sim.last_event_counts["drained_heap_events"] > 0
        for a, b in zip(fast, heap):
            assert a.latency == b.latency
            assert a.utilization == b.utilization
            assert a.num_ticks == b.num_ticks
            assert a.rows_redistributed == b.rows_redistributed
            np.testing.assert_array_equal(a.per_worker_busy,
                                          b.per_worker_busy)

    def test_detector_conservative_while_arrivals_pending(self):
        """While any batch remains unrouted a link transition could still
        change routing, so every arrival must flow through the heap —
        the drain may only absorb post-final-arrival events."""
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._mixed_tenants(cluster)
        total_batches = sum(len(s) for t in tenants for s in t.streams)
        sim = MultiQuerySimulator(cluster)
        res = sim.run(tenants)
        counts = sim.last_event_counts
        assert counts["drain_entered"] == 1
        # Every arrival was popped from the heap, none synthesized by
        # the drain ...
        assert counts["arrival"] + counts["admitted"] == total_batches
        # ... and links genuinely transitioned before the drain began
        # (the late tenants redistribute only after mid-run strikes).
        assert any(r.rows_redistributed > 0 for r in res)

    def test_flag_false_keeps_the_heap(self):
        cluster = ClusterConfig(num_nodes=2)
        sim = MultiQuerySimulator(cluster, closed_form_drain=False)
        sim.run(self._mixed_tenants(cluster))
        assert sim.last_event_counts["drain_entered"] == 0
        assert sim.last_event_counts["drained_heap_events"] == 0

    def test_zero_row_batch_tenant_terminates(self):
        """Regression: a link tenant whose batch carries ZERO rows never
        sees a _DONE, so the incrementally-maintained active flag must
        flip at its last arrival — with the drain disabled the tick
        chain used to reschedule forever."""
        cluster = ClusterConfig(num_nodes=1, interpreters_per_node=2)
        streams = [[] for _ in range(cluster.num_workers)]
        streams[0] = [Batch(costs=np.empty(0), sizes=np.empty(0))]
        t = TenantQuery("empty", streams, default_strategies()["dyskew"],
                        0.0, 1e-4)
        for drain in (False, None):
            res = MultiQuerySimulator(
                cluster, closed_form_drain=drain
            ).run([t])[0]
            assert res.per_worker_busy.sum() == 0.0
            assert res.num_ticks >= 1  # ticked at arrival, then stopped

    def test_drain_num_ticks_exact_with_join_tick_at_pending_grid_event(
        self,
    ):
        """Regression: a member whose join tick fires at EXACTLY the
        pending grid event's time (an on-grid arrival that is also the
        run's last arrival) must not be double-counted by the drain's
        closed-form tick counting — the heap's `last_tick != now` guard
        skips it at that instant."""
        cluster = ClusterConfig(num_nodes=1, interpreters_per_node=4)
        st = default_strategies()["dyskew"]
        interval = st.tick_interval
        g2 = (0.0 + interval) + interval  # chained grid value
        rng = np.random.default_rng(7)

        def tenant(name, arrival):
            streams = [[] for _ in range(cluster.num_workers)]
            streams[0] = [Batch(costs=rng.exponential(1e-3, 24),
                                sizes=np.full(24, 256.0))]
            return TenantQuery(name, streams, st, arrival, 1e-4)

        tenants = [tenant("a", 0.0), tenant("b", g2)]
        heap = MultiQuerySimulator(
            cluster, closed_form_drain=False
        ).run(tenants)
        fast = MultiQuerySimulator(cluster).run(tenants)
        for a, b in zip(fast, heap):
            assert a.num_ticks == b.num_ticks
            assert a.latency == b.latency

    def test_zero_row_enqueue_does_not_corrupt_idle_census(self):
        """Regression: a zero-row segment leaves its worker's ring empty
        and the worker never starts, so it must NOT clear the
        incremental idle flag — a bystander's density guard would
        otherwise see a permanently-busy sibling and block a
        redistribution the O(n) scan admitted."""
        cluster = ClusterConfig(num_nodes=1, interpreters_per_node=4)
        n = cluster.num_workers
        rng = np.random.default_rng(23)

        def zero_row_tenant():
            streams = [[] for _ in range(n)]
            streams[2] = [Batch(costs=np.empty(0), sizes=np.empty(0))]
            return TenantQuery("z", streams, StrategyConfig(kind="none"),
                               0.0, 1e-4)

        def heavy_tenant():
            # 2 sparse heavy rows: trips the density-guard size checks,
            # so the decision comes down to the idle-sibling fraction —
            # threshold 0.9 distinguishes all-3-idle (1.0, redistribute)
            # from the corrupted census (2/3, blocked).
            streams = [[] for _ in range(n)]
            streams[0] = [Batch(costs=rng.exponential(0.05, 2),
                                sizes=np.full(2, 2e6))]
            st = StrategyConfig(
                kind="dyskew",
                dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK,
                                    idle_sibling_frac=0.9),
                enable_cost_gate=False,
            )
            return TenantQuery("h", streams, st, 0.1, 1e-4)

        res = MultiQuerySimulator(cluster, none_closed_form=False).run(
            [zero_row_tenant(), heavy_tenant()]
        )
        assert res[1].rows_redistributed > 0

    @pytest.mark.parametrize("arrival", [0.013, 0.253, 1.01])
    def test_drain_pending_join_tick_fires_once(self, arrival):
        """Regression: a batch-less member arriving after the fleet's
        last routed arrival leaves its one-off join _GTICK pending at
        drain entry — the drain must count it as ONE fire, not replay
        it as a recurring grid chain, and must not count pending grid
        fires from before the member arrived (arrivals beyond the first
        pending chain instants cover that gate)."""
        cluster = ClusterConfig(num_nodes=1, interpreters_per_node=4)
        st = default_strategies()["dyskew"]
        rng = np.random.default_rng(19)
        streams_a = [[] for _ in range(cluster.num_workers)]
        streams_a[0] = [Batch(costs=rng.exponential(1e-3, 24),
                              sizes=np.full(24, 256.0))]
        a = TenantQuery("a", streams_a, st, 0.0, 1e-4)
        b = TenantQuery("b", [[] for _ in range(cluster.num_workers)],
                        st, arrival, 1e-4)  # no batches, off-grid
        heap = MultiQuerySimulator(
            cluster, batch_ticks=True, closed_form_drain=False
        ).run([a, b])
        fast = MultiQuerySimulator(cluster, batch_ticks=True).run([a, b])
        for x, y in zip(fast, heap):
            assert x.num_ticks == y.num_ticks
            assert x.latency == y.latency

    def test_coalesced_enqueues_drain_exact(self):
        """Same-(time, destination) _ENQUEUE pushes coalesce into one
        heap event; the payload must replay per-segment both in the loop
        and in the drain's per-worker replay."""
        cluster = ClusterConfig(num_nodes=1, interpreters_per_node=4)
        rng = np.random.default_rng(11)
        st = StrategyConfig(kind="none")

        def tenant(name):
            costs = rng.exponential(1e-3, 40)
            sizes = np.full(40, 256.0)
            streams = [[] for _ in range(cluster.num_workers)]
            streams[0] = [Batch(costs=costs, sizes=sizes.copy())]
            return TenantQuery(name, streams, st, 0.0, 1e-4)

        tenants = [tenant("a"), tenant("b")]
        sim = MultiQuerySimulator(cluster, none_closed_form=False)
        fast = sim.run(tenants)
        assert sim.last_event_counts["enqueues_coalesced"] >= 1
        heap = MultiQuerySimulator(
            cluster, none_closed_form=False, closed_form_drain=False
        ).run(tenants)
        for a, b in zip(fast, heap):
            assert a.latency == b.latency
            np.testing.assert_array_equal(a.per_worker_busy,
                                          b.per_worker_busy)
        total = sum(b.costs.sum() for t in tenants for s in t.streams
                    for b in s)
        np.testing.assert_allclose(
            sum(r.per_worker_busy.sum() for r in fast), total, rtol=1e-9
        )


class TestMultiQuerySimulator:
    def _tenants(self, cluster, num=4, resolve=dyskew_strategy, seed=0):
        profiles = multi_tenant_suite(num, seed=41)
        return staggered_tenants(profiles, cluster, resolve, seed=seed)

    def test_conservation_per_tenant(self):
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._tenants(cluster)
        results = MultiQuerySimulator(cluster).run(tenants)
        assert len(results) == len(tenants)
        for t, r in zip(tenants, results):
            total_cost = sum(b.costs.sum() for s in t.streams for b in s)
            np.testing.assert_allclose(
                r.per_worker_busy.sum(), total_cost, rtol=1e-9
            )
            assert r.latency > 0

    def test_determinism(self):
        cluster = ClusterConfig(num_nodes=2)
        r1 = MultiQuerySimulator(cluster).run(self._tenants(cluster))
        r2 = MultiQuerySimulator(cluster).run(self._tenants(cluster))
        for a, b in zip(r1, r2):
            assert a.latency == b.latency
            assert a.rows_redistributed == b.rows_redistributed

    def test_contention_slows_tenants_vs_solo(self):
        """A tenant sharing the cluster can't beat its solo run."""
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._tenants(cluster)
        shared = MultiQuerySimulator(cluster).run(tenants)
        for t, r in zip(tenants, shared):
            solo = MultiQuerySimulator(cluster).run(
                [TenantQuery(t.name, t.streams, t.strategy, 0.0,
                             t.arrival_gap)]
            )[0]
            assert r.latency >= solo.latency * 0.999

    def test_dyskew_beats_legacy_under_concurrency(self):
        cluster = ClusterConfig(num_nodes=4)
        profiles = multi_tenant_suite(6, seed=43)
        leg = MultiQuerySimulator(cluster).run(
            staggered_tenants(profiles, cluster, legacy_strategy, seed=0)
        )
        dk = MultiQuerySimulator(cluster).run(
            staggered_tenants(profiles, cluster, dyskew_strategy, seed=0)
        )
        assert np.mean([r.latency for r in dk]) < np.mean(
            [r.latency for r in leg]
        )

    def test_single_tenant_matches_simulator(self):
        """One tenant on the shared engine == the single-query engine
        EXACTLY: `Simulator.run_query` is the N=1 case of the unified
        loop, not a separate implementation."""
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="solo", n_rows=2000, mean_row_cost=1e-3, cost_sigma=1.0,
            partition_alpha=0.8, hot_fraction=0.2,
        )
        st = default_strategies()["dyskew"]
        batches = generate_query(prof, cluster.num_workers, seed=5)
        gap = scan_arrival_gap(prof, cluster)
        solo = Simulator(cluster, st, 0).run_query(batches, gap)
        multi = MultiQuerySimulator(cluster).run(
            [TenantQuery("solo", batches, st, 0.0, gap)]
        )[0]
        assert multi.latency == solo.latency
        assert multi.rows_redistributed == solo.rows_redistributed
        np.testing.assert_array_equal(
            multi.per_worker_busy, solo.per_worker_busy
        )
