"""Integration tests for the discrete-event simulator (paper-faithful layer)."""

import numpy as np
import pytest

from repro.core.types import DySkewConfig, Policy
from repro.sim.engine import (
    Batch,
    ClusterConfig,
    Simulator,
    StrategyConfig,
    waterfill_counts,
)
from repro.sim.workload import (
    QueryProfile,
    generate_query,
    heavy_rows_case,
    self_skip_case,
)
from repro.sim.replay import default_strategies, run_suite, scan_arrival_gap


def _skewed_profile(**kw):
    d = dict(
        name="t", n_rows=4000, mean_row_cost=1e-3, cost_sigma=1.5,
        partition_alpha=1.5, hot_fraction=0.3,
    )
    d.update(kw)
    return QueryProfile(**d)


class TestWaterfill:
    def test_exact_total(self):
        for k in (0, 1, 7, 64, 1000):
            c = waterfill_counts(np.random.default_rng(0).random(16), k, 0.01)
            assert c.sum() == k

    def test_levels_unbalanced_bins(self):
        bl = np.array([10.0, 0.0, 0.0, 0.0])
        c = waterfill_counts(bl, 30, 1.0)
        # bin 0 is 10 ahead; others get ~13 each, bin 0 gets ~0.
        assert c[0] <= 1
        assert c[1:].min() >= 9

    def test_respects_infinite_bins(self):
        bl = np.array([0.0, np.inf, 0.0, np.inf])
        c = waterfill_counts(bl, 10, 1.0)
        assert c[1] == 0 and c[3] == 0
        assert c.sum() == 10


class TestEngine:
    def test_all_rows_processed(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = _skewed_profile()
        batches = generate_query(prof, cluster.num_workers, seed=0)
        total_rows = sum(b.num_rows for s in batches for b in s)
        assert total_rows == prof.n_rows
        for st in default_strategies().values():
            r = Simulator(cluster, st, seed=0).run_query(batches)
            total_cost = sum(b.costs.sum() for s in batches for b in s)
            # busy time conservation: every row processed exactly once.
            np.testing.assert_allclose(r.per_worker_busy.sum(), total_cost, rtol=1e-9)

    def test_latency_bounded_below_by_ideal(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = _skewed_profile()
        batches = generate_query(prof, cluster.num_workers, seed=0)
        total_cost = sum(b.costs.sum() for s in batches for b in s)
        ideal = total_cost / cluster.num_workers
        for st in default_strategies().values():
            r = Simulator(cluster, st, seed=0).run_query(batches)
            assert r.latency >= ideal * 0.999

    def test_redistribution_beats_none_on_partition_skew(self):
        cluster = ClusterConfig(num_nodes=4)
        prof = _skewed_profile(hot_fraction=0.5, partition_alpha=2.0)
        batches = generate_query(prof, cluster.num_workers, seed=1)
        gap = scan_arrival_gap(prof, cluster)
        sts = default_strategies()
        none = Simulator(cluster, sts["none"], 0).run_query(batches, gap)
        dk = Simulator(cluster, sts["dyskew"], 0).run_query(batches, gap)
        assert dk.latency < 0.5 * none.latency
        assert dk.utilization > none.utilization

    def test_dyskew_beats_static_rr_on_cost_skew(self):
        # Heavy-tailed UDF cost: single rows stall workers; backlog-aware
        # routing stops feeding them while round-robin keeps queueing.
        cluster = ClusterConfig(num_nodes=8)
        prof = QueryProfile(
            name="cs", n_rows=12_000, mean_row_cost=2e-3, cost_sigma=2.0
        )
        batches = generate_query(prof, cluster.num_workers, seed=2)
        gap = scan_arrival_gap(prof, cluster)
        sts = default_strategies()
        rr = Simulator(cluster, sts["static_rr"], 0).run_query(batches, gap)
        dk = Simulator(cluster, sts["dyskew"], 0).run_query(batches, gap)
        assert dk.latency < rr.latency

    def test_determinism(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = _skewed_profile()
        batches = generate_query(prof, cluster.num_workers, seed=3)
        st = default_strategies()["dyskew"]
        r1 = Simulator(cluster, st, seed=5).run_query(batches)
        r2 = Simulator(cluster, st, seed=5).run_query(batches)
        assert r1.latency == r2.latency
        assert r1.rows_redistributed == r2.rows_redistributed


class TestHeavyRows:
    """§III.B: unguarded eager redistribution regresses badly on huge rows;
    the Row Size Model (batch density + row size) recovers it."""

    def _run(self, st):
        cluster = ClusterConfig(num_nodes=4)
        prof = heavy_rows_case(row_gb=4.0, n_rows=48)
        batches = generate_query(prof, cluster.num_workers, seed=0)
        return Simulator(cluster, st, seed=0).run_query(batches)

    def test_unguarded_regression_and_guarded_recovery(self):
        none = self._run(StrategyConfig(kind="none"))
        unguarded = self._run(StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(
                policy=Policy.EAGER_SNOWPARK, cost_gate=0.0,
                min_batch_density_frac=0.0,
            ),
            enable_density_guard=False, enable_cost_gate=False,
        ))
        guarded = self._run(StrategyConfig(kind="dyskew"))
        # Paper: regressions up to 20x; we require at least 5x here
        # (cluster-config dependent) and near-complete recovery.
        assert unguarded.latency > 5.0 * none.latency
        assert guarded.latency < 1.2 * none.latency
        assert guarded.bytes_moved_remote < 0.05 * unguarded.bytes_moved_remote


class TestSelfSkip:
    """§III.B 'Forced Remote Distribution': skipping the local worker wastes
    local CPU and network, regressing vs the location-agnostic strategy,
    especially on small clusters."""

    def test_self_skip_regresses_on_small_cluster(self):
        cluster = ClusterConfig(num_nodes=2)
        prof = self_skip_case()
        batches = generate_query(prof, cluster.num_workers, seed=0)
        gap = scan_arrival_gap(prof, cluster)
        agnostic = Simulator(
            cluster,
            StrategyConfig(kind="dyskew",
                           dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK)),
            0,
        ).run_query(batches, gap)
        forced = Simulator(
            cluster,
            StrategyConfig(
                kind="dyskew",
                dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK, self_skip=True),
            ),
            0,
        ).run_query(batches, gap)
        assert agnostic.latency <= forced.latency
        # Forced-remote also moves strictly more bytes over the network.
        assert forced.bytes_moved_remote > agnostic.bytes_moved_remote


class TestReplayHarness:
    def test_run_suite_aggregates(self):
        cluster = ClusterConfig(num_nodes=2)
        profiles = [_skewed_profile(name=f"q{i}", n_rows=2000) for i in range(4)]
        res = run_suite(profiles, cluster, default_strategies()["dyskew"], seed=0)
        assert len(res.results) == 4
        assert res.p(99) >= res.p(50)
        assert 0.0 <= res.mean_utilization() <= 1.0
