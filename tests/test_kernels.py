"""Per-kernel validation: shape/dtype sweeps, interpret=True vs jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dispatch.kernel import dispatch_gather
from repro.kernels.dispatch.ref import dispatch_gather_ref
from repro.kernels.histogram.kernel import load_histogram
from repro.kernels.histogram.ref import load_histogram_ref
from repro.kernels.ssd_scan.kernel import ssd_state_scan
from repro.kernels.ssd_scan.ref import ssd_state_scan_ref
from repro.kernels.topk_gating.kernel import topk_gating
from repro.kernels.topk_gating.ref import topk_gating_ref


class TestDispatchKernel:
    @pytest.mark.parametrize("T,S,D", [(64, 128, 128), (256, 512, 256),
                                       (128, 64, 512), (32, 32, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, T, S, D, dtype):
        key = jax.random.PRNGKey(T + S + D)
        ks = jax.random.split(key, 3)
        x = jax.random.normal(ks[0], (T, D), dtype)
        src = jax.random.randint(ks[1], (S,), 0, T)
        valid = jax.random.bernoulli(ks[2], 0.8, (S,))
        out = dispatch_gather(x, src, valid, block_s=32, block_d=128,
                              interpret=True)
        ref = dispatch_gather_ref(x, src, valid)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32)
        )

    def test_all_invalid_is_zero(self):
        x = jnp.ones((16, 128))
        src = jnp.zeros((32,), jnp.int32)
        valid = jnp.zeros((32,), bool)
        out = dispatch_gather(x, src, valid, block_s=32, block_d=128,
                              interpret=True)
        assert float(jnp.abs(out).max()) == 0.0


class TestHistogramKernel:
    @pytest.mark.parametrize("N,E", [(256, 8), (1024, 64), (2048, 384),
                                     (4096, 32)])
    def test_matches_ref(self, N, E):
        ids = jax.random.randint(jax.random.PRNGKey(N + E), (N,), 0, E)
        out = load_histogram(ids, num_dest=E, block_n=256, interpret=True)
        ref = load_histogram_ref(ids, E)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
        assert float(out.sum()) == N

    def test_skewed_distribution(self):
        ids = jnp.concatenate([jnp.zeros((900,), jnp.int32),
                               jnp.ones((124,), jnp.int32)])
        out = load_histogram(ids, num_dest=16, block_n=256, interpret=True)
        assert float(out[0]) == 900 and float(out[1]) == 124


class TestTopkGatingKernel:
    @pytest.mark.parametrize("T,E,k", [(128, 8, 2), (256, 64, 4),
                                       (512, 384, 8), (64, 16, 1)])
    def test_matches_ref(self, T, E, k):
        logits = jax.random.normal(jax.random.PRNGKey(T + E + k), (T, E))
        w, idx = topk_gating(logits, k=k, block_t=64, interpret=True)
        wr, idxr = topk_gating_ref(logits, k)
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(idxr))
        np.testing.assert_allclose(np.asarray(w), np.asarray(wr),
                                   rtol=1e-5, atol=1e-6)

    def test_weights_normalized(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (128, 32))
        w, _ = topk_gating(logits, k=4, block_t=64, interpret=True)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


class TestSsdScanKernel:
    @pytest.mark.parametrize("C,H,P,N", [(8, 8, 16, 16), (16, 16, 64, 32),
                                         (32, 8, 64, 128)])
    def test_matches_ref(self, C, H, P, N):
        key = jax.random.PRNGKey(C * H + P + N)
        ks = jax.random.split(key, 2)
        states = jax.random.normal(ks[0], (C, H, P, N))
        decay = jax.nn.sigmoid(jax.random.normal(ks[1], (C, H)))  # (0,1)
        out = ssd_state_scan(states, decay, block_h=4, block_p=16,
                             interpret=True)
        ref = ssd_state_scan_ref(states, decay)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_first_prefix_is_zero(self):
        states = jnp.ones((4, 4, 8, 8))
        decay = jnp.full((4, 4), 0.5)
        out = ssd_state_scan(states, decay, block_h=4, block_p=8,
                             interpret=True)
        assert float(jnp.abs(out[0]).max()) == 0.0


class TestKernelOpsIntegration:
    def test_dispatch_reproduces_moe_buffer(self):
        """The dispatch kernel computes the same buffer the MoE layer
        builds with take_along_axis."""
        T, D, E, C = 64, 128, 8, 16
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (T, D))
        src = jax.random.randint(jax.random.PRNGKey(4), (E * C,), 0, T)
        valid = jax.random.bernoulli(jax.random.PRNGKey(5), 0.7, (E * C,))
        kbuf = dispatch_gather(x, src, valid, block_s=32, block_d=128,
                               interpret=True)
        jbuf = jnp.take_along_axis(x[None], src[None, :, None], axis=1)[0]
        jbuf = jbuf * valid[:, None]
        np.testing.assert_allclose(np.asarray(kbuf), np.asarray(jbuf))


class TestSsdScanConsistency:
    def test_scan_composes_with_chunk_recurrence(self):
        """Feeding the kernel's prefix states into the chunk bodies must
        reproduce a direct sequential recurrence."""
        C, H, P, N = 8, 4, 8, 8
        states = jax.random.normal(jax.random.PRNGKey(0), (C, H, P, N))
        decay = jnp.full((C, H), 0.9)
        prefix = ssd_state_scan_ref(states, decay)
        h = jnp.zeros((H, P, N))
        for c in range(C):
            np.testing.assert_allclose(np.asarray(prefix[c]), np.asarray(h),
                                       rtol=1e-5, atol=1e-6)
            h = h * 0.9 + states[c]
