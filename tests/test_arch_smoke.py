"""Per-architecture smoke tests: reduced same-family config, one forward +
one loss/grad step + prefill/decode on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import all_arch_ids, get_config
from repro.models.layers.moe import SpmdCtx
from repro.models.model_api import build

BATCH, SEQ = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    out = {
        "tokens": jax.random.randint(ks[0], (BATCH, SEQ), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (BATCH, SEQ), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            ks[2], (BATCH, cfg.encoder_len, cfg.d_model), jnp.float32
        )
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            ks[2], (BATCH, cfg.num_patches, cfg.d_model), jnp.float32
        )
    return out


@pytest.fixture(scope="module", params=all_arch_ids())
def arch(request):
    full = get_config(request.param)
    cfg = full.reduced()
    # Smoke on CPU in fp32 for numerical checks.
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    return request.param, cfg


class TestSmoke:
    def test_loss_and_grads_finite(self, arch):
        name, cfg = arch
        model = build(cfg)
        key = jax.random.PRNGKey(0)
        params = model.init(key)
        batch = _batch(cfg, jax.random.PRNGKey(1))
        dyskew = model.dyskew_init()

        def loss_fn(p):
            loss, aux = model.loss(p, batch, dyskew=dyskew)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        assert np.isfinite(float(loss)), name
        gnorm = jnp.sqrt(
            sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
        )
        assert np.isfinite(float(gnorm)), name
        assert float(gnorm) > 0.0, name

    def test_prefill_then_decode(self, arch):
        name, cfg = arch
        if cfg.family == "encdec" and cfg.num_heads == 0:
            pytest.skip("n/a")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        max_seq = SEQ + 4
        state = model.decode_state_init(BATCH, max_seq)
        inputs = {k: v for k, v in batch.items() if k != "targets"}
        logits, state = model.prefill(params, inputs, state)
        assert logits.shape == (BATCH, SEQ, cfg.padded_vocab)
        assert int(state["pos"]) == SEQ
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(2):
            logits1, state = model.decode_step(params, state, tok)
            assert logits1.shape == (BATCH, 1, cfg.padded_vocab)
            assert bool(jnp.all(jnp.isfinite(logits1)))
            tok = jnp.argmax(logits1, axis=-1).astype(jnp.int32)

    def test_decode_matches_full_forward(self, arch):
        """Causality/cache correctness: token-by-token decode logits must
        match the full forward pass."""
        name, cfg = arch
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = _batch(cfg, jax.random.PRNGKey(1))
        inputs = {k: v for k, v in batch.items() if k != "targets"}

        if cfg.family == "encdec":
            from repro.models import encdec

            enc_out = encdec.encode(params, batch["frames"], cfg)
            full_logits, _ = encdec.forward(
                params, batch["tokens"], cfg=cfg, enc_out=enc_out
            )
        else:
            from repro.models import transformer

            full_logits, _ = transformer.forward(
                params, batch["tokens"], cfg=cfg,
                dyskew=model.dyskew_init(),
                prefix_embeds=inputs.get("patches"),
            )

        # int8 KV caches (qwen) trade exactness for capacity — wider band.
        int8 = cfg.kv_cache_dtype == "int8"
        rtol, atol = (0.5, 0.25) if int8 else (2e-2, 2e-3)
        # Prefill on the first half, decode the second half step by step.
        half = SEQ // 2
        state = model.decode_state_init(BATCH, SEQ)
        pre_inputs = dict(inputs, tokens=inputs["tokens"][:, :half])
        logits_p, state = model.prefill(params, pre_inputs, state)
        np.testing.assert_allclose(
            np.asarray(logits_p), np.asarray(full_logits[:, :half]),
            rtol=rtol, atol=atol,
        )
        for t in range(half, min(half + 3, SEQ)):
            tok = inputs["tokens"][:, t : t + 1]
            logits_t, state = model.decode_step(params, state, tok)
            np.testing.assert_allclose(
                np.asarray(logits_t[:, 0]), np.asarray(full_logits[:, t]),
                rtol=rtol, atol=atol, err_msg=f"{name} step {t}",
            )


def test_param_counts_match_estimates():
    """Full configs: spec param count within 12% of the analytic estimate."""
    from repro.models.model_api import build as b

    for arch_id in all_arch_ids():
        cfg = get_config(arch_id)
        est = cfg.param_count()
        actual = b(cfg).num_params()
        assert abs(actual - est) / est < 0.12, (arch_id, est, actual)


def test_full_config_param_counts_sane():
    """Published parameter-count sanity bands for the full configs."""
    bands = {
        "granite-20b": (18e9, 23e9),
        "chatglm3-6b": (5.5e9, 7.5e9),
        "starcoder2-3b": (2.7e9, 4e9),
        "qwen1.5-32b": (29e9, 36e9),
        "pixtral-12b": (11e9, 14e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.15e12),
        "mamba2-1.3b": (1.0e9, 1.7e9),
        "granite-moe-1b-a400m": (0.9e9, 1.5e9),
        "whisper-base": (55e6, 110e6),
    }
    for arch_id, (lo, hi) in bands.items():
        cfg = get_config(arch_id)
        n = build(cfg).num_params()
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
