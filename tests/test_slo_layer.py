"""The SLO layer end to end: deadline-aware admission (EDF credit
boost, EDF release order, preemption bookkeeping), warehouse
autoscaling (hysteresis policy + the engine's RESIZE event, pinned
against a fixed-pool run), the repaired serving timeline (prefill floor,
honest migration accounting, truncation reporting) and the data
pipeline's pack-time token conservation."""

import numpy as np
import pytest

from repro.core.admission import (
    AutoscaleConfig,
    AutoscalePolicy,
    DeadlineAwareAdmission,
    DeadlineConfig,
    FairShareConfig,
)
from repro.serving.engine import Request, ServeConfig, ServingEngine
from repro.sim.engine import ClusterConfig, MultiQuerySimulator, TenantQuery
from repro.sim.replay import (
    dyskew_strategy,
    open_loop_rate,
    run_open_loop,
    scan_arrival_gap,
)
from repro.sim.workload import ArrivalProcess, QueryProfile, generate_query, slo_suite

FS = FairShareConfig(quantum_rows=64.0, heavy_row_bytes=1e6)


class TestDeadlineAwarePlanner:
    """Unit tests for the EDF-boosted admission planner."""

    def test_slo_targets_length_validated(self):
        with pytest.raises(ValueError):
            DeadlineAwareAdmission([1.0, 1.0], [0.5])

    def test_boost_admits_urgent_but_charges_in_full(self):
        """An urgent request (slack ~0) is admitted where a deadline-free
        one is refused — and the full charge still lands as debt."""
        # caps: burst_quanta(4) * quantum(64) * share(0.5) = 128 rows.
        p = DeadlineAwareAdmission([1.0, 1.0], [0.5, None], FS)
        assert p.try_admit(1, 256, 0.0)          # idle bypass → busy pool
        # Drain tenant 0 near zero (admitted via cap saturation).
        assert p.try_admit(0, 118, 0.0, deadline=100.0, now=0.0)
        d0 = p.deficit_rows[0]
        assert d0 == pytest.approx(10.0)
        # Far-from-deadline ask: refused (deficit below rows, no boost).
        assert not p.try_admit(0, 60, 0.0, deadline=100.0, now=0.0)
        # Same ask at the deadline: boosted through (max boost =
        # boost_quanta(2) * quantum(64) * share(0.5) = 64 rows).
        assert p.try_admit(0, 60, 0.0, deadline=0.0, now=0.0)
        assert p.deficit_rows[0] == pytest.approx(d0 - 60)  # full charge
        assert p.deficit_rows[0] < 0  # debt, not minted credit
        assert p.boost_admits[0] == 1

    def test_edf_release_order_prefers_earliest_deadline(self):
        p = DeadlineAwareAdmission(
            [1.0, 1.0, 1.0], [5.0, 1.0, None], FS
        )
        assert p.try_admit(2, 512, 0.0)  # saturate via the no-SLO tenant
        # Drain 0 and 1 below their caps, then park them with deadlines;
        # 1's deadline is earlier.
        assert p.try_admit(0, 10, 0.0, deadline=5.0, now=0.0)
        assert p.try_admit(1, 10, 0.0, deadline=1.0, now=0.0)
        assert not p.try_admit(0, 1_000, 0.0, deadline=5.0, now=0.0)
        assert not p.try_admit(1, 1_000, 0.0, deadline=1.0, now=0.0)
        order = p.release_order()
        assert order.index(1) < order.index(0)
        assert order.index(0) < order.index(2)  # no-deadline last

    def test_starvation_freedom_no_slo_tenant_still_admitted(self):
        """A deadline-free tenant under constant urgent pressure is still
        admitted once completions lift its deficit back to the cap."""
        cfg = FairShareConfig(quantum_rows=64.0, burst_quanta=2.0)
        p = DeadlineAwareAdmission([1.0, 1.0], [0.1, None], cfg)
        assert p.try_admit(0, 200, 0.0, deadline=0.0, now=0.0)
        assert p.try_admit(1, 32, 0.0)      # drains below the cap
        assert not p.try_admit(1, 64, 0.0)  # parked behind the urgent one
        for _ in range(8):
            p.on_complete(0, 32)
            if p.try_admit(1, 64, 0.0):
                break
        else:
            pytest.fail("deadline-free tenant starved")

    def test_preempt_candidates_names_over_share_tenants(self):
        p = DeadlineAwareAdmission([1.0, 1.0, 1.0], [0.5, None, None], FS)
        assert p.try_admit(1, 900, 0.0)
        assert p.try_admit(2, 100, 0.0)
        cands = p.preempt_candidates(protect=(0,))
        assert cands and cands[0][0] == 1      # most-over-share first
        assert all(q != 0 for q, _ in cands)   # protected
        # Tenant 2 holds 10% of 1000 rows against a 1/3 share: not over.
        assert all(q != 2 for q, _ in cands)

    def test_preempt_transfer_bookkeeping(self):
        p = DeadlineAwareAdmission([1.0, 1.0], [0.5, None], FS)
        assert p.try_admit(1, 400, 0.0)
        assert p.try_admit(0, 50, 0.0)  # drain 0 so the advance shows
        out1 = p.outstanding_rows[1]
        d0, d1 = p.deficit_rows
        p.preempt_transfer(victim=1, urgent=0, rows=100)
        assert p.outstanding_rows[1] == pytest.approx(out1 - 100)
        assert p.deficit_rows[1] > d1          # victim refunded
        assert p.deficit_rows[0] > d0          # urgent advanced
        assert p.preempted_rows[1] == pytest.approx(100)
        assert p.backlogged[1]


class TestAutoscalePolicy:
    def test_hysteresis_grow_shrink_cooldown(self):
        cfg = AutoscaleConfig(min_workers=4, max_workers=16,
                              backlog_high=32.0, backlog_low=4.0,
                              step=4, cooldown=1.0)
        pol = AutoscalePolicy(cfg)
        assert pol.decide(0.0, 4, 1_000.0) == 8          # grow
        assert pol.decide(0.5, 8, 1_000.0) == 8          # cooldown holds
        assert pol.decide(1.5, 8, 1_000.0) == 12         # grow again
        assert pol.decide(3.0, 12, 0.0) == 8             # shrink
        assert pol.decide(10.0, 4, 0.0) == 4             # floor
        assert [r[1:] for r in pol.resizes] == [(4, 8), (8, 12), (12, 8)]

    def test_attainment_triggers_growth(self):
        cfg = AutoscaleConfig(min_workers=4, max_workers=8, step=4,
                              backlog_high=1e9, attainment_low=0.9)
        pol = AutoscalePolicy(cfg)
        # Backlog alone is below the threshold; sagging attainment grows.
        assert pol.decide(0.0, 4, 10.0, attainment=0.5) == 8
        # Healthy attainment with the same backlog: no change.
        pol2 = AutoscalePolicy(cfg)
        assert pol2.decide(0.0, 4, 10.0, attainment=1.0) == 4


def _uniform_tenants(cluster, weights, slos=None, n_rows=1500, seed=10):
    prof = QueryProfile(
        name="t", n_rows=n_rows, mean_row_cost=1.2e-3, cost_sigma=0.8,
        partition_alpha=0.6, hot_fraction=0.1,
    )
    gap = scan_arrival_gap(prof, cluster)
    slos = slos or [None] * len(weights)
    return [
        TenantQuery(
            f"t{i}", generate_query(prof, cluster.num_workers, seed=seed + i),
            dyskew_strategy(prof), 0.0, gap, weight=w, slo_target=s,
        )
        for i, (w, s) in enumerate(zip(weights, slos))
    ]


def _total_cost(t: TenantQuery) -> float:
    return sum(float(b.costs.sum()) for s in t.streams for b in s)


class TestEngineSLOLayer:
    """Deadline admission, preemption and RESIZE inside the event loop."""

    def test_resize_noop_equivalence_vs_fixed_pool(self):
        """An autoscaled run whose pool is pinned at the full cluster
        (min == max == n) fires RESIZE events that never change anything:
        every result must be IDENTICAL to the fixed-pool run."""
        cluster = ClusterConfig(num_nodes=2)
        n = cluster.num_workers
        base = MultiQuerySimulator(cluster, fair_share=FS).run(
            _uniform_tenants(cluster, (4.0, 1.0, 1.0))
        )
        pinned = MultiQuerySimulator(
            cluster, fair_share=FS,
            autoscale=AutoscaleConfig(min_workers=n, max_workers=n),
        ).run(_uniform_tenants(cluster, (4.0, 1.0, 1.0)))
        for a, b in zip(base, pinned):
            assert a.latency == b.latency
            assert a.rows_redistributed == b.rows_redistributed
            np.testing.assert_array_equal(a.per_worker_busy, b.per_worker_busy)

    def test_autoscale_grows_under_overload_and_conserves_work(self):
        cluster = ClusterConfig(num_nodes=2)
        sim = MultiQuerySimulator(
            cluster, fair_share=FS,
            autoscale=AutoscaleConfig(
                min_workers=4, max_workers=cluster.num_workers,
                backlog_high=16.0, step=4, interval=0.05, cooldown=0.1,
            ),
        )
        tenants = _uniform_tenants(cluster, (1.0, 1.0, 1.0, 1.0))
        results = sim.run(tenants)
        assert any(new > old for _, old, new in sim.last_resizes)
        for t, r in zip(tenants, results):
            np.testing.assert_allclose(
                r.per_worker_busy.sum(), _total_cost(t), rtol=1e-9
            )

    def test_preemption_conserves_every_row(self):
        """Preempted rows are re-injected, never lost: per-tenant busy
        time still equals the tenant's total hidden cost, and the run
        under open-loop overload actually preempts something."""
        cluster = ClusterConfig(num_nodes=2)
        specs = slo_suite()
        proc = ArrivalProcess(
            kind="poisson",
            rate=open_loop_rate([p for p, _, _ in specs], cluster, load=2.5),
        )
        out = run_open_loop(
            specs, cluster, proc, 10, seed=0, fair_share=FS,
            deadline_aware=True, preemption=True,
            deadline_cfg=DeadlineConfig(urgency_horizon=1.0, boost_quanta=4.0),
        )
        assert out["event_counts"]["preempted_rows"] > 0
        for t, r in zip(out["tenants"], out["results"]):
            np.testing.assert_allclose(
                r.per_worker_busy.sum(),
                sum(float(b.costs.sum()) for s in t.streams for b in s),
                rtol=1e-9,
            )
        assert sum(r.preempted_rows for r in out["results"]) == (
            out["event_counts"]["preempted_rows"]
        )

    def test_deadline_aware_beats_weight_only_under_overload(self):
        """The acceptance scenario at test scale: identical overloaded
        traffic, deadline-aware admission must not lose to weight-only
        fair share on overall SLO attainment."""
        cluster = ClusterConfig(num_nodes=2)
        specs = slo_suite()
        proc = ArrivalProcess(
            kind="poisson",
            rate=open_loop_rate([p for p, _, _ in specs], cluster, load=2.5),
        )
        kw = dict(fair_share=FS, seed=0)
        base = run_open_loop(specs, cluster, proc, 14, **kw)
        dl = run_open_loop(
            specs, cluster, proc, 14, deadline_aware=True,
            deadline_cfg=DeadlineConfig(urgency_horizon=1.0, boost_quanta=4.0),
            **kw,
        )
        assert dl["slo_attainment"] >= base["slo_attainment"]
        g_base = base["per_class"]["gold"]
        g_dl = dl["per_class"]["gold"]
        assert g_dl["slo_attainment"] >= g_base["slo_attainment"]
        assert g_dl["p99_tardiness"] <= g_base["p99_tardiness"] + 1e-9

    def test_deadline_run_is_deterministic(self):
        cluster = ClusterConfig(num_nodes=2)

        def go():
            return MultiQuerySimulator(
                cluster, fair_share=FS, deadline_aware=True, preemption=True,
            ).run(_uniform_tenants(cluster, (1.0, 1.0, 1.0),
                                   slos=(0.3, None, None)))

        for a, b in zip(go(), go()):
            assert a.latency == b.latency
            assert a.preempted_rows == b.preempted_rows


class TestServingTimeline:
    """The repaired request-level timeline + serving SLO layer."""

    def test_prefill_latency_floor(self):
        """A huge prompt cannot finish faster than prompt/prefill_rate +
        decode time (the seed engine skipped prefill entirely)."""
        cfg = ServeConfig(num_replicas=1, max_batch=4,
                          prefill_rate=10_000.0, decode_rate=1_000.0)
        res = ServingEngine(cfg).run([
            Request(rid=0, prompt_len=40_000, max_new_tokens=100,
                    arrival=0.0)
        ])
        floor = 40_000 / 10_000.0 + 100 / 1_000.0
        assert res["completed"] == 1
        # Discrete 10 ms steps: allow 2 quanta of slack.
        assert res["mean_latency"] >= floor - 2 * 10e-3

    def test_migration_charges_delay_but_not_unprefilled_kv(self):
        """Queued requests that never prefilled migrate with ZERO KV
        bytes (nothing was materialized) yet still pay migration
        latency in simulated time."""
        cfg = ServeConfig(num_replicas=2, max_batch=2, decode_rate=500.0,
                          scheduler="dyskew")
        reqs = [Request(rid=0, prompt_len=64, max_new_tokens=5_000,
                        arrival=0.0)]
        reqs += [Request(rid=1 + i, prompt_len=64, max_new_tokens=400,
                         arrival=0.001) for i in range(12)]
        res = ServingEngine(cfg).run(reqs)
        assert res["completed"] == len(reqs)
        assert res["migrations"] > 0
        assert res["migrated_gb"] == 0.0       # no prefilled KV moved
        assert res["migration_delay_s"] > 0.0  # ... but the move took time
        assert res["migration_delay_s"] == pytest.approx(
            res["migrations"] * cfg.migration_latency
        )

    def test_kv_counts_only_materialized_tokens(self):
        r = Request(rid=0, prompt_len=512, max_new_tokens=64, arrival=0.0)
        assert r.kv_len == 0                   # nothing prefilled yet
        r.prefilled = 512
        r.generated = 10
        assert r.kv_len == 522
        assert r.kv_bytes(2.0) == pytest.approx(1044.0)

    def test_truncation_is_reported_not_silent(self):
        cfg = ServeConfig(num_replicas=1, max_batch=1, decode_rate=1.0,
                          max_sim_s=0.5)
        res = ServingEngine(cfg).run([
            Request(rid=i, prompt_len=16, max_new_tokens=10_000,
                    arrival=0.0) for i in range(3)
        ])
        assert res["truncated"]
        assert res["incomplete"] == 3
        assert res["completed"] == 0

    def test_slot_preemption_rescues_gold_deadlines(self):
        cfg = ServeConfig(
            num_replicas=2, max_batch=4, decode_rate=2_000.0,
            tenant_weights=(1.0, 1.0), slo_targets=(0.5, None),
            deadline_aware=True, preemption=True,
        )
        reqs = []
        for i in range(40):
            gold = i % 4 == 0
            reqs.append(Request(
                rid=i, prompt_len=128,
                max_new_tokens=60 if gold else 400,
                arrival=i * 0.01, tenant=0 if gold else 1,
            ))
        res = ServingEngine(cfg).run(reqs)
        assert res["preemptions"] > 0
        assert res["per_tenant"][0]["slo_attainment"] >= 0.9
        assert res["per_tenant"][0]["p99_tardiness"] <= 0.1
        assert "slo_attainment" in res


class TestPipelineTokenConservation:
    """pack_documents carry + pack-time tenant token accounting."""

    def test_unpacked_doc_is_carried_not_dropped(self):
        from repro.data.pipeline import pack_documents

        docs = iter([
            np.ones(200, np.int32),
            np.ones(100, np.int32),   # fits nowhere after the 200
            np.ones(56, np.int32),
        ])
        carry = []
        seqs = pack_documents(docs, seq_len=256, count=1, carry=carry)
        assert int((seqs[0] != 0).sum()) == 256      # 200 + 56 packed
        assert [len(d) for d in carry] == [100]      # carried, not lost
        # Next batch packs the carried doc first.
        seqs2 = pack_documents(iter([]), seq_len=256, count=1, carry=carry)
        assert int((seqs2[0] != 0).sum()) == 100
        assert carry == []

    def test_tenant_tokens_equal_emitted_tokens(self):
        """The counters must equal the non-pad tokens that actually
        reached batches — the seed credited at draw time and then
        dropped unpacked docs, so the books never balanced."""
        from repro.data.pipeline import DataConfig, DataPipeline

        cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=8,
                         doc_len_mean=180.0, doc_len_sigma=1.2,
                         tenant_weights=(3.0, 1.0), seed=5, num_shards=1)
        pipe = DataPipeline(cfg)
        emitted = 0
        for _ in range(10):
            emitted += int((next(pipe)["tokens"] != 0).sum())
        assert int(pipe.tenant_tokens.sum()) == emitted


class TestPickNextDebt:
    def test_rotation_fallback_charges_cost_instead_of_free_reset(self):
        """The rotation-bound fallback must charge the served item like
        the normal path (carrying debt) — zeroing the deficit gave
        oversized items a free reset and broke weighted shares."""
        from repro.core.admission import FairShareAdmission

        p = FairShareAdmission(
            [1.0, 1.0], FairShareConfig(quantum_rows=1.0)
        )
        # Deep pre-existing debt: the bounded rotation cannot recover it,
        # so pick_next must take the fallback path.
        p.deficit_rows[0] = -5_000.0
        before = p.deficit_rows[0]
        q = p.pick_next([10.0, None])
        assert q == 0
        # Debt persists (bounded rotation gains minus the item charge) —
        # NOT reset to zero: 24 loop iterations visit tenant 0 twelve
        # times at +1 row each, then the fallback charges the cost.
        assert p.deficit_rows[0] == pytest.approx(before + 12.0 - 10.0)
