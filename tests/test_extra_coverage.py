"""Additional edge-case coverage: adaptive-link telemetry, serving
migration behavior, data-pipeline permutation invariants, report
rendering, launcher configs."""

import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveLink, AdaptiveLinkConfig, DySkewConfig, Policy
from repro.data.pipeline import DataConfig, DataPipeline
from repro.roofline.report import fmt_bytes, fmt_s, roofline_table
from repro.serving.engine import Request, ServeConfig, ServingEngine


class TestAdaptiveLinkTelemetry:
    def test_cost_gate_telemetry_fields(self):
        link = AdaptiveLink(AdaptiveLinkConfig(
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK),
            num_instances=4,
        ))
        state = link.init_state()
        state, plan = link.step(
            state, jnp.ones(16), jnp.full(16, 1e3), jnp.zeros(16, jnp.int32)
        )
        assert float(plan.est_bytes_moved) > 0
        assert float(plan.est_time_saved) > 0

    def test_transitions_counted_once_per_commit(self):
        link = AdaptiveLink(AdaptiveLinkConfig(
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK),
            num_instances=2,
        ))
        state = link.init_state()
        for _ in range(5):
            state, _ = link.step(
                state, jnp.ones(8), jnp.ones(8), jnp.zeros(8, jnp.int32)
            )
        assert np.asarray(state["transitions"]).max() == 1


class TestServingMigration:
    def test_skewed_queues_trigger_migration(self):
        """A burst landing on one replica (all arrivals before the others
        spin up) must be spread by the DySkew rebalance pass."""
        cfg = ServeConfig(num_replicas=4, scheduler="dyskew",
                          kv_bytes_per_token=1e3)  # tiny KV → cheap to move
        # Long-running requests arriving simultaneously: least-loaded
        # placement ties are broken to replica 0 first.
        reqs = [
            Request(rid=i, prompt_len=64, max_new_tokens=500, arrival=0.0)
            for i in range(32)
        ]
        res = ServingEngine(cfg).run(reqs)
        assert res["completed"] == 32

    def test_round_robin_spreads_placement(self):
        cfg = ServeConfig(num_replicas=4, scheduler="round_robin")
        reqs = [Request(rid=i, prompt_len=64, max_new_tokens=10,
                        arrival=0.0) for i in range(8)]
        res = ServingEngine(cfg).run(reqs)
        assert res["completed"] == 8
        assert res["migrations"] == 0  # rr never migrates


class TestDataPipelinePermutation:
    def test_dyskew_reorder_preserves_sequences(self):
        """Balancing may permute rows across shards but must not create or
        destroy tokens."""
        cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=8,
                         num_shards=4, dyskew_balance=True, seed=3)
        pipe = DataPipeline(cfg)
        b = next(pipe)
        cfg2 = DataConfig(vocab_size=100, seq_len=64, global_batch=8,
                          num_shards=4, dyskew_balance=False, seed=3)
        b2 = next(DataPipeline(cfg2))
        # same multiset of row-hashes regardless of balancing
        h1 = sorted(hash(r.tobytes()) for r in b["tokens"])
        h2 = sorted(hash(r.tobytes()) for r in b2["tokens"])
        assert h1 == h2


class TestReportRendering:
    def test_skip_rows_render(self):
        recs = [
            {"arch": "a", "shape": "long_500k", "mesh": "single",
             "status": "SKIP: quadratic"},
            {"arch": "a", "shape": "train_4k", "mesh": "single",
             "status": "OK",
             "roofline": {
                 "t_compute_s": 1.0, "t_memory_s": 2.0,
                 "t_collective_s": 0.5, "bottleneck": "memory",
                 "useful_flops_ratio": 0.7,
                 "collective_bytes_global": 1e12,
             },
             "memory": {"per_device_total_gb": 1.5, "fits_hbm": True}},
        ]
        table = roofline_table(recs, "single")
        assert "SKIP" in table and "memory" in table

    def test_formatters(self):
        assert fmt_s(0) == "0"
        assert fmt_s(5e-6).endswith("µs")
        assert fmt_s(0.005).endswith("ms")
        assert fmt_bytes(2e12) == "2.0TB"
        assert fmt_bytes(512) == "512B"


class TestLauncherConfigs:
    def test_all_archs_have_reduced_variants(self):
        from repro.config.base import all_arch_ids, get_config

        for a in all_arch_ids():
            r = get_config(a).reduced()
            assert r.d_model <= 128
            assert r.vocab_size <= 512
            if r.moe:
                # dropless in reduced mode (capacity covers worst case)
                assert r.moe.capacity_factor >= r.moe.num_experts / r.moe.top_k

    def test_perf_flag_parsing(self):
        from repro.launch.dryrun import parse_flags

        flags, h7, h6 = parse_flags("h1,h5,h7")
        assert flags.causal_skip and flags.constrain_activations
        assert h7 and not h6
        flags, h7, h6 = parse_flags("")
        assert not flags.causal_skip and not h7
