"""Tests for tools/lint — the dyslint framework and its four passes.

Each pass gets at least one positive, one suppressed, and one clean
fixture snippet, exercised in-process through the same Module/pass API
the runner uses.  The suite also pins the two ends of the contract:
the ``contracts.CAPABILITY_FLAGS`` table must match the live
``RedistributionPolicy`` class attributes, and the deliberately
misdeclared policy in ``tests/lint_fixtures/`` must make the runner
exit non-zero while the real tree exits zero.
"""

import os
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)

from tools.lint import (  # noqa: E402
    Module,
    dump_baseline,
    load_baseline,
    split_baselined,
    split_suppressed,
    suppressions,
)
from tools.lint.passes import (  # noqa: E402
    ALL_PASSES,
    PROGRAM_PASSES,
    all_codes,
    capability,
    determinism,
    float_order,
    jax_hazard,
)
from tools.lint import runner  # noqa: E402

CONTRACTS = runner.load_contracts()

SIM_PATH = "src/repro/sim/fixture.py"           # determinism scope
PINNED_PATH = "src/repro/sim/engine.py"         # float-order scope


def _lint(pass_mod, source, path="src/repro/core/fixture.py"):
    """Run one pass over a snippet; returns (active, suppressed)."""
    module = Module.from_source(path, textwrap.dedent(source))
    assert pass_mod.applies(path, CONTRACTS)
    findings = pass_mod.run(module, CONTRACTS)
    return split_suppressed(findings, module.lines)


def _codes(findings):
    return sorted(f.code for f in findings)


# --------------------------------------------------------------------- #
# Framework
# --------------------------------------------------------------------- #

class TestFramework:
    def test_trailing_suppression_hits_its_own_line(self):
        supp = suppressions(["x = 1  # dyslint: disable=DY101 -- why"])
        assert supp == {1: {"DY101"}}

    def test_comment_only_suppression_hits_next_line(self):
        supp = suppressions([
            "    # dyslint: disable=DY202, DY402 -- reason",
            "    self._rr += 1",
        ])
        assert supp == {2: {"DY202", "DY402"}}

    def test_unrelated_comments_do_not_suppress(self):
        assert suppressions(["# TODO dyslint someday", "x = 1"]) == {}

    def test_pass_codes_are_disjoint_and_prefixed(self):
        seen = {}
        for p in ALL_PASSES + PROGRAM_PASSES:
            for code in p.CODES:
                assert code.startswith("DY"), code
                assert code not in seen, f"{code} claimed twice"
                seen[code] = p.NAME
        assert set(all_codes()) == set(seen)

    def test_baseline_roundtrip_and_staleness(self, tmp_path):
        src = "import random\nr = random.random()\n"
        active, _ = _lint(determinism, src, SIM_PATH)
        assert _codes(active) == ["DY103"]
        lines = {SIM_PATH: src.splitlines()}
        bl_file = tmp_path / "baseline.json"
        bl_file.write_text(dump_baseline(active, lines))
        baseline = load_baseline(str(bl_file))
        new, old, stale = split_baselined(active, baseline, lines)
        assert (new, len(old), stale) == ([], 1, 0)
        # Remove the offending line: the entry goes stale, not silent.
        new, old, stale = split_baselined([], baseline, lines)
        assert (new, old, stale) == ([], [], 1)


# --------------------------------------------------------------------- #
# DY1xx determinism
# --------------------------------------------------------------------- #

class TestDeterminismPass:
    def test_scope_is_sim_path_only(self):
        assert determinism.applies(SIM_PATH, CONTRACTS)
        assert not determinism.applies("tools/check_bench.py", CONTRACTS)
        assert not determinism.applies("src/repro/models/net.py", CONTRACTS)

    def test_global_sampler_and_argless_rng(self):
        active, _ = _lint(determinism, """\
            import numpy as np
            a = np.random.choice(4)
            g = np.random.default_rng()
        """, SIM_PATH)
        assert _codes(active) == ["DY101", "DY102"]

    def test_default_factory_pattern_is_caught(self):
        # The exact bug dogfooding found in PolicyContext: the bare
        # function object handed to default_factory is an argless
        # generator at every dataclass construction.
        active, _ = _lint(determinism, """\
            import dataclasses
            import numpy as np

            @dataclasses.dataclass
            class Ctx:
                rng: np.random.Generator = dataclasses.field(
                    default_factory=np.random.default_rng
                )
        """, SIM_PATH)
        assert _codes(active) == ["DY102"]

    def test_stdlib_random_wall_clock_environ(self):
        active, _ = _lint(determinism, """\
            import os
            import random
            import time
            r = random.random()
            t = time.time()
            for k in os.environ:
                print(k)
        """, SIM_PATH)
        assert _codes(active) == ["DY103", "DY104", "DY105"]

    def test_seeded_rng_is_clean(self):
        active, _ = _lint(determinism, """\
            import numpy as np
            g = np.random.default_rng(0)
            h = np.random.default_rng(seed=123)
            x = g.normal(size=4)
        """, SIM_PATH)
        assert active == []

    def test_suppression_silences_the_finding(self):
        active, silenced = _lint(determinism, """\
            import time
            t0 = time.perf_counter()  # dyslint: disable=DY104 -- log only
        """, SIM_PATH)
        assert active == []
        assert _codes(silenced) == ["DY104"]


# --------------------------------------------------------------------- #
# DY2xx capability contract
# --------------------------------------------------------------------- #

_POLICY_HEADER = (
    "import numpy as np\n"
    "from repro.core.policy import RedistributionPolicy, "
    "register_policy\n\n"
)


def _lint_policy(body):
    """Capability-pass helper: dedent the class snippet FIRST, then
    prepend the (already flush-left) import header."""
    return _lint(capability, _POLICY_HEADER + textwrap.dedent(body))


class TestCapabilityPass:
    def test_flags_table_matches_live_base_class(self):
        from repro.core.policy import RedistributionPolicy

        live = {
            k: getattr(RedistributionPolicy, k)
            for k in CONTRACTS.CAPABILITY_FLAGS
        }
        assert live == CONTRACTS.CAPABILITY_FLAGS

    def test_undeclared_rng_use(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                def propose(self, producer, k, backlog, unit):
                    return self.ctx.rng.integers(0, k)
        """)
        assert _codes(active) == ["DY201"]

    def test_declared_but_unused_stochastic(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                stochastic = True
                def propose(self, producer, k, backlog, unit):
                    return None
        """)
        assert _codes(active) == ["DY205"]

    def test_mutation_outside_route_propose(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                def place_one(self, backlog):
                    self.count += 1
                    return 0
        """)
        assert _codes(active) == ["DY202"]

    def test_private_helper_called_from_propose_is_allowed(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                def propose(self, producer, k, backlog, unit):
                    self._observe(backlog)
                    return None
                def _observe(self, backlog):
                    self.seen = backlog.copy()
        """)
        assert active == []

    def test_link_mask_requires_uses_link(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                def propose(self, producer, k, backlog, unit):
                    if self.link_mask is None:
                        return None
                    return None
        """)
        assert _codes(active) == ["DY203"]

    def test_never_redistributes_must_stay_on_producer(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                never_redistributes = True
                def propose(self, producer, k, backlog, unit):
                    counts = np.zeros(len(backlog), np.int64)
                    counts[int(np.argmin(backlog))] = k
                    return counts
        """)
        assert _codes(active) == ["DY204"]

    def test_honest_policy_is_clean(self):
        active, _ = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                stochastic = True
                def propose(self, producer, k, backlog, unit):
                    counts = np.zeros(len(backlog), np.int64)
                    j = int(self.ctx.rng.integers(0, len(backlog)))
                    counts[j] = k
                    return counts
        """)
        assert active == []

    def test_unregistered_class_is_ignored(self):
        active, _ = _lint(capability, """\
            class NotAPolicy:
                def place_one(self, backlog):
                    self.count += 1
        """)
        assert active == []

    def test_suppression_silences_the_finding(self):
        active, silenced = _lint_policy("""\
            @register_policy
            class P(RedistributionPolicy):
                name = "p"
                def place_one(self, backlog):
                    # dyslint: disable=DY202 -- serving seam, sim never calls it
                    self.count += 1
                    return 0
        """)
        assert active == []
        assert _codes(silenced) == ["DY202"]

    def test_misdeclared_fixture_fails_the_runner(self, capsys):
        rc = runner.main([
            "tests/lint_fixtures/misdeclared_policy.py", "--no-baseline",
        ])
        out = capsys.readouterr().out
        assert rc == 1
        assert "DY202" in out and "SneakyStatefulPolicy" in out


# --------------------------------------------------------------------- #
# DY3xx jax hazards
# --------------------------------------------------------------------- #

class TestJaxHazardPass:
    def test_branch_and_host_sync_in_jitted_fn(self):
        active, _ = _lint(jax_hazard, """\
            import jax
            import jax.numpy as jnp

            @jax.jit
            def step(x):
                if x > 0:
                    x = x + 1
                return float(x.sum())
        """)
        assert _codes(active) == ["DY301", "DY303"]

    def test_jit_call_site_and_transform_args_are_reachable(self):
        active, _ = _lint(jax_hazard, """\
            import jax

            def inner(x):
                return x.item()

            run = jax.vmap(inner)
        """)
        assert _codes(active) == ["DY301"]

    def test_per_call_jit_is_a_retrace_hazard(self):
        active, _ = _lint(jax_hazard, """\
            import jax

            def caller(f, x):
                return jax.jit(f)(x)
        """)
        assert _codes(active) == ["DY304"]

    def test_shape_branches_and_static_args_are_clean(self):
        active, _ = _lint(jax_hazard, """\
            import functools
            import jax
            import jax.numpy as jnp

            @functools.partial(jax.jit, static_argnames=("cfg",))
            def step(x, cfg):
                if x.ndim > 1:
                    x = x.sum(axis=0)
                if cfg.use_bias:
                    x = x + 1.0
                return jnp.tanh(x)
        """)
        assert active == []

    def test_unjitted_host_code_is_clean(self):
        active, _ = _lint(jax_hazard, """\
            import numpy as np

            def summarize(x):
                if x > 0:
                    return float(x)
                return np.asarray(x)
        """)
        assert active == []

    def test_suppression_silences_the_finding(self):
        active, silenced = _lint(jax_hazard, """\
            import jax

            @jax.jit
            def step(x):
                return x.item()  # dyslint: disable=DY301 -- debug-only path
        """)
        assert active == []
        assert _codes(silenced) == ["DY301"]


# --------------------------------------------------------------------- #
# DY4xx float order
# --------------------------------------------------------------------- #

class TestFloatOrderPass:
    def test_scope_is_pinned_modules_only(self):
        assert float_order.applies(PINNED_PATH, CONTRACTS)
        assert not float_order.applies(SIM_PATH, CONTRACTS)

    def test_sum_over_set_and_dict_accumulation(self):
        active, _ = _lint(float_order, """\
            def tally(rates, by_class):
                total = sum({r * 2 for r in rates})
                acc = 0.0
                for v in by_class.values():
                    acc += v
                return total + acc
        """, PINNED_PATH)
        assert _codes(active) == ["DY401", "DY402"]

    def test_sorted_iteration_is_clean(self):
        active, _ = _lint(float_order, """\
            def tally(rates, by_class):
                total = sum(sorted({r * 2 for r in rates}))
                acc = 0.0
                for k in sorted(by_class):
                    acc += by_class[k]
                return total + acc
        """, PINNED_PATH)
        assert active == []

    def test_non_accumulating_dict_loop_is_clean(self):
        active, _ = _lint(float_order, """\
            def flags(by_class):
                out = {}
                for k, v in by_class.items():
                    out[k] = v > 0
                return out
        """, PINNED_PATH)
        assert active == []

    def test_suppression_silences_the_finding(self):
        active, silenced = _lint(float_order, """\
            def count(pending):
                n = 0
                # dyslint: disable=DY402 -- integer counter, order-free
                for v in pending.values():
                    n += len(v)
                return n
        """, PINNED_PATH)
        assert active == []
        assert _codes(silenced) == ["DY402"]


# --------------------------------------------------------------------- #
# The real tree
# --------------------------------------------------------------------- #

class TestRealTree:
    def test_default_scope_is_green(self, capsys):
        """`make lint` semantics: the shipped tree has zero active
        findings (inline suppressions and baseline included)."""
        rc = runner.main([])
        out = capsys.readouterr().out
        assert rc == 0, out
        assert "0 finding(s)" in out

    def test_contracts_load_without_repro_import(self):
        mod = runner.load_contracts()
        assert "repro" not in sys.modules or mod.__name__ not in (
            "repro.core.contracts",
        )
        assert mod.CAPABILITY_FLAGS["drain_safe"] is True
