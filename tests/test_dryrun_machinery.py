"""Dry-run machinery tests: spec/init consistency, sharding resolution,
and a reduced-config multi-device lower+compile in a subprocess."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.config.base import all_arch_ids, get_config
from repro.models.model_api import build
from repro.models.param import default_rules, resolve_pspec, spec


class TestDecodeStateSpecs:
    @pytest.mark.parametrize("arch_id", all_arch_ids())
    def test_specs_match_init_shapes(self, arch_id):
        """decode_state_specs must mirror decode_state_init exactly —
        the dry-run shardings are resolved from the spec tree."""
        cfg = get_config(arch_id).reduced()
        model = build(cfg)
        live = model.decode_state_init(2, 16)
        ab = model.decode_state_specs(2, 16)
        live_shapes = jax.tree.map(lambda x: tuple(x.shape), live)
        ab_shapes = jax.tree.map(
            lambda s: tuple(s.shape), ab,
            is_leaf=lambda x: hasattr(x, "axes"),
        )
        assert live_shapes == ab_shapes, arch_id
        live_dt = jax.tree.map(lambda x: str(x.dtype), live)
        ab_dt = jax.tree.map(
            lambda s: str(jnp.dtype(s.dtype)), ab,
            is_leaf=lambda x: hasattr(x, "axes"),
        )
        assert live_dt == ab_dt, arch_id


class TestShardingResolution:
    def _mesh(self):
        import numpy as np
        from jax.sharding import Mesh

        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_divisible_dim_sharded(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = default_rules(multi_pod=False)
        ps = resolve_pspec(spec((64, 32), ("embed", "mlp")), mesh, rules)
        assert ps == jax.sharding.PartitionSpec("data", "model")

    def test_indivisible_dim_replicated(self):
        # 7 not divisible by any axis > 1 → replicate that dim.
        mesh = jax.make_mesh((1,), ("model",))
        rules = {"heads": "model", None: None}
        ps = resolve_pspec(spec((7, 4), ("heads", None)), mesh, rules)
        assert ps == jax.sharding.PartitionSpec("model", None)

    def test_mesh_axis_used_once(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = {"a": "model", "b": "model", None: None}
        ps = resolve_pspec(spec((8, 8), ("a", "b")), mesh, rules)
        # second dim must not reuse 'model'
        assert ps[1] is None


SUBPROCESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config.base import get_config
from repro.models.layers.moe import SpmdCtx
from repro.models.model_api import build
from repro.models.param import default_rules, tree_abstract, tree_shardings
from repro.optim.optimizers import OptimizerConfig
from repro.optim.specs import opt_state_specs
from repro.train.step import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = get_config("granite-moe-1b-a400m").reduced()
model = build(cfg)
rules = default_rules(False)
rules["batch"] = ("data",)
pspecs = model.specs()
opt_cfg = OptimizerConfig(name="adamw")
ospecs = opt_state_specs(opt_cfg, pspecs)
ctx = SpmdCtx(num_groups=4, num_ep_shards=2)
fn = make_train_step(model, opt_cfg, ctx=ctx)
state_ab = {
    "params": tree_abstract(pspecs),
    "opt": tree_abstract(ospecs),
    "step": jax.ShapeDtypeStruct((), jnp.int32),
}
state_sh = {
    "params": tree_shardings(pspecs, mesh, rules),
    "opt": tree_shardings(ospecs, mesh, rules),
    "step": NamedSharding(mesh, P()),
}
dk = model.dyskew_init(ctx)
state_ab["dyskew"] = jax.eval_shape(lambda: dk)
state_sh["dyskew"] = jax.tree.map(lambda _: NamedSharding(mesh, P()), state_ab["dyskew"])
batch_ab = dict(
    tokens=jax.ShapeDtypeStruct((8, 32), jnp.int32),
    targets=jax.ShapeDtypeStruct((8, 32), jnp.int32),
)
tok_sh = NamedSharding(mesh, P(("data",), None))
with mesh:
    compiled = jax.jit(
        fn,
        in_shardings=(state_sh, dict(tokens=tok_sh, targets=tok_sh)),
        out_shardings=(state_sh, None),
    ).lower(state_ab, batch_ab).compile()
cost = compiled.cost_analysis()
if isinstance(cost, list):
    cost = cost[0]
assert cost.get("flops", 0) > 0
print("SUBPROCESS_OK")
"""


class TestMultiDeviceCompile:
    def test_reduced_moe_train_step_compiles_on_8_devices(self):
        """End-to-end sharded lower+compile of the DySkew-MoE train step on
        an 8-host-device mesh (subprocess: device count is process-global)."""
        # Propagate backend-selection env vars: without JAX_PLATFORMS the
        # child may probe for a TPU runtime (30 s+ metadata stalls) and
        # blow the timeout on CPU-only hosts.
        env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
        env.update({
            k: v for k, v in os.environ.items()
            if k.startswith(("JAX_", "XLA_", "TPU_", "LIBTPU"))
        })
        env.setdefault("JAX_PLATFORMS", "cpu")
        res = subprocess.run(
            [sys.executable, "-c", SUBPROCESS_SCRIPT],
            capture_output=True, text=True, timeout=420,
            env=env,
        )
        assert "SUBPROCESS_OK" in res.stdout, res.stderr[-2000:]
