"""Pinned baseline for `repro.runtime.fault_tolerance`: heartbeat-driven
failure detection, sync-slope straggler detection with N-strikes
hysteresis, elastic membership (exclude / rejoin without flapping), and
mesh reshaping — the substrate the spot-worker work builds on."""

import numpy as np
import pytest

from repro.runtime.fault_tolerance import (
    FaultConfig,
    FaultTolerantRuntime,
    elastic_mesh_shape,
)

CFG = FaultConfig()  # heartbeat 10s, 3 missed beats dead, 3 strikes


def beat_all(rt, now, step_times):
    for h, st in enumerate(step_times):
        if rt.hosts[h].alive:
            rt.heartbeat(h, now, st)


def run_ticks(rt, n_hosts, ticks, step_fn, start=0.0):
    """Drive `ticks` heartbeat+tick rounds; step_fn(host, k) gives each
    host's per-round step time.  Returns the last tick report."""
    out = {"failed": [], "stragglers": []}
    for k in range(ticks):
        now = start + (k + 1) * CFG.heartbeat_interval
        beat_all(rt, now, [step_fn(h, k) for h in range(n_hosts)])
        out = rt.tick(now)
    return out


class TestFailureDetection:
    def test_healthy_fleet_no_detections(self):
        rt = FaultTolerantRuntime(4)
        for k in range(10):
            now = (k + 1) * 10.0
            beat_all(rt, now, [1.0] * 4)
            rep = rt.tick(now)
            assert rep["failed"] == []
            assert rep["stragglers"] == []
        assert rt.events == []

    def test_silent_host_flagged_dead_after_grace(self):
        rt = FaultTolerantRuntime(4)
        # Host 3 stops beating from t=0; others stay healthy.
        flagged_at = None
        for k in range(6):
            now = (k + 1) * 10.0
            for h in range(3):
                rt.heartbeat(h, now, 1.0)
            rep = rt.tick(now)
            if rep["failed"]:
                flagged_at = k
                assert rep["failed"] == [3]
                break
        # idle-time grace: the tick at one interval is still inside the
        # 1.5x freshness window, then missed_beats_dead silent ticks.
        assert flagged_at == CFG.missed_beats_dead
        assert (rt.tick(70.0)["failed"] == [3])  # stays flagged

    def test_one_missed_beat_is_not_death(self):
        rt = FaultTolerantRuntime(3)
        beat_all(rt, 10.0, [1.0] * 3)
        rt.tick(10.0)
        # host 2 misses exactly one beat, then recovers
        rt.heartbeat(0, 20.0, 1.0)
        rt.heartbeat(1, 20.0, 1.0)
        assert rt.tick(20.0)["failed"] == []
        beat_all(rt, 30.0, [1.0] * 3)
        assert rt.tick(30.0)["failed"] == []


class TestStragglerDetection:
    def test_accelerating_host_flagged_before_failure(self):
        rt = FaultTolerantRuntime(4)
        # Host 0's step time grows 3x siblings': cumulative sync slope
        # pulls away while it still beats on schedule.
        rep = run_ticks(rt, 4, 12, lambda h, k: 3.0 if h == 0 else 1.0)
        assert rep["stragglers"] == [0]
        assert rep["failed"] == []
        kinds = {e[1] for e in rt.events}
        assert kinds == {"straggler"}

    def test_transient_skew_suppressed_by_strikes(self):
        rt = FaultTolerantRuntime(4)
        # One slow ROUND (not a slow host): strikes must not accumulate
        # to n_strikes, so nobody is flagged.
        rep = run_ticks(
            rt, 4, 10,
            lambda h, k: 5.0 if (h == 0 and k == 4) else 1.0,
        )
        assert rep["stragglers"] == []
        assert all(e[1] != "straggler" for e in rt.events)

    def test_uniform_slowdown_is_not_skew(self):
        rt = FaultTolerantRuntime(4)
        # Everyone slows down together — no one is a straggler.
        rep = run_ticks(rt, 4, 10, lambda h, k: 1.0 + 0.5 * k)
        assert rep["stragglers"] == []


class TestElasticMembership:
    def test_exclude_and_survivors(self):
        rt = FaultTolerantRuntime(5)
        assert rt.exclude([1, 3]) == [0, 2, 4]
        assert not rt.hosts[1].alive
        assert rt.survivors() == [0, 2, 4]

    def test_exclude_respects_min_hosts(self):
        rt = FaultTolerantRuntime(3, FaultConfig(min_hosts=2))
        assert rt.exclude([0, 1, 2]) == [1, 2]
        assert len(rt.survivors()) == 2

    def test_excluded_host_not_reported(self):
        rt = FaultTolerantRuntime(4)
        rt.exclude([0])
        # Host 0 stays silent (it's gone) — it must not appear in
        # failure reports anymore.
        rep = run_ticks(rt, 4, 5, lambda h, k: 1.0)
        assert rep["failed"] == []

    def test_rejoin_restores_membership(self):
        rt = FaultTolerantRuntime(4)
        rt.exclude([2])
        rt.rejoin(2, now=100.0)
        assert rt.survivors() == [0, 1, 2, 3]
        assert rt.hosts[2].alive

    def test_rejoined_straggler_does_not_flap(self):
        """The latent-bug pin: a host excluded as a straggler must come
        back CLEAN — leftover strikes + its old accelerating sync window
        used to re-flag it on the first tick after rejoin."""
        rt = FaultTolerantRuntime(4)
        rep = run_ticks(rt, 4, 12, lambda h, k: 3.0 if h == 0 else 1.0)
        assert rep["stragglers"] == [0]
        rt.exclude([0])
        rejoin_t = 130.0
        rt.rejoin(0, now=rejoin_t)
        assert rt.strikes[0] == 0
        # Healthy behaviour after rejoin: never flagged again.
        for k in range(6):
            now = rejoin_t + (k + 1) * CFG.heartbeat_interval
            beat_all(rt, now, [1.0] * 4)
            rep = rt.tick(now)
            assert 0 not in rep["stragglers"]
            assert 0 not in rep["failed"]

    def test_failed_host_replacement_cycle(self):
        """End-to-end recovery path: detect death → exclude → remesh →
        rejoin → healthy fleet again."""
        rt = FaultTolerantRuntime(4)
        for k in range(4):
            now = (k + 1) * 10.0
            for h in range(3):
                rt.heartbeat(h, now, 1.0)
            rep = rt.tick(now)
        assert 3 in rep["failed"]
        survivors = rt.exclude(rep["failed"])
        assert survivors == [0, 1, 2]
        assert elastic_mesh_shape(len(survivors)) == (1, 12)
        rt.rejoin(3, now=50.0)
        rep = run_ticks(rt, 4, 4, lambda h, k: 1.0, start=50.0)
        assert rep["failed"] == [] and rep["stragglers"] == []
        assert elastic_mesh_shape(len(rt.survivors())) == (1, 16)


class TestElasticMeshShape:
    @pytest.mark.parametrize("hosts,chips,expect", [
        (4, 4, (1, 16)),
        (8, 4, (2, 16)),
        (2, 2, (1, 4)),
        (1, 1, (1, 1)),
        (3, 4, (1, 12)),
    ])
    def test_shapes(self, hosts, chips, expect):
        assert elastic_mesh_shape(hosts, chips) == expect

    def test_total_chips_preserved_or_truncated(self):
        for hosts in range(1, 12):
            d, m = elastic_mesh_shape(hosts)
            assert d * m <= hosts * 4
            assert d >= 1 and m >= 1

    @pytest.mark.parametrize("hosts,chips", [(0, 4), (-1, 4), (4, 0)])
    def test_empty_mesh_rejected(self, hosts, chips):
        """The latent-bug pin: 0 hosts used to raise ZeroDivisionError
        deep in the shape arithmetic instead of a caller-actionable
        error."""
        with pytest.raises(ValueError, match="at least one host"):
            elastic_mesh_shape(hosts, chips)
