"""Fault-injection invariant suite: the deterministic fault layer must
(1) take NOT ONE new branch when the schedule is empty — a faults-off
run and a `faults=None` run are bit-identical, so the legacy rtol-1e-9
equivalence chain and the PR 6/7 digest pins are untouched; (2) conserve
every injected row under crashes/preemptions — a row completes exactly
once, with voided partial service billed to `wasted_service_s`, never
double-counted into busy; (3) replay bit-for-bit under the same seed,
fault statistics included; and (4) never let autoscale shrink the live
pool below `FaultConfig.min_hosts` or decommission a worker
mid-recovery.

Pinned twice, like the pipeline suite: a deterministic parametrized
grid that ALWAYS runs in tier-1, and a hypothesis fuzz layer over the
same checkers when the optional dev dependency is installed."""

import numpy as np
import pytest

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency
    hypothesis = None

from repro.core.admission import (
    AutoscaleConfig,
    DeadlineConfig,
    FairShareConfig,
)
from repro.runtime.fault_tolerance import FaultConfig
from repro.sim.engine import ClusterConfig, MultiQuerySimulator, TenantQuery
from repro.sim.faults import (
    CRASH,
    FAULT_KINDS,
    NIC_DEGRADE,
    PREEMPT,
    SLOWDOWN,
    FaultEvent,
    FaultSchedule,
    hazard_schedule,
)
from repro.sim.replay import dyskew_strategy, scan_arrival_gap
from repro.sim.workload import QueryProfile, generate_query

FS = FairShareConfig(quantum_rows=64.0, heavy_row_bytes=1e6)
# Tight virtual-time detection cadence so short test runs still detect.
FCFG = FaultConfig(heartbeat_interval=0.02, missed_beats_dead=2,
                   n_strikes=3, slope_window=8, min_hosts=2)


def _tenants(cluster, n_tenants=3, n_rows=800, seed=3, weights=None,
             slos=None):
    prof = QueryProfile(
        name="t", n_rows=n_rows, mean_row_cost=1.2e-3, cost_sigma=0.8,
        partition_alpha=0.6, hot_fraction=0.1,
    )
    gap = scan_arrival_gap(prof, cluster)
    weights = weights or [1.0] * n_tenants
    slos = slos or [None] * n_tenants
    return [
        TenantQuery(
            f"t{i}", generate_query(prof, cluster.num_workers, seed=seed + i),
            dyskew_strategy(prof), 0.02 * i, gap, weight=w, slo_target=s,
        )
        for i, (w, s) in enumerate(zip(weights, slos))
    ]


def _total_cost(t: TenantQuery) -> float:
    return sum(float(b.costs.sum()) for s in t.streams for b in s)


def _run(tenants, cluster, faults=None, **kw):
    sim = MultiQuerySimulator(cluster, fair_share=FS, faults=faults,
                              fault_cfg=FCFG if faults else None, **kw)
    return sim, sim.run(tenants)


def _snapshot(results, stats):
    """Everything a same-seed rerun must reproduce bit-for-bit."""
    return (
        tuple(r.latency for r in results),
        tuple(tuple(np.asarray(r.per_worker_busy)) for r in results),
        tuple(r.rows_redistributed for r in results),
        repr(stats),
    )


# ------------------------------------------------------------------ #
# Checkers (shared by the deterministic grid and the fuzz layer)
# ------------------------------------------------------------------ #

def check_empty_schedule_bit_neutral(seed):
    """`faults=FaultSchedule()` takes the same trajectory as
    `faults=None` — exact array equality, not a tolerance."""
    cluster = ClusterConfig(num_nodes=2)
    base_sim, base = _run(_tenants(cluster, seed=seed), cluster)
    sim, out = _run(_tenants(cluster, seed=seed), cluster,
                    faults=FaultSchedule())
    assert sim.last_fault_stats["enabled"] is False
    for a, b in zip(base, out):
        assert a.latency == b.latency
        assert np.array_equal(a.per_worker_busy, b.per_worker_busy)
        assert a.rows_redistributed == b.rows_redistributed


def check_crash_conservation(schedule, seed, cluster=None):
    """Under crash/preempt faults every row's service lands in busy
    EXACTLY once: per-tenant busy time equals the tenant's hidden total
    row cost to float equality (voided partial service is billed to
    wasted_service_s, re-execution replaces — not duplicates — it)."""
    cluster = cluster or ClusterConfig(num_nodes=2)
    tenants = _tenants(cluster, seed=seed)
    sim, out = _run(tenants, cluster, faults=schedule)
    stats = sim.last_fault_stats
    assert stats["enabled"]
    assert stats["unrecovered_rows"] == 0
    for t, r in zip(tenants, out):
        assert float(np.asarray(r.per_worker_busy).sum()) == pytest.approx(
            _total_cost(t), rel=1e-9
        )
    return sim, out


def check_same_seed_bit_identity(schedule, seed):
    cluster = ClusterConfig(num_nodes=2)

    def go():
        sim, out = _run(_tenants(cluster, seed=seed), cluster,
                        faults=schedule, deadline_aware=True,
                        deadline_cfg=DeadlineConfig())
        return _snapshot(out, sim.last_fault_stats)

    assert go() == go()


def check_hazard_run(seed, slowdown=False):
    """Full-stack run under a seeded hazard draw: everything recovered,
    same-seed bit identity, and (crash/preempt-only draws) exact busy
    conservation."""
    cluster = ClusterConfig(num_nodes=2)
    n = cluster.num_workers
    sched = hazard_schedule(
        seed=seed, num_workers=n, num_nodes=cluster.num_nodes,
        horizon=1.5, crash_rate=3.0, preempt_rate=3.0,
        slowdown_rate=2.0 if slowdown else 0.0, mttr=0.3,
        min_live=max(2, n // 2),
    )
    tenants = _tenants(cluster, seed=seed)
    sim, out = _run(tenants, cluster, faults=sched)
    stats = sim.last_fault_stats
    assert stats["unrecovered_rows"] == 0
    if not slowdown:
        # Slowdown inflates billed busy (honest spend), so the exact
        # busy==cost identity only holds for crash/preempt-only draws.
        for t, r in zip(tenants, out):
            assert float(np.asarray(r.per_worker_busy).sum()) == (
                pytest.approx(_total_cost(t), rel=1e-9)
            )
    sim2, out2 = _run(_tenants(cluster, seed=seed), cluster, faults=sched)
    assert _snapshot(out, stats) == _snapshot(out2, sim2.last_fault_stats)


# ------------------------------------------------------------------ #
# Deterministic grid (always runs in tier-1)
# ------------------------------------------------------------------ #

class TestEmptyScheduleNeutral:
    @pytest.mark.parametrize("seed", [3, 11])
    def test_empty_schedule_is_bit_neutral(self, seed):
        check_empty_schedule_bit_neutral(seed)


class TestRowConservation:
    def test_single_crash_conserves_rows(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.05, kind=CRASH, worker=1),
        ))
        sim, _ = check_crash_conservation(sched, seed=3)
        assert sim.last_fault_stats["detections"] >= 1
        assert sum(sim.last_fault_stats["recovered_rows"]) > 0

    def test_crash_with_repair_conserves_rows(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.05, kind=CRASH, worker=2, duration=0.2),
            FaultEvent(time=0.08, kind=CRASH, worker=5, duration=0.3),
        ))
        check_crash_conservation(sched, seed=7)

    def test_preemption_with_notice_conserves_rows(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.04, kind=PREEMPT, worker=0, notice=0.02,
                       duration=0.25),
            FaultEvent(time=0.10, kind=PREEMPT, worker=3, notice=0.02,
                       duration=0.25),
        ))
        check_crash_conservation(sched, seed=5)

    def test_retry_backoff_path_conserves_rows(self):
        """Slow network + simultaneous preemption notices: transfers
        take ~60ms, so sends routed BEFORE a notice (which flips
        routable instantly) land on a draining worker and must bounce —
        the capped exponential backoff retries must still land every
        row exactly once."""
        cluster = ClusterConfig(num_nodes=2, interpreters_per_node=4,
                                network_latency=0.06)
        prof = QueryProfile(
            name="t", n_rows=1200, mean_row_cost=1e-3, cost_sigma=0.8,
            partition_alpha=0.8, hot_fraction=0.2,
        )
        tenants = [TenantQuery(
            "t", generate_query(prof, cluster.num_workers, seed=11),
            dyskew_strategy(prof), 0.0, 1e-4,
        )]
        sched = FaultSchedule(events=tuple(
            FaultEvent(time=0.03, kind=PREEMPT, worker=w, notice=0.02,
                       duration=0.5)
            for w in (4, 5, 6)
        ))
        sim = MultiQuerySimulator(cluster, faults=sched, fault_cfg=FCFG)
        out = sim.run(tenants)
        stats = sim.last_fault_stats
        assert stats["transfer_retries"] > 0
        assert stats["retry_backoff_s"] > 0.0
        assert stats["unrecovered_rows"] == 0
        busy = float(np.asarray(out[0].per_worker_busy).sum())
        assert busy == pytest.approx(_total_cost(tenants[0]), rel=1e-9)


class TestSameSeedBitIdentity:
    def test_mixed_kind_schedule_replays_bit_identically(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.03, kind=CRASH, worker=3),
            FaultEvent(time=0.05, kind=PREEMPT, worker=5, notice=0.03,
                       duration=0.4),
            FaultEvent(time=0.02, kind=SLOWDOWN, worker=1, factor=4.0,
                       duration=0.3),
            FaultEvent(time=0.04, kind=NIC_DEGRADE, worker=0, factor=3.0,
                       duration=0.2),
        ))
        check_same_seed_bit_identity(sched, seed=3)

    @pytest.mark.parametrize("seed", [0, 5])
    def test_hazard_draw_full_stack(self, seed):
        check_hazard_run(seed, slowdown=False)

    def test_hazard_draw_with_slowdowns(self):
        check_hazard_run(seed=2, slowdown=True)


class TestAutoscaleFaultGuard:
    """Satellite guard: scale-down concurrent with crashes must never
    shrink the ACTIVE pool below `FaultConfig.min_hosts` nor
    decommission a worker that is mid-recovery."""

    @staticmethod
    def _no_grow(cluster, min_workers=1):
        # Thresholds no backlog can cross: the pool stays at its
        # starting size for the whole run.
        return AutoscaleConfig(
            min_workers=min_workers, max_workers=cluster.num_workers,
            backlog_high=1e9, backlog_low=0.0,
            step=cluster.interpreters_per_node, interval=0.02,
            cooldown=0.0,
        )

    def test_pool_floor_is_min_hosts_under_faults(self):
        """With faults on, a min_workers=1 autoscaler is floored at
        `min_hosts`: the commissioned pool starts (and stays) at 4
        workers, so even with one of them crashed the recovery has
        live capacity — and row conservation survives the combination."""
        cluster = ClusterConfig(num_nodes=2)
        fcfg = FaultConfig(heartbeat_interval=0.02, missed_beats_dead=2,
                           min_hosts=4)
        sched = FaultSchedule(events=(
            FaultEvent(time=0.05, kind=CRASH, worker=1),
        ))
        sim = MultiQuerySimulator(
            cluster, fair_share=FS, faults=sched, fault_cfg=fcfg,
            autoscale=self._no_grow(cluster),
        )
        out = sim.run(_tenants(cluster, seed=3))
        assert sim.last_fault_stats["unrecovered_rows"] == 0
        busy = sum(np.asarray(r.per_worker_busy) for r in out)
        served = set(np.flatnonzero(busy > 0).tolist())
        assert served <= set(range(fcfg.min_hosts))
        assert len(served) >= fcfg.min_hosts - 1  # worker 1 died early
        for t, r in zip(_tenants(cluster, seed=3), out):
            assert float(np.asarray(r.per_worker_busy).sum()) == (
                pytest.approx(_total_cost(t), rel=1e-9)
            )

    def test_faults_off_keeps_configured_min_workers(self):
        """The floor is a FAULTS-mode guard: without a schedule the
        same min_workers=1 autoscaler really does run one worker."""
        cluster = ClusterConfig(num_nodes=2)
        sim = MultiQuerySimulator(
            cluster, fair_share=FS, autoscale=self._no_grow(cluster),
        )
        out = sim.run(_tenants(cluster, seed=3))
        busy = sum(np.asarray(r.per_worker_busy) for r in out)
        assert set(np.flatnonzero(busy > 0).tolist()) == {0}

    def test_shrink_concurrent_with_crash_respects_guards(self):
        """Grow-then-shrink around a permanent crash: the shrink pass
        must skip live workers whenever decommissioning them would take
        the LIVE pool to (or below) `min_hosts` — observable as a
        nonzero `shrink_blocked_mid_recovery` counter, resize targets
        never below `min_hosts`, and exact conservation throughout."""
        cluster = ClusterConfig(num_nodes=1)  # 8 workers
        fcfg = FaultConfig(heartbeat_interval=0.02, missed_beats_dead=2,
                           min_hosts=6)
        asc = AutoscaleConfig(
            min_workers=2, max_workers=cluster.num_workers,
            backlog_high=8.0, backlog_low=4.0, step=2,
            interval=0.02, cooldown=0.0,
        )
        sched = FaultSchedule(events=(
            FaultEvent(time=0.1, kind=CRASH, worker=3),
        ))
        sim = MultiQuerySimulator(
            cluster, fair_share=FS, faults=sched, fault_cfg=fcfg,
            autoscale=asc,
        )
        out = sim.run(_tenants(cluster, seed=3))
        stats = sim.last_fault_stats
        assert stats["unrecovered_rows"] == 0
        assert stats["shrink_blocked_mid_recovery"] > 0
        assert sim.last_resizes, "the pool must actually resize"
        for _now, _active, target in sim.last_resizes:
            assert target >= fcfg.min_hosts
        for t, r in zip(_tenants(cluster, seed=3), out):
            assert float(np.asarray(r.per_worker_busy).sum()) == (
                pytest.approx(_total_cost(t), rel=1e-9)
            )


# ------------------------------------------------------------------ #
# Schedule construction and validation
# ------------------------------------------------------------------ #

class TestScheduleValidation:
    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(time=0.1, kind="meteor", worker=0)

    def test_negative_time_rejected(self):
        with pytest.raises(ValueError):
            FaultEvent(time=-0.1, kind=CRASH, worker=0)

    def test_slowdown_needs_factor_above_one(self):
        with pytest.raises(ValueError):
            FaultEvent(time=0.1, kind=SLOWDOWN, worker=0, factor=0.5)

    def test_retry_knobs_validated(self):
        with pytest.raises(ValueError):
            FaultSchedule(retry_base=0.0)
        with pytest.raises(ValueError):
            FaultSchedule(retry_base=2e-3, retry_cap=1e-3)

    def test_validate_rejects_out_of_range_targets(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.1, kind=CRASH, worker=9),
        ))
        with pytest.raises(ValueError):
            sched.validate(num_workers=4, num_nodes=1)
        # nic_degrade targets NODES, not workers.
        nic = FaultSchedule(events=(
            FaultEvent(time=0.1, kind=NIC_DEGRADE, worker=2, factor=2.0),
        ))
        with pytest.raises(ValueError):
            nic.validate(num_workers=8, num_nodes=2)

    def test_engine_validates_at_construction(self):
        cluster = ClusterConfig(num_nodes=1)
        sched = FaultSchedule(events=(
            FaultEvent(time=0.1, kind=CRASH, worker=cluster.num_workers),
        ))
        with pytest.raises(ValueError):
            MultiQuerySimulator(cluster, faults=sched)

    def test_events_sorted_and_counted(self):
        sched = FaultSchedule(events=(
            FaultEvent(time=0.3, kind=CRASH, worker=0),
            FaultEvent(time=0.1, kind=SLOWDOWN, worker=1, factor=2.0),
        ))
        assert [e.time for e in sched.events] == [0.1, 0.3]
        counts = sched.injected_counts()
        assert counts[CRASH] == 1 and counts[SLOWDOWN] == 1
        assert bool(sched) and not bool(FaultSchedule())


class TestHazardSchedule:
    def test_same_seed_same_draw(self):
        kw = dict(num_workers=8, num_nodes=2, horizon=2.0,
                  crash_rate=2.0, preempt_rate=1.0, slowdown_rate=1.0,
                  nic_rate=0.5)
        assert hazard_schedule(7, **kw) == hazard_schedule(7, **kw)
        assert hazard_schedule(7, **kw) != hazard_schedule(8, **kw)

    def test_min_live_floor_suppresses_total_wipeout(self):
        """A saturating crash rate with min_live == num_workers draws NO
        crash/preempt events at all — the floor keeps at least min_live
        workers up at every instant."""
        sched = hazard_schedule(
            seed=1, num_workers=4, num_nodes=1, horizon=5.0,
            crash_rate=50.0, preempt_rate=50.0, min_live=4,
        )
        counts = sched.injected_counts()
        assert counts.get(CRASH, 0) == 0 and counts.get(PREEMPT, 0) == 0

    def test_kinds_are_known(self):
        sched = hazard_schedule(
            seed=3, num_workers=8, num_nodes=2, horizon=2.0,
            crash_rate=2.0, preempt_rate=2.0, slowdown_rate=2.0,
            nic_rate=2.0,
        )
        assert sched.events, "saturating rates must draw something"
        assert all(e.kind in FAULT_KINDS for e in sched.events)


# ------------------------------------------------------------------ #
# Hypothesis fuzz layer (optional dev dependency, same checkers)
# ------------------------------------------------------------------ #

if hypothesis is not None:
    FUZZ = settings(max_examples=8, deadline=None)

    class TestFuzzFaults:
        @FUZZ
        @given(seed=st.integers(0, 30))
        def test_hazard_conservation_and_identity(self, seed):
            check_hazard_run(seed, slowdown=False)

        @FUZZ
        @given(seed=st.integers(0, 30))
        def test_hazard_with_slowdowns_recovers(self, seed):
            check_hazard_run(seed, slowdown=True)

        @FUZZ
        @given(seed=st.integers(0, 30),
               t1=st.floats(0.01, 0.2), t2=st.floats(0.01, 0.2),
               w1=st.integers(0, 7), w2=st.integers(0, 7))
        def test_two_crash_conservation(self, seed, t1, t2, w1, w2):
            sched = FaultSchedule(events=(
                FaultEvent(time=t1, kind=CRASH, worker=w1, duration=0.3),
                FaultEvent(time=t2, kind=PREEMPT, worker=w2, notice=0.02,
                           duration=0.3),
            ))
            check_crash_conservation(sched, seed=seed)
