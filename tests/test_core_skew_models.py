"""Unit tests for the DySkew skew-detection models (paper §III.A/B)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import skew_models
from repro.core.types import DySkewConfig, SkewModelKind, link_metrics_zeros


def _metrics(n=4, window=8):
    return link_metrics_zeros(n, window)


class TestRowPercentage:
    def test_balanced_not_skewed(self):
        m = _metrics()
        m["rows"] = jnp.array([100.0, 100.0, 100.0, 100.0])
        skewed = skew_models.row_percentage_skew(m, theta=0.5)
        assert not bool(jnp.any(skewed))

    def test_hot_instance_detected(self):
        # Eq. (1): R_i * theta > mean(R_-i). theta=0.5 fires at >2x sibling avg.
        m = _metrics()
        m["rows"] = jnp.array([500.0, 100.0, 100.0, 100.0])
        skewed = skew_models.row_percentage_skew(m, theta=0.5)
        assert bool(skewed[0])
        assert not bool(jnp.any(skewed[1:]))

    def test_threshold_edge(self):
        m = _metrics()
        # R_0 = 200, siblings avg = 100: 200*0.5 = 100 is NOT > 100.
        m["rows"] = jnp.array([200.0, 100.0, 100.0, 100.0])
        assert not bool(skew_models.row_percentage_skew(m, theta=0.5)[0])
        m["rows"] = jnp.array([201.0, 100.0, 100.0, 100.0])
        assert bool(skew_models.row_percentage_skew(m, theta=0.5)[0])

    def test_single_instance_never_skewed(self):
        m = _metrics(n=1)
        m["rows"] = jnp.array([1e9])
        assert not bool(jnp.any(skew_models.row_percentage_skew(m, theta=0.5)))


class TestIdleTime:
    def test_busy_among_idle_siblings(self):
        m = _metrics()
        m["idle_ticks"] = jnp.array([0.0, 5.0, 5.0, 5.0])
        skewed = skew_models.idle_time_skew(m, idle_grace=2, idle_sibling_frac=0.5)
        assert bool(skewed[0])
        # idle instances themselves are not 'skewed' (they have no work).
        assert not bool(jnp.any(skewed[1:]))

    def test_all_busy_not_skewed(self):
        m = _metrics()
        m["idle_ticks"] = jnp.zeros(4)
        skewed = skew_models.idle_time_skew(m, idle_grace=2, idle_sibling_frac=0.5)
        assert not bool(jnp.any(skewed))

    def test_sibling_fraction_threshold(self):
        m = _metrics()
        # Only 1/3 siblings idle < 0.5 threshold → no skew.
        m["idle_ticks"] = jnp.array([0.0, 5.0, 0.0, 0.0])
        skewed = skew_models.idle_time_skew(m, idle_grace=2, idle_sibling_frac=0.5)
        assert not bool(jnp.any(skewed))


class TestSyncSlope:
    def test_accelerating_instance_detected(self):
        m = _metrics()
        t = jnp.arange(8, dtype=jnp.float32)
        # Instance 0's cumulative sync time grows 10x faster.
        m["sync_window"] = jnp.stack([10.0 * t, t, t, t])
        skewed = skew_models.sync_time_slope_skew(m, theta=0.5)
        assert bool(skewed[0])
        assert not bool(jnp.any(skewed[1:]))

    def test_flat_windows_do_not_fire(self):
        m = _metrics()
        skewed = skew_models.sync_time_slope_skew(m, theta=0.5)
        assert not bool(jnp.any(skewed))

    def test_slope_computation(self):
        w = jnp.array([[0.0, 1.0, 2.0, 3.0], [0.0, 2.0, 4.0, 6.0]])
        s = skew_models.sync_slope(w)
        np.testing.assert_allclose(np.asarray(s), [1.0, 2.0], rtol=1e-6)


class TestNStrikes:
    def test_requires_n_consecutive(self):
        strikes = jnp.zeros((2,), jnp.int32)
        skewed = jnp.array([True, False])
        for i in range(3):
            fire, strikes = skew_models.apply_n_strikes(skewed, strikes, n_strikes=3)
            if i < 2:
                assert not bool(fire[0])
        assert bool(fire[0])
        assert not bool(fire[1])

    def test_reset_on_clean_tick(self):
        strikes = jnp.array([2, 0], jnp.int32)
        fire, strikes = skew_models.apply_n_strikes(
            jnp.array([False, False]), strikes, n_strikes=3
        )
        assert int(strikes[0]) == 0
        assert not bool(jnp.any(fire))


class TestRowSizeModel:
    def test_density_collapse_detected(self):
        cfg = DySkewConfig(target_batch_density=4096.0, min_batch_density_frac=0.01)
        m = _metrics()
        # >99% density drop: 4096 -> 10 rows/batch, rows are 100 MB blobs.
        m["batch_density"] = jnp.array([10.0, 4096.0, 4096.0, 4096.0])
        m["bytes_per_row"] = jnp.array([100e6, 500.0, 500.0, 500.0])
        heavy = skew_models.batch_density_heavy_rows(m, cfg)
        assert bool(heavy[0]) and not bool(jnp.any(heavy[1:]))

    def test_small_remainder_batch_not_heavy(self):
        # A 10-row batch of ordinary 500 B rows (end-of-stream remainder)
        # must NOT count as heavy-row density collapse.
        cfg = DySkewConfig()
        m = _metrics()
        m["batch_density"] = jnp.array([10.0, 4096.0, 4096.0, 4096.0])
        m["bytes_per_row"] = jnp.array([500.0, 500.0, 500.0, 500.0])
        heavy = skew_models.batch_density_heavy_rows(m, cfg)
        assert not bool(jnp.any(heavy))

    def test_zero_density_is_not_evidence(self):
        cfg = DySkewConfig()
        m = _metrics()
        heavy = skew_models.batch_density_heavy_rows(m, cfg)
        assert not bool(jnp.any(heavy))

    def test_disable_requires_not_skewed(self):
        # Paper: disable only when NOT skewed AND density low.
        cfg = DySkewConfig()
        m = _metrics()
        m["batch_density"] = jnp.array([10.0, 10.0, 10.0, 10.0])
        m["bytes_per_row"] = jnp.full((4,), 100e6)
        # Instance 0 busy while others idle → skewed → must NOT disable.
        m["idle_ticks"] = jnp.array([0.0, 5.0, 5.0, 5.0])
        disable = skew_models.heavy_row_disable(m, cfg)
        assert not bool(disable[0])
        # The idle ones are not skewed and have low density → disable fires.
        assert bool(disable[1])


class TestMetricsUpdate:
    def test_idle_tick_accounting(self):
        m = _metrics(n=3)
        m2 = skew_models.update_metrics(
            m,
            rows_this_tick=jnp.array([5.0, 0.0, 2.0]),
            sync_time_this_tick=jnp.array([1.0, 0.0, 1.0]),
            batch_density=jnp.array([5.0, 0.0, 2.0]),
            bytes_per_row=jnp.array([100.0, 0.0, 100.0]),
        )
        np.testing.assert_allclose(np.asarray(m2["idle_ticks"]), [0.0, 1.0, 0.0])
        np.testing.assert_allclose(np.asarray(m2["rows"]), [5.0, 0.0, 2.0])

    def test_sync_window_slides_cumulative(self):
        m = _metrics(n=1, window=4)
        for step in range(4):
            m = skew_models.update_metrics(
                m,
                rows_this_tick=jnp.array([1.0]),
                sync_time_this_tick=jnp.array([2.0]),
                batch_density=jnp.array([1.0]),
                bytes_per_row=jnp.array([8.0]),
            )
        np.testing.assert_allclose(
            np.asarray(m["sync_window"][0]), [2.0, 4.0, 6.0, 8.0]
        )
