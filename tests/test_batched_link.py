"""Tests for the hundreds-of-tenants scaling paths added with the
batched tick engine:

  * `BatchedLinkSim` — T tenants in one jitted call must match T
    independent `AdaptiveLinkSim` instances state-for-state across mixed
    cadences and inactive-tenant masks;
  * the engine's batched-tick mode — conservation, determinism, and the
    auto flag defaulting on only where equivalence is proven;
  * the closed-form 'none' strategy — bit-exact vs the event loop in the
    proven regime, eligibility gating;
  * `sim/replay.py` pool regressions — a poisoned process pool must
    recover on the next `_map_queries` call, and `warm_pool` must surface
    worker crashes instead of discarding its futures.
"""

import warnings
from concurrent.futures import Future

import numpy as np
import pytest

import repro.sim.replay as replay
from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.batched_link import BatchedLinkSim, _next_pow2
from repro.sim.engine import (
    AdaptiveLinkSim,
    ClusterConfig,
    MultiQuerySimulator,
    StrategyConfig,
    TenantQuery,
    _arrivals_on_grid,
    closed_form_none_result,
)
from repro.sim.replay import dyskew_strategy, scan_arrival_gap, staggered_tenants
from repro.sim.workload import QueryProfile, generate_query, multi_tenant_suite


def _tree_leaves(state):
    import jax

    return [np.asarray(x) for x in jax.tree_util.tree_leaves(state)]


def _rand_inputs(rng, n):
    rows = (rng.poisson(3, n) * (rng.random(n) < 0.7)).astype(np.float64)
    sync = rng.random(n) * rows
    density = rng.random(n) * 100.0
    bpr = rng.random(n) * 2e6
    signal = rng.random(n) < 0.3
    return rows, sync, density, bpr, signal


CONFIGS = [
    DySkewConfig(policy=Policy.EAGER_SNOWPARK,
                 skew_model=SkewModelKind.IDLE_TIME, n_strikes=2),
    DySkewConfig(policy=Policy.LATE,
                 skew_model=SkewModelKind.ROW_PERCENTAGE, n_strikes=3),
    DySkewConfig(policy=Policy.LATE,
                 skew_model=SkewModelKind.SYNC_TIME_SLOPE, n_strikes=2),
]


class TestBatchedLinkSim:
    @pytest.mark.parametrize("cfg", CONFIGS, ids=lambda c: c.skew_model.name)
    def test_matches_independent_instances_mixed_cadence(self, cfg):
        """T tenants ticking on DIFFERENT cadences (via the active mask)
        must match T independent AdaptiveLinkSim instances state-for-state
        and mask-for-mask at every step."""
        n, T, steps = 6, 5, 40
        rng = np.random.default_rng(0)
        batched = BatchedLinkSim(cfg, n, T)
        solo = [AdaptiveLinkSim(cfg, n) for _ in range(T)]
        # Tenant t ticks every (t+1)-th step — mixed cadences.
        for step in range(steps):
            active = np.array([step % (t + 1) == 0 for t in range(T)])
            inputs = [_rand_inputs(rng, n) for _ in range(T)]
            stacked = [np.stack([inp[k] for inp in inputs])
                       for k in range(5)]
            dist = batched.tick(*stacked, active=active)
            for t in range(T):
                if active[t]:
                    d = solo[t].tick(*(np.asarray(x) for x in inputs[t]))
                    np.testing.assert_array_equal(dist[t], d)
                else:
                    assert not dist[t].any()
        for t in range(T):
            for a, b in zip(_tree_leaves(solo[t].state),
                            _tree_leaves(batched.state)):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b)[t],
                    err_msg=f"tenant {t} state leaf diverged",
                )

    def test_inactive_rows_frozen(self):
        cfg = CONFIGS[0]
        n, T = 4, 3
        rng = np.random.default_rng(1)
        sim = BatchedLinkSim(cfg, n, T)
        before = [x.copy() for x in _tree_leaves(sim.state)]
        inputs = [np.stack([_rand_inputs(rng, n)[k]] * T) for k in range(5)]
        sim.tick(*inputs, active=np.zeros(T, bool))
        after = _tree_leaves(sim.state)
        for a, b in zip(before, after):
            np.testing.assert_array_equal(a, b)

    def test_capacity_padding(self):
        assert _next_pow2(1) == 1
        assert _next_pow2(2) == 2
        assert _next_pow2(129) == 256
        sim = BatchedLinkSim(CONFIGS[0], 4, 5)
        assert sim.capacity == 8
        assert sim.states.shape == (5, 4)


class TestBatchedEngineMode:
    def _tenants(self, cluster, num=6, seed=43):
        profiles = multi_tenant_suite(num, seed=seed)
        return staggered_tenants(profiles, cluster, dyskew_strategy, seed=0)

    def test_batched_conserves_and_is_deterministic(self):
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._tenants(cluster)
        r1 = MultiQuerySimulator(cluster, batch_ticks=True).run(tenants)
        r2 = MultiQuerySimulator(cluster, batch_ticks=True).run(
            self._tenants(cluster)
        )
        for t, r in zip(tenants, r1):
            total = sum(b.costs.sum() for s in t.streams for b in s)
            np.testing.assert_allclose(r.per_worker_busy.sum(), total,
                                       rtol=1e-9)
        for a, b in zip(r1, r2):
            assert a.latency == b.latency
            assert a.rows_redistributed == b.rows_redistributed

    def test_single_link_tenant_auto_equals_per_tenant(self):
        """The auto default (batch when at most one tenant has a link)
        must be bit-identical to the forced per-tenant path."""
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="auto", n_rows=1500, mean_row_cost=1e-3, cost_sigma=1.0,
            partition_alpha=0.8, hot_fraction=0.2,
        )
        batches = generate_query(prof, cluster.num_workers, seed=7)
        gap = scan_arrival_gap(prof, cluster)
        st = dyskew_strategy(prof)
        t = [TenantQuery("solo", batches, st, 0.0, gap)]
        auto = MultiQuerySimulator(cluster).run(t)[0]
        per = MultiQuerySimulator(cluster, batch_ticks=False).run(t)[0]
        assert auto.latency == per.latency
        assert auto.num_ticks == per.num_ticks
        np.testing.assert_array_equal(auto.per_worker_busy,
                                      per.per_worker_busy)

    def test_batched_groups_by_config(self):
        """Tenants with different (config, cadence) still run correctly
        under forced batching (one group per distinct key)."""
        cluster = ClusterConfig(num_nodes=2)
        profiles = multi_tenant_suite(4, seed=41)
        tenants = staggered_tenants(profiles, cluster, dyskew_strategy,
                                    seed=0)
        tenants[1].strategy = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.LATE,
                                skew_model=SkewModelKind.ROW_PERCENTAGE),
            tick_interval=25e-3,
        )
        results = MultiQuerySimulator(cluster, batch_ticks=True).run(tenants)
        for t, r in zip(tenants, results):
            total = sum(b.costs.sum() for s in t.streams for b in s)
            np.testing.assert_allclose(r.per_worker_busy.sum(), total,
                                       rtol=1e-9)


class TestAutoEnvelope:
    """The widened batched-tick auto envelope: a multi-link (config,
    cadence) group batches by default when every member arrival lies
    exactly on the group's chained tick grid — identical arrivals being
    the trivial case — and falls back to per-tenant links otherwise."""

    def test_on_grid_detection(self):
        assert _arrivals_on_grid([0.3, 0.3, 0.3], 0.05)  # identical
        # Chained grid values (the engine walks t += I, so must we).
        t, grid = 0.1, [0.1]
        for _ in range(5):
            t += 0.008
            grid.append(t)
        assert _arrivals_on_grid([grid[0], grid[2], grid[5]], 0.008)
        assert not _arrivals_on_grid([0.1, 0.1037], 0.008)  # off grid
        # Exactness matters: k*I need not equal the chained sum.
        assert not _arrivals_on_grid([0.0, 0.1 + 3e-18], 0.008)

    def _identical_arrival_tenants(self, cluster, arrival=0.02):
        profiles = multi_tenant_suite(4, seed=51)
        tenants = staggered_tenants(profiles, cluster, dyskew_strategy,
                                    seed=2)
        for t in tenants:
            t.arrival = arrival
        return tenants

    def test_identical_arrival_group_auto_batches_bit_exact(self):
        """Identical-arrival multi-link tenants: the auto default must
        take the batched path AND reproduce the per-tenant trajectory
        bit-for-bit (ticks, latencies, busy vectors)."""
        cluster = ClusterConfig(num_nodes=2)
        tenants = self._identical_arrival_tenants(cluster)
        assert sum(t.strategy.kind == "dyskew" for t in tenants) > 1
        auto = MultiQuerySimulator(cluster).run(tenants)
        per = MultiQuerySimulator(cluster, batch_ticks=False).run(
            self._identical_arrival_tenants(cluster)
        )
        forced = MultiQuerySimulator(cluster, batch_ticks=True).run(
            self._identical_arrival_tenants(cluster)
        )
        for a, p, f in zip(auto, per, forced):
            assert a.latency == p.latency == f.latency
            assert a.num_ticks == p.num_ticks == f.num_ticks
            np.testing.assert_array_equal(a.per_worker_busy,
                                          p.per_worker_busy)
            np.testing.assert_array_equal(a.per_worker_busy,
                                          f.per_worker_busy)

    def test_off_grid_group_falls_back_per_tenant(self):
        """Scattered arrivals: auto must keep the per-tenant cadence
        (the shared grid would quantize tick times)."""
        cluster = ClusterConfig(num_nodes=2)
        profiles = multi_tenant_suite(4, seed=51)
        tenants = staggered_tenants(profiles, cluster, dyskew_strategy,
                                    seed=2)
        arrivals = [t.arrival for t in tenants if
                    t.strategy.kind == "dyskew"]
        interval = tenants[0].strategy.tick_interval
        assert not _arrivals_on_grid(arrivals, interval)
        auto = MultiQuerySimulator(cluster).run(tenants)
        per = MultiQuerySimulator(cluster, batch_ticks=False).run(
            staggered_tenants(profiles, cluster, dyskew_strategy, seed=2)
        )
        for a, p in zip(auto, per):
            assert a.latency == p.latency
            assert a.num_ticks == p.num_ticks

    def test_grid_aligned_open_loop_batches_by_default(self):
        """`open_loop_tenants(grid_align=I)` snaps a whole open-loop
        fleet onto the tick grid, so `many_tenants_suite`-style traffic
        rides the batched path under the auto default, bit-identically."""
        from repro.sim.replay import open_loop_tenants
        from repro.sim.workload import ArrivalProcess, many_tenants_suite

        cluster = ClusterConfig(num_nodes=1)
        specs = many_tenants_suite(16, seed=71)
        st = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.LATE,
                                skew_model=SkewModelKind.IDLE_TIME),
            tick_interval=8e-3,
        )
        proc = ArrivalProcess(kind="poisson", rate=30.0)
        tenants = open_loop_tenants(
            specs, cluster, lambda prof: st, proc, 16, seed=3,
            grid_align=st.tick_interval,
        )
        assert _arrivals_on_grid([t.arrival for t in tenants],
                                 st.tick_interval)
        auto = MultiQuerySimulator(cluster).run(tenants)
        per = MultiQuerySimulator(cluster, batch_ticks=False).run(tenants)
        for a, p in zip(auto, per):
            assert a.latency == p.latency
            assert a.num_ticks == p.num_ticks
            np.testing.assert_array_equal(a.per_worker_busy,
                                          p.per_worker_busy)

    def test_batched_waterfill_engages_on_same_time_arrivals(self):
        """An eager tenant's producers all arrive at one instant — the
        coalesced run must route them through `waterfill_counts_many`."""
        cluster = ClusterConfig(num_nodes=2)
        prof = QueryProfile(
            name="wf", n_rows=1200, mean_row_cost=1e-3, cost_sigma=1.0,
            partition_alpha=0.6, hot_fraction=0.2,
        )
        batches = generate_query(prof, cluster.num_workers, seed=13)
        st = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(policy=Policy.EAGER_SNOWPARK),
        )
        sim = MultiQuerySimulator(cluster)
        sim.run([TenantQuery("wf", batches, st, 0.0,
                             scan_arrival_gap(prof, cluster))])
        counts = sim.last_event_counts
        assert counts["arrival_runs_coalesced"] >= 1
        assert counts["waterfill_batched_rows"] >= 1


class TestClosedFormNone:
    def _single_batch_tenant(self, cluster, seed=3, arrival=0.0):
        prof = QueryProfile(name="cf", n_rows=400, mean_row_cost=1e-3,
                            cost_sigma=0.9, batch_rows=10_000)
        batches = generate_query(prof, cluster.num_workers, seed=seed)
        assert all(len(s) <= 1 for s in batches)
        return TenantQuery("cf", batches, StrategyConfig(kind="none"),
                           arrival, 1e-4)

    def test_exact_vs_event_loop_single_batch(self):
        cluster = ClusterConfig(num_nodes=2)
        t = self._single_batch_tenant(cluster)
        loop = MultiQuerySimulator(cluster, none_closed_form=False).run([t])[0]
        cf = closed_form_none_result(t, cluster)
        assert cf.latency == loop.latency
        assert cf.utilization == loop.utilization
        np.testing.assert_array_equal(cf.per_worker_busy,
                                      loop.per_worker_busy)
        assert cf.num_ticks == 0 and cf.rows_redistributed == 0

    def test_auto_takes_closed_form_only_when_proven(self):
        cluster = ClusterConfig(num_nodes=2)
        t = self._single_batch_tenant(cluster)
        auto = MultiQuerySimulator(cluster).run([t])[0]
        cf = closed_form_none_result(t, cluster)
        assert auto.latency == cf.latency
        # Multi-batch streams: auto must stay on the event loop.
        prof = QueryProfile(name="mb", n_rows=2000, mean_row_cost=1e-3,
                            cost_sigma=0.9)
        batches = generate_query(prof, cluster.num_workers, seed=3)
        assert any(len(s) > 1 for s in batches)
        tm = TenantQuery("mb", batches, StrategyConfig(kind="none"),
                         0.0, scan_arrival_gap(prof, cluster))
        auto_m = MultiQuerySimulator(cluster).run([tm])[0]
        loop_m = MultiQuerySimulator(
            cluster, none_closed_form=False).run([tm])[0]
        assert auto_m.latency == loop_m.latency

    def test_overlapping_producers_ineligible(self):
        """Two 'none' tenants on the SAME producers share worker FIFOs —
        the closed form must refuse even when forced."""
        cluster = ClusterConfig(num_nodes=2)
        a = self._single_batch_tenant(cluster, seed=3)
        b = self._single_batch_tenant(cluster, seed=4)
        sim = MultiQuerySimulator(cluster, none_closed_form=True)
        assert not sim._none_fast_path_ok([a, b])
        loop = MultiQuerySimulator(cluster, none_closed_form=False)
        res_forced = sim.run([a, b])
        res_loop = loop.run([a, b])
        for x, y in zip(res_forced, res_loop):
            assert x.latency == y.latency

    def test_disjoint_tenants_exact(self):
        cluster = ClusterConfig(num_nodes=2)
        n = cluster.num_workers
        prof = QueryProfile(name="dj", n_rows=600, mean_row_cost=1e-3,
                            cost_sigma=0.8, batch_rows=10_000)
        full = generate_query(prof, n, seed=9)
        st = StrategyConfig(kind="none")
        half = n // 2
        ta = TenantQuery("a", [s if p < half else [] for p, s in
                               enumerate(full)], st, 0.0, 1e-4)
        tb = TenantQuery("b", [s if p >= half else [] for p, s in
                               enumerate(full)], st, 0.05, 1e-4)
        fast = MultiQuerySimulator(cluster).run([ta, tb])
        loop = MultiQuerySimulator(cluster, none_closed_form=False).run(
            [ta, tb]
        )
        for x, y in zip(fast, loop):
            assert x.latency == y.latency
            np.testing.assert_array_equal(x.per_worker_busy,
                                          y.per_worker_busy)


# ------------------------------------------------------------------ #
# replay.py pool regressions
# ------------------------------------------------------------------ #


class _FailingPool:
    """Executor stub whose map always raises (a poisoned pool)."""

    def __init__(self):
        self.shutdowns = []

    def map(self, *a, **kw):
        raise RuntimeError("worker died")

    def shutdown(self, wait=True, cancel_futures=False):
        self.shutdowns.append((wait, cancel_futures))


class _InProcessPool:
    """Executor stub that runs map in-process (a healthy pool)."""

    def map(self, fn, tasks, chunksize=1):
        return [fn(t) for t in tasks]

    def shutdown(self, wait=True, cancel_futures=False):
        pass


def _tiny_tasks(k=2):
    cluster = ClusterConfig(num_nodes=1, interpreters_per_node=4)
    prof = QueryProfile(name="tiny", n_rows=64, mean_row_cost=1e-4,
                        cost_sigma=0.3)
    st = StrategyConfig(kind="none")
    return [(prof, cluster, st, i, i, 1e-4) for i in range(k)]


class TestPoolRecovery:
    def setup_method(self):
        self._saved = (replay._POOL, replay._POOL_WORKERS)
        replay._POOL, replay._POOL_WORKERS = None, 0

    def teardown_method(self):
        replay._POOL, replay._POOL_WORKERS = self._saved

    def test_poisoned_pool_recovers_on_next_call(self, monkeypatch):
        """Regression: one pool failure used to permanently degrade
        _map_queries to serial (the broken executor stayed cached)."""
        bad = _FailingPool()
        replay._POOL, replay._POOL_WORKERS = bad, 8
        tasks = _tiny_tasks()
        with pytest.warns(RuntimeWarning, match="pool failed"):
            results = replay._map_queries(tasks, workers=8)
        assert len(results) == len(tasks)  # serial fallback still ran
        # The broken pool was shut down and discarded...
        assert replay._POOL is None and replay._POOL_WORKERS == 0
        assert bad.shutdowns
        # ...so the next call builds a fresh pool and uses it.
        good = _InProcessPool()
        monkeypatch.setattr(replay, "_get_pool", lambda workers: good)
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            results2 = replay._map_queries(tasks, workers=8)
        assert len(results2) == len(tasks)
        for a, b in zip(results, results2):
            assert a.latency == b.latency

    def test_grow_shuts_replaced_pool_down_waiting(self, monkeypatch):
        """Growing the pool must reap the replaced pool's processes
        (shutdown wait=True), not leak them."""
        small = _FailingPool()
        replay._POOL, replay._POOL_WORKERS = small, 2

        created = []

        class _FakeExecutor(_InProcessPool):
            def __init__(self, max_workers=None, mp_context=None):
                created.append(max_workers)

        monkeypatch.setattr(replay, "ProcessPoolExecutor", _FakeExecutor)
        pool = replay._get_pool(4)
        assert isinstance(pool, _FakeExecutor) and created == [4]
        assert small.shutdowns == [(True, False)]

    def test_warm_pool_surfaces_worker_crash(self, monkeypatch):
        """Regression: warm_pool discarded its futures, so a worker that
        crashed during the jax warm-import was silently ignored."""

        class _CrashingSubmitPool:
            def submit(self, fn):
                f = Future()
                f.set_exception(RuntimeError("import jax segfaulted"))
                return f

        monkeypatch.setattr(
            replay, "_get_pool", lambda workers: _CrashingSubmitPool()
        )
        with pytest.warns(RuntimeWarning, match="warm-up worker failed"):
            futures = replay.warm_pool(workers=3)
        assert len(futures) == 3
        assert all(f.exception() is not None for f in futures)

    def test_warm_pool_quiet_on_success(self, monkeypatch):
        class _OkPool:
            def submit(self, fn):
                f = Future()
                f.set_result(True)
                return f

        monkeypatch.setattr(replay, "_get_pool", lambda workers: _OkPool())
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            futures = replay.warm_pool(workers=2)
        assert [f.result() for f in futures] == [True, True]
