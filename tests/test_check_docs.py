"""Tests for tools/check_docs.py, the doc-link and snippet checker.

The checker resolves everything against its module-level ``ROOT``;
these tests monkeypatch ROOT to a synthetic tree under tmp_path so each
judgement — reference hit, reference miss, fenced-shell parsing, make
target resolution — is pinned without depending on the real docs
(which ``make check-docs`` keeps green separately).
"""

import os
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)

from tools import check_docs  # noqa: E402


@pytest.fixture
def tree(tmp_path, monkeypatch):
    """A minimal repo skeleton the checker can resolve against."""
    (tmp_path / "src" / "repro" / "sim").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "__init__.py").write_text("")
    (tmp_path / "src" / "repro" / "sim" / "__init__.py").write_text("")
    (tmp_path / "src" / "repro" / "sim" / "engine.py").write_text("")
    (tmp_path / "tools").mkdir()
    (tmp_path / "tools" / "runner.py").write_text("")
    (tmp_path / "Makefile").write_text(
        ".PHONY: test lint\n"
        "test:\n\tpytest\n"
        "lint:\n\techo lint\n"
        "VAR := 1\n"
    )
    monkeypatch.setattr(check_docs, "ROOT", str(tmp_path))
    return tmp_path


def _doc(tree, text):
    (tree / "README.md").write_text(textwrap.dedent(text))
    return ["README.md"]


class TestReferenceCheck:
    def test_existing_path_and_module_resolve(self, tree):
        docs = _doc(tree, """\
            See `src/repro/sim/engine.py` and the `repro.sim` package,
            or run `python tools/runner.py`.
        """)
        checked, failures = check_docs.check(docs)
        assert failures == []
        assert checked == 3

    def test_missing_path_and_module_are_reported_with_line(self, tree):
        docs = _doc(tree, """\
            intro line
            Broken: `src/repro/gone.py` and `repro.gone.module`.
        """)
        _, failures = check_docs.check(docs)
        assert len(failures) == 2
        assert all("README.md:2" in f for f in failures)
        assert any("`src/repro/gone.py`" in f for f in failures)
        assert any("`repro.gone.module`" in f for f in failures)

    def test_non_pathish_tokens_are_ignored(self, tree):
        docs = _doc(tree, """\
            Flags like `--quick`, versions like `1.2.3`, and code like
            `foo(bar)` or `make` are not checkable references.
        """)
        checked, failures = check_docs.check(docs)
        assert (checked, failures) == (0, [])

    def test_missing_document_is_a_failure(self, tree):
        _, failures = check_docs.check(["NOPE.md"])
        assert failures == ["NOPE.md: document missing"]


class TestSnippetCheck:
    def test_good_shell_block_passes(self, tree):
        docs = _doc(tree, """\
            ```bash
            PYTHONPATH=src python tools/runner.py --quick
            make test lint
            ```
        """)
        checked, failures = check_docs.check_snippets(docs)
        assert failures == []
        assert checked == 2

    def test_unknown_make_target_is_reported(self, tree):
        docs = _doc(tree, """\
            ```sh
            make bogus
            ```
        """)
        _, failures = check_docs.check_snippets(docs)
        assert len(failures) == 1
        assert "make target `bogus`" in failures[0]

    def test_missing_script_is_reported(self, tree):
        docs = _doc(tree, """\
            ```bash
            python tools/gone.py
            ```
        """)
        _, failures = check_docs.check_snippets(docs)
        assert len(failures) == 1
        assert "`tools/gone.py` does not exist" in failures[0]

    def test_unparseable_line_is_reported(self, tree):
        docs = _doc(tree, """\
            ```bash
            echo "unterminated
            ```
        """)
        _, failures = check_docs.check_snippets(docs)
        assert len(failures) == 1
        assert "does not parse" in failures[0]

    def test_non_shell_fences_are_skipped(self, tree):
        docs = _doc(tree, """\
            ```python
            make bogus  # not a shell block
            ```
            ```
            make bogus
            ```
        """)
        checked, failures = check_docs.check_snippets(docs)
        assert (checked, failures) == (0, [])

    def test_console_output_lines_are_not_commands(self, tree):
        docs = _doc(tree, """\
            ```console
            $ make test
            ...ran 409 tests...
            ```
        """)
        checked, failures = check_docs.check_snippets(docs)
        assert failures == []
        assert checked == 1

    def test_backslash_continuation_joins_lines(self, tree):
        docs = _doc(tree, """\
            ```bash
            python tools/runner.py \\
                --quick --only sim
            ```
        """)
        checked, failures = check_docs.check_snippets(docs)
        assert failures == []
        assert checked == 1

    def test_compound_command_segments_all_checked(self, tree):
        docs = _doc(tree, """\
            ```bash
            make test && make bogus
            ```
        """)
        _, failures = check_docs.check_snippets(docs)
        assert len(failures) == 1
        assert "make target `bogus`" in failures[0]


class TestMakefileTargets:
    def test_targets_parsed_variables_and_phony_excluded(self, tree):
        targets = check_docs._makefile_targets()
        assert targets == {"test", "lint"}

    def test_missing_makefile_yields_empty_set(self, tree):
        (tree / "Makefile").unlink()
        assert check_docs._makefile_targets() == set()


def test_real_docs_pass():
    """The repo's actual docs must satisfy their own checker."""
    checked, failures = check_docs.check()
    snip_checked, snip_failures = check_docs.check_snippets()
    assert failures + snip_failures == []
    assert checked > 0 and snip_checked > 0
