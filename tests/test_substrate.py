"""Substrate tests: optimizers, grad compression, checkpointing, data
pipeline, fault-tolerance runtime, serving scheduler."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataPipeline, pack_documents, SyntheticDocs
from repro.optim.grad_compress import (
    allreduce_compressed,
    compress_with_feedback,
    dequantize_int8,
    quantize_int8,
    residual_init,
)
from repro.optim.optimizers import (
    OptimizerConfig,
    global_norm,
    lr_schedule,
    opt_init,
    opt_update,
)
from repro.optim.specs import opt_state_specs
from repro.runtime.fault_tolerance import FaultConfig, FaultTolerantRuntime
from repro.serving.engine import Request, ServeConfig, ServingEngine


class TestOptimizers:
    def _quad_params(self):
        return {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.zeros((3, 200))}

    @pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
    def test_decreases_quadratic_loss(self, name):
        cfg = OptimizerConfig(name=name, lr=0.05, warmup_steps=0,
                              weight_decay=0.0)
        params = self._quad_params()
        state = opt_init(cfg, params)

        def loss(p):
            return sum(jnp.sum(x**2) for x in jax.tree.leaves(p))

        l0 = float(loss(params))
        for step in range(20):
            grads = jax.grad(loss)(params)
            params, state, stats = opt_update(
                cfg, grads, state, params, jnp.asarray(step)
            )
        factor = 0.8 if name == 'sgd' else 0.5  # sgd is clipped
        assert float(loss(params)) < factor * l0, name

    def test_adafactor_factored_state_is_small(self):
        cfg = OptimizerConfig(name="adafactor", factored_dim_threshold=128)
        params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((4, 4))}
        state = opt_init(cfg, params)
        assert state["v"]["big"]["vr"].shape == (512,)
        assert state["v"]["big"]["vc"].shape == (256,)
        assert state["v"]["small"]["v"].shape == (4, 4)

    def test_opt_state_specs_match_init(self):
        from repro.models.param import spec, tree_abstract, tree_materialize

        pspecs = {"w": spec((256, 256), ("embed", "mlp")),
                  "b": spec((8,), (None,))}
        params = tree_materialize(pspecs, jax.random.PRNGKey(0))
        for name in ("adamw", "adafactor"):
            cfg = OptimizerConfig(name=name)
            live = opt_init(cfg, params)
            ab = tree_abstract(opt_state_specs(cfg, pspecs))
            live_shapes = jax.tree.map(lambda x: x.shape, live)
            ab_shapes = jax.tree.map(lambda x: x.shape, ab)
            assert live_shapes == ab_shapes, name

    def test_lr_schedule_warmup_and_decay(self):
        cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        assert float(lr_schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-3)
        assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(
            1e-4, rel=0.01
        )

    def test_grad_clip(self):
        cfg = OptimizerConfig(name="sgd", grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        grads = {"w": jnp.full(4, 100.0)}
        _, _, stats = opt_update(cfg, grads, {}, params, jnp.asarray(0))
        assert float(stats["grad_norm"]) == pytest.approx(200.0)


class TestGradCompression:
    def test_quantize_roundtrip_error_bounded(self):
        g = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = quantize_int8(g)
        err = jnp.abs(dequantize_int8(q, s) - g)
        assert float(err.max()) <= float(s) * 0.51

    def test_error_feedback_accumulates_residual(self):
        grads = {"w": jnp.full((64,), 0.001)}
        residual = residual_init(grads)
        qs, ss, rs = compress_with_feedback(grads, residual)
        # quantization loses info; the loss must live in the residual
        recon = dequantize_int8(qs["w"], ss["w"]) + rs["w"]
        np.testing.assert_allclose(np.asarray(recon), 0.001, rtol=1e-5)

    def test_allreduce_compressed_matches_mean(self):
        # shard_map over 1 device: psum degenerates but path exercises.
        try:  # jax >= 0.6 exports shard_map at the top level
            from jax import shard_map as _sm
        except ImportError:
            from jax.experimental.shard_map import shard_map as _sm
        from jax.sharding import Mesh, PartitionSpec as P

        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (128,))}
        residual = residual_init(grads)

        def f(g, r):
            return allreduce_compressed(g, r, "pod")

        out, new_r = jax.jit(
            _sm(f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
        )(grads, residual)
        # max quant error = scale/2 ≈ amax/254 ≈ 0.013 for N(0,1)
        np.testing.assert_allclose(
            np.asarray(out["w"]), np.asarray(grads["w"]), atol=2e-2
        )
        # error feedback: residual + dequant == original
        np.testing.assert_allclose(
            np.asarray(out["w"] + new_r["w"]), np.asarray(grads["w"]),
            atol=1e-6,
        )


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {
            "params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7),
        }
        mgr.save(7, state, blocking=True)
        restored = mgr.restore(state)
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]),
            np.asarray(state["params"]["w"]),
        )
        assert int(restored["step"]) == 7

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        state = {"x": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, state, blocking=True)
        assert mgr.all_steps() == [3, 4]

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"x": jnp.zeros(2)}, blocking=True)
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_elastic_restore_resharding(self, tmp_path):
        """Checkpoint on one 'mesh', restore with different shardings."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        mgr = CheckpointManager(str(tmp_path))
        state = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(1, state, blocking=True)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("d",))
        sh = {"w": NamedSharding(mesh, P(None, None))}
        restored = mgr.restore(state, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))


class TestDataPipeline:
    def test_packing_fills_sequences(self):
        cfg = DataConfig(vocab_size=100, seq_len=256, global_batch=8)
        docs = iter(SyntheticDocs(cfg))
        seqs = pack_documents(docs, 256, 8)
        fills = [(s != 0).sum() for s in seqs]
        assert all(f > 0 for f in fills)
        assert all(len(s) == 256 for s in seqs)

    def test_pipeline_batches_and_targets(self):
        cfg = DataConfig(vocab_size=100, seq_len=128, global_batch=4,
                         num_shards=2)
        pipe = DataPipeline(cfg)
        batch = next(pipe)
        assert batch["tokens"].shape == (4, 128)
        assert batch["targets"].shape == (4, 128)
        # targets shifted: where tokens[t+1] nonzero, targets[t]==tokens[t+1]
        nz = batch["tokens"][:, 1:] != 0
        np.testing.assert_array_equal(
            batch["targets"][:, :-1][nz], batch["tokens"][:, 1:][nz]
        )

    def test_prefetch_thread(self):
        cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=2)
        pipe = DataPipeline(cfg).start()
        b1, b2 = next(pipe), next(pipe)
        pipe.stop()
        assert b1["tokens"].shape == b2["tokens"].shape


class TestFaultTolerance:
    def test_dead_host_detected_via_idle_model(self):
        rt = FaultTolerantRuntime(4, FaultConfig(missed_beats_dead=2))
        t = 0.0
        for tick in range(5):
            t += 10.0
            for h in range(4):
                if h != 2 or tick < 1:   # host 2 dies after first beat
                    rt.heartbeat(h, t, step_time=1.0)
            res = rt.tick(t)
        assert 2 in res["failed"]
        survivors = rt.exclude(res["failed"])
        assert survivors == [0, 1, 3]

    def test_straggler_detected_via_slope_model(self):
        rt = FaultTolerantRuntime(4, FaultConfig(n_strikes=2))
        t = 0.0
        detected = []
        for tick in range(10):
            t += 10.0
            for h in range(4):
                # host 1's step times grow 10x faster
                rt.heartbeat(h, t, step_time=10.0 if h == 1 else 1.0)
            detected.append(rt.tick(t)["stragglers"])
        assert any(1 in d for d in detected)
        assert not any(0 in d or 2 in d or 3 in d for d in detected)

    def test_min_hosts_respected(self):
        rt = FaultTolerantRuntime(3, FaultConfig(min_hosts=2))
        rt.exclude([0])
        survivors = rt.exclude([1])  # would drop below min → refused
        assert len(survivors) >= 2

    def test_rejoin(self):
        rt = FaultTolerantRuntime(3)
        rt.exclude([1])
        rt.rejoin(1, now=100.0)
        assert rt.survivors() == [0, 1, 2]

    def test_elastic_mesh_shape(self):
        from repro.runtime.fault_tolerance import elastic_mesh_shape

        assert elastic_mesh_shape(64, 4) == (16, 16)
        assert elastic_mesh_shape(63, 4) == (15, 16)
        assert elastic_mesh_shape(2, 4) == (1, 8)


class TestServing:
    def _requests(self, n=64, skew=False, seed=0):
        rng = np.random.default_rng(seed)
        out = []
        for i in range(n):
            new = int(rng.integers(300, 400)) if (skew and i % 7 == 0) \
                else int(rng.integers(20, 60))
            out.append(Request(
                rid=i, prompt_len=int(rng.integers(64, 512)),
                max_new_tokens=new, arrival=float(i) * 0.02,
            ))
        return out

    def test_completes_all_requests(self):
        cfg = ServeConfig(num_replicas=4, scheduler="dyskew")
        res = ServingEngine(cfg).run(self._requests())
        assert res["completed"] == 64

    def test_dyskew_beats_round_robin_on_skew(self):
        reqs = lambda: self._requests(skew=True, seed=3)
        rr = ServingEngine(ServeConfig(scheduler="round_robin")).run(reqs())
        dk = ServingEngine(ServeConfig(scheduler="dyskew")).run(reqs())
        assert dk["p99_latency"] <= rr["p99_latency"] * 1.05
        assert dk["mean_latency"] <= rr["mean_latency"]

    def test_heavy_kv_requests_not_thrashed(self):
        """Requests with huge KV should rarely migrate (Row Size Model)."""
        cfg = ServeConfig(num_replicas=4, scheduler="dyskew",
                          kv_bytes_per_token=4e6)  # enormous KV per token
        res = ServingEngine(cfg).run(self._requests(skew=True))
        assert res["migrations"] <= 4

    def test_forward_migration_terminates(self):
        """A rebalance move to a HIGHER replica must not re-visit the moved
        request while applying moves (seed bug: appending to a queue that
        the apply loop iterates later looped forever)."""
        cfg = ServeConfig(num_replicas=4, scheduler="dyskew")
        eng = ServingEngine(cfg)
        orig = eng.sched.rebalance
        forced = []

        def force_one(queued, load_tokens):
            if queued and not forced:
                forced.append(True)
                r = queued[0]
                return {r.rid: (r.replica + 2) % cfg.num_replicas}
            return orig(queued, load_tokens)

        eng.sched.rebalance = force_one
        res = eng.run(self._requests(n=16))
        assert res["completed"] == 16
        assert res["migrations"] == 1
