"""The pluggable redistribution-policy seam (`repro.core.policy`).

Covers the registry contract end to end:

  * unknown `StrategyConfig.kind` raises ValueError at CONSTRUCTION time
    (regression — it used to fall through to no redistribution silently);
  * every registered policy's `propose` conserves rows: counts sum to
    the batch size, nothing goes negative, and +inf-masked (self-skip /
    decommissioned) destinations receive zero;
  * engine-level conservation — each policy run end to end on a skewed
    workload accounts for every row of work exactly once;
  * the three ported built-ins reproduce the pre-refactor engine
    bit-for-bit (pinned digests of a fixed trace);
  * stochastic policies replay bit-identically under the same injected
    seed and diverge across seeds;
  * the serving scheduler and data pipeline resolve placement through
    the same registry (aliases work, unknown names raise).
"""

import hashlib

import numpy as np
import pytest

from repro.core.policy import (
    PolicyContext,
    RedistributionPolicy,
    StrategyConfig,
    available_policies,
    register_policy,
    resolve_policy,
    waterfill_counts,
)
from repro.sim.engine import ClusterConfig, MultiQuerySimulator, TenantQuery
from repro.sim.workload import QueryProfile, generate_query

BUILTINS = ("none", "static_rr", "dyskew")
NEW_POLICIES = ("p2c", "key_affinity", "hillclimb")


def _ctx(n=8, seed=0):
    return PolicyContext(num_workers=n, rng=np.random.default_rng(seed))


def _skewed_tenant(kind, seed=3, alpha=1.4):
    prof = QueryProfile(
        name=f"t_{kind}", n_rows=4096, partition_alpha=alpha,
        hot_fraction=0.3, cost_sigma=0.8,
    )
    streams = generate_query(prof, n_producers=8, seed=seed)
    return TenantQuery(
        name=prof.name, streams=streams,
        strategy=StrategyConfig(kind=kind), arrival=0.0,
    )


class TestRegistry:
    def test_unknown_kind_raises_at_construction(self):
        # Regression: unknown kinds used to silently behave like 'none'.
        with pytest.raises(ValueError, match="bogus"):
            StrategyConfig(kind="bogus")

    def test_unknown_kind_lists_registered_names(self):
        with pytest.raises(ValueError, match="static_rr"):
            resolve_policy("nope")

    def test_builtins_and_new_policies_registered(self):
        names = available_policies()
        for k in BUILTINS + NEW_POLICIES:
            assert k in names

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="dyskew"):
            @register_policy
            class Dup(RedistributionPolicy):  # noqa: F811
                name = "dyskew"

    def test_registry_returns_classes_with_flags(self):
        assert resolve_policy("none").never_redistributes
        assert resolve_policy("dyskew").uses_link
        assert resolve_policy("p2c").stochastic
        assert not resolve_policy("static_rr").pays_decision_overhead


class TestConservation:
    """propose() must neither lose nor duplicate rows — including when
    self-skip or decommission masks destinations to +inf."""

    @pytest.mark.parametrize("kind", sorted(available_policies()))
    @pytest.mark.parametrize("mask_mode", ["none", "self", "decom"])
    def test_propose_conserves_rows(self, kind, mask_mode):
        rng = np.random.default_rng(17)
        pol = StrategyConfig(kind=kind).make_policy(_ctx(n=8, seed=5))
        for trial in range(20):
            n = 8
            backlog = rng.exponential(2.0, size=n)
            producer = int(rng.integers(n))
            if mask_mode == "self":
                backlog[producer] = np.inf
            elif mask_mode == "decom":
                backlog[rng.integers(n, size=2)] = np.inf
            k = int(rng.integers(1, 600))
            counts = pol.propose(producer, k, backlog.copy(), unit=1e-5)
            if counts is None:  # 'none' never proposes a spread
                assert kind == "none"
                continue
            counts = np.asarray(counts)
            assert counts.shape == (n,)
            assert int(counts.sum()) == k, (kind, mask_mode, trial)
            assert (counts >= 0).all()
            assert (counts[~np.isfinite(backlog)] == 0).all(), (
                kind, mask_mode)

    @pytest.mark.parametrize("kind", sorted(available_policies()))
    def test_engine_level_conservation(self, kind):
        """End to end: total busy-time across workers equals the total
        cost of every row generated — no row lost, none run twice."""
        t = _skewed_tenant(kind)
        total_cost = sum(
            float(b.costs.sum()) for stream in t.streams for b in stream
        )
        sim = MultiQuerySimulator(ClusterConfig(num_nodes=2, interpreters_per_node=4), seed=0)
        res = sim.run([t])[0]
        busy = float(np.asarray(res.per_worker_busy).sum())
        assert busy == pytest.approx(total_cost, rel=1e-9), kind

    def test_waterfill_counts_exact_sum(self):
        backlog = np.array([0.0, 5.0, np.inf, 1.0])
        counts = waterfill_counts(backlog, 1000, unit=0.01)
        assert counts.sum() == 1000 and counts[2] == 0


class TestBuiltinsBitIdentity:
    """Pinned digests of a fixed skewed trace: the registry-resolved
    built-ins must keep producing the exact same schedules the string-
    dispatch engine produced before the refactor (the rtol-1e-9 legacy
    equivalence suite pins dyskew separately; these pin all three)."""

    PINS = {
        # Digest over (latency, per_worker_busy, rows_redistributed)
        # for the fixed trace below, generated by the PRE-refactor
        # string-dispatch engine (verified identical at the refactor
        # commit).  Regenerate ONLY for an intentional engine-semantics
        # change, never for a policy-port change.
        "none": "5fa8fac3ab82d020",
        "static_rr": "6dcc87585b324bb5",
        "dyskew": "cbe950b4b8c3feff",
    }

    @staticmethod
    def _digest(kind):
        t = _skewed_tenant(kind, seed=11, alpha=1.8)
        res = MultiQuerySimulator(
            ClusterConfig(num_nodes=2, interpreters_per_node=4), seed=0
        ).run([t])[0]
        h = hashlib.sha256()
        h.update(np.float64(res.latency).tobytes())
        h.update(np.asarray(res.per_worker_busy, np.float64).tobytes())
        h.update(np.int64(res.rows_redistributed).tobytes())
        return h.hexdigest()[:16]

    @pytest.mark.parametrize("kind", BUILTINS)
    def test_builtin_matches_pin(self, kind):
        assert self._digest(kind) == self.PINS[kind], kind


class TestStochasticDeterminism:
    """Injected-RNG contract: same seed => bit-identical replay; the
    built-ins never touch the stream at all."""

    @staticmethod
    def _run(kind, seed):
        t = _skewed_tenant(kind)
        res = MultiQuerySimulator(
            ClusterConfig(num_nodes=2, interpreters_per_node=4), seed=seed
        ).run([t])[0]
        return res.latency, np.asarray(res.per_worker_busy)

    @pytest.mark.parametrize("kind", sorted(available_policies()))
    def test_same_seed_bit_identical(self, kind):
        l1, b1 = self._run(kind, 7)
        l2, b2 = self._run(kind, 7)
        assert l1 == l2 and np.array_equal(b1, b2)

    def test_p2c_diverges_across_seeds(self):
        l1, _ = self._run("p2c", 7)
        l2, _ = self._run("p2c", 8)
        assert l1 != l2

    @pytest.mark.parametrize("kind", BUILTINS)
    def test_builtins_seed_invariant(self, kind):
        # Deterministic built-ins must IGNORE the injected stream: the
        # legacy equivalence pin depends on it.
        l1, b1 = self._run(kind, 7)
        l2, b2 = self._run(kind, 1234)
        assert l1 == l2 and np.array_equal(b1, b2)


class TestServingAndDataResolution:
    def test_serving_aliases_resolve(self):
        from repro.serving.engine import ServeConfig, ServingScheduler

        for sched, kind in (("round_robin", "static_rr"),
                            ("least_loaded", "none"),
                            ("p2c", "p2c")):
            s = ServingScheduler(ServeConfig(num_replicas=4,
                                             scheduler=sched))
            assert s.policy.name == kind

    def test_serving_unknown_scheduler_raises(self):
        from repro.serving.engine import ServeConfig, ServingScheduler

        with pytest.raises(ValueError, match="bogus"):
            ServingScheduler(ServeConfig(num_replicas=4,
                                         scheduler="bogus"))

    def test_serving_p2c_places_on_live_replicas(self):
        from repro.serving.engine import ServeConfig, ServingScheduler

        s = ServingScheduler(ServeConfig(num_replicas=4, scheduler="p2c"))
        load = np.array([5.0, 0.0, 3.0, 1.0])
        for _ in range(16):
            assert 0 <= s.place(None, load) < 4

    def test_data_pipeline_registry_placement(self):
        from repro.data.pipeline import DataConfig, DataPipeline

        cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=8,
                         num_shards=4, placement="static_rr", seed=3)
        batch = next(DataPipeline(cfg))
        assert batch["tokens"].shape == (8, 128)

    def test_data_pipeline_unknown_placement_raises(self):
        from repro.data.pipeline import DataConfig, DataPipeline

        cfg = DataConfig(vocab_size=64, seq_len=128, global_batch=8,
                         num_shards=4, placement="bogus")
        with pytest.raises(ValueError, match="bogus"):
            DataPipeline(cfg)


class TestManyRegimeBatchedDeterminism:
    """The ``--many`` regime pin (PR 6 follow-on): 128 open-loop tenants
    on one warehouse with arrivals snapped onto the shared 8 ms tick
    grid.  The balanced seven-of-eight majority runs the production
    dyskew link strategy, whose homogeneous grid-aligned group rides the
    batched-tick path (one coalesced BatchedLinkSim tick per cadence —
    ``gtick`` > 0 proves it engaged); the skewed noisy neighbours run a
    registry policy under test, so its routing decisions interleave with
    batched group ticks on the shared cluster.  Pins, at this scale:

      * same ``sim_seed`` replays the full 128-query latency trajectory
        AND the per-kind event counters bit-identically for both p2c
        (stochastic) and hillclimb (stateful feedback controller);
      * a different ``sim_seed`` perturbs the p2c trajectory — the
        injected per-tenant RNG streams flow through the mixed
        batched/per-tenant dispatch rather than being flattened away;
      * hillclimb is deterministic BY CONTRACT (``stochastic=False`` —
        its observations come from the routing trajectory, not an RNG),
        so its trajectory must be sim_seed-INVARIANT even here.
    """

    TICK = 8e-3
    N = 128

    @classmethod
    def _run(cls, kind, sim_seed):
        from repro.core.types import DySkewConfig, Policy, SkewModelKind
        from repro.sim.replay import (
            ArrivalProcess,
            open_loop_rate,
            run_open_loop,
        )
        from repro.sim.workload import many_tenants_suite

        link_strategy = StrategyConfig(
            kind="dyskew",
            dyskew=DySkewConfig(
                policy=Policy.LATE,
                skew_model=SkewModelKind.IDLE_TIME,
                n_strikes=2,
            ),
            tick_interval=cls.TICK,
        )

        def resolve(prof):
            if "skew" in prof.name:
                return StrategyConfig(kind=kind, tick_interval=cls.TICK)
            return link_strategy

        cluster = ClusterConfig(num_nodes=2)
        specs = many_tenants_suite(cls.N, seed=71)
        proc = ArrivalProcess(
            kind="poisson",
            rate=open_loop_rate([p for p, _ in specs], cluster, load=3.0),
        )
        out = run_open_loop(
            specs, cluster, proc, cls.N, seed=1, resolve=resolve,
            grid_align=cls.TICK, sim_seed=sim_seed,
        )
        lat = np.array([r.latency for r in out["results"]], np.float64)
        return lat, dict(out["event_counts"])

    @pytest.mark.parametrize("kind", NEW_POLICIES)
    def test_same_seed_bit_identical_under_batched_ticks(self, kind):
        l1, ev1 = self._run(kind, 7)
        l2, ev2 = self._run(kind, 7)
        assert np.array_equal(l1, l2), kind
        assert ev1 == ev2
        # The batched path must actually have engaged: the grid-aligned
        # homogeneous dyskew majority batches by default.
        assert ev1.get("gtick", 0) > 0

    def test_p2c_cross_seed_divergence(self):
        l1, _ = self._run("p2c", 7)
        l2, _ = self._run("p2c", 8)
        assert not np.array_equal(l1, l2), (
            "p2c produced identical 128-tenant trajectories across "
            "sim seeds"
        )

    def test_hillclimb_seed_invariant(self):
        # The flip side of the divergence pin: hillclimb advertises
        # stochastic=False, so the injected stream must not leak into
        # its decisions at any scale.
        l1, _ = self._run("hillclimb", 7)
        l2, _ = self._run("hillclimb", 8)
        assert np.array_equal(l1, l2)


class TestPolicyContextDefaults:
    """Regression for the dyslint DY102 finding: PolicyContext's rng
    default used to be an argless ``default_rng()``, so every context
    built without an explicit stream (serving placement, ad-hoc policy
    probes) drew from fresh OS entropy and was irreproducible."""

    def test_default_rng_stream_is_deterministic(self):
        a = PolicyContext(num_workers=4)
        b = PolicyContext(num_workers=4)
        assert np.array_equal(a.rng.random(16), b.rng.random(16))

    def test_default_rng_streams_are_independent_objects(self):
        # Same seed, but distinct generators: advancing one context's
        # stream must not perturb another's.
        a = PolicyContext(num_workers=4)
        b = PolicyContext(num_workers=4)
        a.rng.random(8)
        assert a.rng is not b.rng
        assert np.array_equal(
            b.rng.random(4), PolicyContext(num_workers=4).rng.random(4)
        )

    def test_explicit_stream_still_wins(self):
        rng = np.random.default_rng(123)
        want = np.random.default_rng(123).random(4)
        ctx = PolicyContext(num_workers=4, rng=rng)
        assert np.array_equal(ctx.rng.random(4), want)
