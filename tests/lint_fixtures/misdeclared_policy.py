"""Deliberately misdeclared policy — dyslint's capability pass MUST
flag this file (DY202), and ``tests/test_dyslint.py`` pins that running
the linter over it exits non-zero.

The class declares ``drain_safe=True`` (it inherits the base-class
default and even restates it) while mutating ``self`` inside
``place_one`` — an entry point the engine may call after routing is
complete, where a mutation invalidates the closed-form drain.  This is
exactly the drift the pass exists to catch, so keep this file OUT of
the default lint scope (``tests/`` is excluded by design) and never
"fix" it.
"""

import numpy as np

from repro.core.policy import RedistributionPolicy, register_policy


@register_policy
class SneakyStatefulPolicy(RedistributionPolicy):
    """Claims to be drain-safe but keeps a placement counter."""

    name = "sneaky_stateful_fixture"
    drain_safe = True

    def __init__(self):
        self._placed = 0

    def propose(self, producer, k, backlog, unit):
        counts = np.zeros(len(backlog), np.int64)
        counts[producer] = k
        return counts

    def place_one(self, backlog):
        worker = int(np.argmin(backlog))
        self._placed += 1          # <-- mutation outside route/propose
        return worker
