"""Deliberately unit-broken fixture for the dyflow DY5xx units pass.

NOT part of the linted tree (tests/ is outside DEFAULT_LINT_PATHS):
``tests/test_dyflow.py`` lints this file explicitly and asserts every
violation below is flagged — if a lattice change silently stops
catching one of these, that test fails, not the repo's own lint run.
"""


def kv_budget_bytes():
    return 4.0 * float(2 ** 30)


def deficit_rows():
    return 128.0


def bill(worker_seconds_spent):
    return worker_seconds_spent


def mixed_dimension_add(wall_s, queue_ms, moved_bytes):
    # DY501: seconds + bytes
    broken = wall_s + moved_bytes
    # DY504: seconds + milliseconds without conversion
    also_broken_s = wall_s + queue_ms
    return broken, also_broken_s


def mixed_dimension_compare(wall_s):
    # DY502: seconds vs bytes
    if wall_s > kv_budget_bytes():
        return True
    # DY502: min() arguments mix seconds and rows
    return min(wall_s, deficit_rows())


def silent_coercions(wall_s):
    # DY504: bytes value bound to a *_gb name without conversion
    cap_gb = kv_budget_bytes()
    # fine: the literal performs the conversion exactly
    ok_gb = kv_budget_bytes() / float(2 ** 30)
    # DY504: decimal/binary confusion — lands NEAR 2**30, not on it
    near_gb = kv_budget_bytes() / 1e9
    # DY503: seconds passed for a worker-seconds parameter
    cost = bill(wall_s)
    # DY503: dict value disagrees with its unit-suffixed key
    row = {"p99_s": deficit_rows()}
    return cap_gb, ok_gb, near_gb, cost, row


def suppressed_mix(wall_s, moved_bytes):
    # dyslint: disable=DY501 -- fixture: prove suppressions work here
    return wall_s + moved_bytes
