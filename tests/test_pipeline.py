"""Pipeline invariant suite: the multi-stage layer must conserve rows
and bytes through arbitrary shuffles, replay bit-identically under the
same seed, and — with one stage — collapse EXACTLY to a bare
`MultiQuerySimulator` run, so the legacy rtol-1e-9 equivalence chain
extends through the new layer.

The invariants are pinned twice: a deterministic parametrized grid that
ALWAYS runs in tier-1, and a hypothesis fuzz layer over the same
checkers that widens the input space when the optional dev dependency
is installed (see requirements-dev.txt)."""

import numpy as np
import pytest

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dependency
    hypothesis = None

from repro.core.types import DySkewConfig, Policy, SkewModelKind
from repro.sim.engine import (
    Batch,
    ClusterConfig,
    MultiQuerySimulator,
    StrategyConfig,
    TenantQuery,
)
from repro.sim.pipeline import (
    PipelineInput,
    PipelineSimulator,
    StageSpec,
    hash_partition,
    override_strategy,
    zipf_keys,
)
from repro.sim.replay import (
    amplification_ratios,
    imbalance_coefficient,
    summarize_pipeline,
)
from repro.sim.workload import pipeline_suite

CLUSTER = ClusterConfig(num_nodes=2, interpreters_per_node=4)

KINDS = ["none", "static_rr", "dyskew", "p2c"]


def _fanout_mod(keys, rng):
    return keys % 3


def _fanout_rand(keys, rng):
    return rng.integers(0, 4, len(keys))


FANOUTS = [None, _fanout_mod, _fanout_rand]


def _stages(shuffles, fanout=None, kind=None):
    specs = []
    for i, sh in enumerate(shuffles):
        specs.append(StageSpec(
            name=f"s{i}", shuffle=sh, mean_row_cost=2e-4,
            fanout_fn=fanout, batch_rows=64,
        ))
    return override_strategy(specs, kind) if kind else specs


def _inputs(n_rows, alpha=1.2):
    return [
        PipelineInput(name="a", n_rows=n_rows, num_keys=64, zipf_alpha=alpha),
        PipelineInput(name="b", n_rows=max(n_rows // 2, 8), num_keys=32,
                      zipf_alpha=0.0, partition="rr"),
    ]


# ------------------------------------------------------------------ #
# Invariant checkers — shared by the parametrized grid and the fuzz
# layer so both exercise identical logic.
# ------------------------------------------------------------------ #


def check_row_conservation(shuffles, fanout, kind, seed):
    """Every stage must process EXACTLY the rows the previous stage's
    fanout emitted — none lost in a shuffle, none duplicated."""
    sim = PipelineSimulator(
        CLUSTER, _stages(shuffles, fanout=fanout, kind=kind), seed=seed
    )
    inputs = _inputs(120)
    res = sim.run(inputs)
    assert res.stages[0].rows_in == [i.n_rows for i in inputs]
    # Replay the fanout draws independently: stage k+1's row count must
    # equal the sum of stage k's per-row fanout.
    rows = sim.initial_rows(inputs)
    for k, stage in enumerate(sim.stages):
        assert res.stages[k].rows_in == [len(rs.keys) for rs in rows]
        for ti, rs in enumerate(rows):
            rng = sim._rng(k, ti, lane=2)
            fan = stage.fanout(rs.keys, rng)
            rs.keys = stage.transform_keys(np.repeat(rs.keys, fan), rng)
            rs.producers = np.zeros(len(rs.keys), np.int64)
    assert res.rows_out == [len(rs.keys) for rs in rows]


def check_byte_conservation(kind, seed):
    """The bytes a stage offers the engine are exactly the sizes its
    size model assigned — batching/stream-splitting loses nothing."""
    sim = PipelineSimulator(
        CLUSTER, _stages(["hash", "worker"], kind=kind), seed=seed
    )
    inputs = _inputs(100)
    res = sim.run(inputs)
    rows = sim.initial_rows(inputs)
    for k, stage in enumerate(sim.stages):
        tenants = sim.stage_tenants(k, rows, inputs)
        for ti, t in enumerate(tenants):
            rng = sim._rng(k, ti, lane=1)
            stage.costs(rows[ti].keys, rng)  # advance the cost draw
            expect = float(stage.sizes(rows[ti].keys, rng).sum())
            got = sum(float(b.sizes.sum()) for s in t.streams for b in s)
            assert got == pytest.approx(expect, rel=1e-12)
            assert res.stages[k].bytes_in[ti] == pytest.approx(
                expect, rel=1e-12
            )
        for ti, rs in enumerate(rows):
            rng = sim._rng(k, ti, lane=2)
            fan = stage.fanout(rs.keys, rng)
            rs.keys = stage.transform_keys(np.repeat(rs.keys, fan), rng)
            rs.producers = np.zeros(len(rs.keys), np.int64)


def check_same_seed_bit_identity(kind, seed):
    stages = _stages(["hash", "worker"], fanout=_fanout_rand, kind=kind)
    inputs = _inputs(100)
    r1 = PipelineSimulator(CLUSTER, stages, seed=seed).run(inputs)
    r2 = PipelineSimulator(CLUSTER, stages, seed=seed).run(inputs)
    assert r1.makespan == r2.makespan
    assert r1.rows_out == r2.rows_out
    for s1, s2 in zip(r1.stages, r2.stages):
        assert s1.completions == s2.completions
        assert np.array_equal(
            s1.input_rows_per_worker, s2.input_rows_per_worker
        )
        assert np.array_equal(s1.busy_per_worker, s2.busy_per_worker)
        for q1, q2 in zip(s1.results, s2.results):
            assert q1.latency == q2.latency
            assert q1.bytes_moved_remote == q2.bytes_moved_remote


def check_one_stage_equals_bare_engine(kind, seed, alpha):
    """A 1-stage pipeline IS a bare engine run: same tenants, same seed
    → bit-identical results, traced or not.  This is the joint that
    welds the pipeline layer onto the legacy rtol-1e-9 chain."""
    stages = _stages(["hash"], kind=kind)
    inputs = _inputs(150, alpha=alpha)
    sim = PipelineSimulator(CLUSTER, stages, seed=seed)
    res = sim.run(inputs)
    # Rebuild the exact stage-0 tenants and run them on a bare, UNTRACED
    # engine (ids lanes stripped): every float must match bit for bit.
    tenants = sim.stage_tenants(0, sim.initial_rows(inputs), inputs)
    for t in tenants:
        for s in t.streams:
            for i, b in enumerate(s):
                s[i] = Batch(costs=b.costs, sizes=b.sizes)
    bare = MultiQuerySimulator(CLUSTER, seed=sim.stage_seed(0)).run(tenants)
    assert len(bare) == len(res.stages[0].results)
    for qb, qp in zip(bare, res.stages[0].results):
        assert qb.latency == qp.latency
        assert qb.utilization == qp.utilization
        assert qb.bytes_moved_remote == qp.bytes_moved_remote
        assert qb.rows_redistributed == qp.rows_redistributed
        assert np.array_equal(qb.per_worker_busy, qp.per_worker_busy)


# ------------------------------------------------------------------ #
# Always-on parametrized grid (tier-1)
# ------------------------------------------------------------------ #


class TestPartitioning:
    @pytest.mark.parametrize("n", [1, 7, 64])
    def test_hash_partition_in_range_and_deterministic(self, n):
        keys = np.random.default_rng(3).integers(0, 10_000, 500)
        d1 = hash_partition(keys, n)
        assert np.array_equal(d1, hash_partition(keys, n))
        assert d1.min() >= 0 and d1.max() < n

    @pytest.mark.parametrize("alpha", [0.0, 1.1, 2.0])
    def test_zipf_keys_in_range(self, alpha):
        keys = zipf_keys(200, 16, alpha, np.random.default_rng(5))
        assert len(keys) == 200
        assert keys.min() >= 0 and keys.max() < 16

    def test_zipf_skews_with_alpha(self):
        rng = np.random.default_rng(0)
        flat = np.bincount(zipf_keys(5000, 16, 0.0, rng), minlength=16)
        rng = np.random.default_rng(0)
        skew = np.bincount(zipf_keys(5000, 16, 1.5, rng), minlength=16)
        assert skew.max() > 2 * flat.max()


class TestConservation:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("fanout", FANOUTS)
    def test_row_conservation(self, kind, fanout):
        check_row_conservation(["hash", "worker"], fanout, kind, seed=7)

    @pytest.mark.parametrize("shuffles", [["hash"], ["worker", "hash", "worker"]])
    def test_row_conservation_depths(self, shuffles):
        check_row_conservation(shuffles, _fanout_rand, "dyskew", seed=11)

    @pytest.mark.parametrize("kind", KINDS)
    def test_byte_conservation(self, kind):
        check_byte_conservation(kind, seed=13)

    def test_empty_tenant_flows_through(self):
        """A tenant whose fanout kills every row mid-pipeline must still
        produce stage reports (zero rows) instead of crashing."""
        stages = [
            StageSpec(name="kill", shuffle="hash", batch_rows=32,
                      fanout_fn=lambda k, rng: np.zeros(len(k), np.int64)),
            StageSpec(name="after", batch_rows=32),
        ]
        res = PipelineSimulator(CLUSTER, stages, seed=3).run(
            [PipelineInput(name="t", n_rows=64, num_keys=8)]
        )
        assert res.stages[1].rows_in == [0]
        assert res.rows_out == [0]


class TestDeterminism:
    @pytest.mark.parametrize("kind", KINDS)
    def test_same_seed_bit_identity(self, kind):
        check_same_seed_bit_identity(kind, seed=17)

    def test_cross_seed_divergence(self):
        stages = _stages(["hash"], fanout=_fanout_rand)
        inputs = _inputs(200)
        r1 = PipelineSimulator(CLUSTER, stages, seed=1).run(inputs)
        r2 = PipelineSimulator(CLUSTER, stages, seed=2).run(inputs)
        assert r1.makespan != r2.makespan


class TestDifferentialPin:
    @pytest.mark.parametrize("kind", KINDS)
    def test_one_stage_equals_bare_engine(self, kind):
        check_one_stage_equals_bare_engine(kind, seed=23, alpha=1.2)

    def test_trace_does_not_perturb_run(self):
        """trace_placement=True vs False on identical tenants: results
        bit-identical (tracing is purely observational)."""
        n = CLUSTER.num_workers

        def tenants():
            rng = np.random.default_rng(4)
            out = []
            for q in range(2):
                streams, base = [], 0
                for p in range(n):
                    m = 20 + 10 * (p == 0)
                    costs = rng.lognormal(np.log(3e-4), 0.4, m)
                    streams.append([Batch(
                        costs=costs.copy(),
                        sizes=np.full(m, 1024.0),
                        ids=np.arange(base, base + m, dtype=np.int64),
                    )])
                    base += m
                out.append(TenantQuery(
                    name=f"q{q}", streams=streams,
                    strategy=StrategyConfig(kind="dyskew"),
                ))
            return out

        traced_sim = MultiQuerySimulator(CLUSTER, trace_placement=True, seed=9)
        traced = traced_sim.run(tenants())
        plain = MultiQuerySimulator(CLUSTER, seed=9).run(tenants())
        for a, b in zip(traced, plain):
            assert a.latency == b.latency
            assert np.array_equal(a.per_worker_busy, b.per_worker_busy)
        # And the trace itself is complete: every row placed.
        for tr in traced_sim.last_placement:
            assert tr is not None and (tr >= 0).all()

    @pytest.mark.parametrize("force_loop", [False, True])
    def test_none_strategy_placement_is_producer(self, force_loop):
        """'none' never moves rows, so the traced placement must equal
        each row's producer — through the closed-form fast path AND the
        event loop."""
        n = CLUSTER.num_workers
        streams, base = [], 0
        for p in range(n):
            m = 8 + p
            streams.append([Batch(
                costs=np.full(m, 2e-4), sizes=np.full(m, 64.0),
                ids=np.arange(base, base + m, dtype=np.int64),
            )])
            base += m
        t = TenantQuery(name="t", streams=streams,
                        strategy=StrategyConfig(kind="none"))
        sim = MultiQuerySimulator(
            CLUSTER, trace_placement=True,
            none_closed_form=False if force_loop else None,
        )
        sim.run([t])
        place = sim.last_placement[0]
        expect = np.concatenate([
            np.full(8 + p, p, np.int64) for p in range(n)
        ])
        assert np.array_equal(place, expect)


class TestSuiteAndMetrics:
    def test_pipeline_suite_shapes(self):
        suite = pipeline_suite(quick=True)
        names = [name for name, _, _ in suite]
        assert names == ["fanout_explode", "groupby_attenuate",
                         "collision_chain", "etl_chain"]
        for _, stages, inputs in suite:
            assert 2 <= len(stages) <= 5
            assert inputs
            # quick mode shrinks but keeps every scenario runnable
            assert all(i.n_rows >= 256 for i in inputs)

    def test_imbalance_coefficient(self):
        assert imbalance_coefficient([4, 4, 4, 4]) == 1.0
        assert imbalance_coefficient([8, 0, 0, 0]) == 4.0
        assert np.isnan(imbalance_coefficient([]))
        assert np.isnan(imbalance_coefficient([0.0, 0.0]))

    def test_amplification_ratios(self):
        assert amplification_ratios([1.0, 2.0, 1.0]) == [2.0, 0.5]
        assert np.isnan(amplification_ratios([float("nan"), 2.0])[0])

    def test_summarize_pipeline(self):
        name, stages, inputs = pipeline_suite(quick=True)[2]
        assert name == "collision_chain"
        res = PipelineSimulator(
            ClusterConfig(num_nodes=2), stages, seed=5
        ).run(inputs)
        s = summarize_pipeline(res)
        assert s["stages"] == [sp.name for sp in stages]
        assert len(s["input_imbalance"]) == len(stages)
        assert len(s["amplification"]) == len(stages) - 1
        assert s["makespan"] > 0
        # one tenant: end-to-end makespan == sum of stage makespans
        assert s["makespan"] == pytest.approx(s["stage_makespan_sum"])
        # the collision chain must actually amplify skew mid-pipeline
        assert max(s["amplification"]) > 1.5

    def test_makespan_vs_stage_sum_with_overlapping_tenants(self):
        """With tenants at different completion times, later stages
        start at per-tenant barriers — end-to-end makespan is at most
        the per-stage sum (stages of DIFFERENT tenants overlap)."""
        stages = _stages(["hash", "worker", "hash"])
        inputs = _inputs(150) + [
            PipelineInput(name="late", n_rows=64, num_keys=8, arrival=0.05),
        ]
        res = PipelineSimulator(CLUSTER, stages, seed=11).run(inputs)
        assert res.makespan <= res.stage_makespan_sum + 1e-12


class TestValidation:
    def test_bad_shuffle_rejected(self):
        with pytest.raises(ValueError, match="shuffle"):
            StageSpec(name="x", shuffle="broadcast")

    def test_bad_partition_rejected(self):
        with pytest.raises(ValueError, match="partition"):
            PipelineInput(name="x", partition="range")

    def test_empty_pipeline_rejected(self):
        with pytest.raises(ValueError, match="at least one stage"):
            PipelineSimulator(CLUSTER, [])

    def test_empty_inputs_rejected(self):
        with pytest.raises(ValueError, match="at least one input"):
            PipelineSimulator(CLUSTER, _stages(["hash"])).run([])

    def test_negative_fanout_rejected(self):
        stages = [StageSpec(
            name="bad", shuffle="hash",
            fanout_fn=lambda k, rng: np.full(len(k), -1),
        ), StageSpec(name="sink")]
        with pytest.raises(ValueError, match="fanout_fn"):
            PipelineSimulator(CLUSTER, stages, seed=0).run(
                [PipelineInput(name="t", n_rows=32, num_keys=4)]
            )

    def test_override_strategy_preserves_knobs(self):
        spec = StageSpec(name="s")
        out = override_strategy([spec], "static_rr")
        assert out[0].strategy.kind == "static_rr"
        assert out[0].strategy.tick_interval == spec.strategy.tick_interval
        # and the dyskew detection config rides along untouched
        assert out[0].strategy.dyskew == spec.strategy.dyskew


# ------------------------------------------------------------------ #
# Hypothesis fuzz layer (optional dev dependency, same checkers)
# ------------------------------------------------------------------ #

if hypothesis is not None:
    # Keep runs fast on 1 CPU.
    FUZZ = settings(max_examples=10, deadline=None)
    KIND_ST = st.sampled_from(KINDS)

    class TestFuzzInvariants:
        @FUZZ
        @given(
            shuffles=st.lists(st.sampled_from(["hash", "worker"]),
                              min_size=1, max_size=3),
            fanout=st.sampled_from(FANOUTS),
            kind=KIND_ST,
            seed=st.integers(0, 50),
        )
        def test_row_conservation(self, shuffles, fanout, kind, seed):
            check_row_conservation(shuffles, fanout, kind, seed)

        @FUZZ
        @given(kind=KIND_ST, seed=st.integers(0, 50))
        def test_byte_conservation(self, kind, seed):
            check_byte_conservation(kind, seed)

        @FUZZ
        @given(kind=KIND_ST, seed=st.integers(0, 50))
        def test_same_seed_bit_identity(self, kind, seed):
            check_same_seed_bit_identity(kind, seed)

        @FUZZ
        @given(kind=KIND_ST, seed=st.integers(0, 50),
               alpha=st.floats(0.0, 1.6))
        def test_one_stage_equals_bare_engine(self, kind, seed, alpha):
            check_one_stage_equals_bare_engine(kind, seed, alpha)
