"""Property-based tests (hypothesis) for system invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st

from repro.core import skew_models, state_machine
from repro.core.types import DySkewConfig, LinkState, Policy, link_state_init
from repro.kernels.topk_gating.ref import topk_gating_ref
from repro.optim.grad_compress import dequantize_int8, quantize_int8
from repro.roofline.analysis import shape_bytes
from repro.sim.engine import waterfill_counts, waterfill_counts_many

# Keep runs fast on 1 CPU.
FAST = settings(max_examples=25, deadline=None)


class TestStateMachineInvariants:
    @FAST
    @given(
        policy=st.sampled_from(list(Policy)),
        n=st.integers(2, 8),
        ticks=st.integers(1, 12),
        seed=st.integers(0, 1000),
    )
    def test_states_always_valid_and_terminals_absorb(self, policy, n, ticks, seed):
        cfg = DySkewConfig(policy=policy, n_strikes=2)
        link = link_state_init(n, cfg)
        rng = np.random.default_rng(seed)
        was_terminal = np.zeros(n, bool)
        prev_state = np.asarray(link["state"])
        for _ in range(ticks):
            rows = jnp.asarray(rng.exponential(10, n).astype(np.float32))
            link, dist = state_machine.tick(
                link, cfg,
                rows_this_tick=rows,
                sync_time_this_tick=rows,
                batch_density=rows,
                bytes_per_row=jnp.full((n,), 8.0),
            )
            s = np.asarray(link["state"])
            assert ((0 <= s) & (s < 6)).all()
            # non-looping: terminal states absorb
            terminal = (s == int(LinkState.LOCAL_TERMINAL)) | (
                s == int(LinkState.DISTRIBUTED_TERMINAL)
            )
            assert (terminal | ~was_terminal).all() or (
                s[was_terminal] == prev_state[was_terminal]
            ).all()
            was_terminal |= terminal
            prev_state = s
            # distribute mask only from remote-routing states
            d = np.asarray(dist)
            routing = (s == int(LinkState.DISTRIBUTING)) | (
                s == int(LinkState.DISTRIBUTED_TERMINAL)
            )
            assert (d == routing).all()

    @FAST
    @given(n=st.integers(2, 6), strikes_needed=st.integers(1, 5))
    def test_n_strikes_never_fires_early(self, n, strikes_needed):
        strikes = jnp.zeros((n,), jnp.int32)
        skewed = jnp.ones((n,), bool)
        for i in range(strikes_needed):
            fire, strikes = skew_models.apply_n_strikes(
                skewed, strikes, strikes_needed
            )
            if i < strikes_needed - 1:
                assert not bool(fire.any())
        assert bool(fire.all())


class TestRedistributionInvariants:
    @FAST
    @given(
        n=st.integers(1, 32),
        k=st.integers(0, 500),
        seed=st.integers(0, 999),
    )
    def test_waterfill_conserves_items(self, n, k, seed):
        rng = np.random.default_rng(seed)
        bl = rng.exponential(5.0, n)
        counts = waterfill_counts(bl, k, 0.5)
        assert counts.sum() == k
        assert (counts >= 0).all()

    @FAST
    @given(k=st.integers(1, 200), seed=st.integers(0, 999))
    def test_waterfill_levels_within_one_unit(self, k, seed):
        rng = np.random.default_rng(seed)
        n = 8
        bl = np.zeros(n)
        counts = waterfill_counts(bl, k, 1.0)
        assert counts.max() - counts.min() <= 1

    @FAST
    @given(
        batch=st.integers(1, 6),
        n=st.integers(1, 24),
        seed=st.integers(0, 9999),
    )
    def test_waterfill_many_matches_scalar_row_for_row(self, batch, n, seed):
        """`waterfill_counts_many` must be BIT-identical per row to the
        scalar `waterfill_counts` — the engine's coalesced routing path
        relies on it — including +inf backlogs (self-skip destination
        masks), all-inf rows, k=0 rows, tied backlogs (repair
        tie-breaking) and tiny units."""
        rng = np.random.default_rng(seed)
        bls, ks, units = [], [], []
        for _ in range(batch):
            bl = rng.exponential(5.0, n)
            inf_frac = rng.choice([0.0, 0.3, 1.0], p=[0.5, 0.4, 0.1])
            bl[rng.random(n) < inf_frac] = np.inf
            if n > 2 and rng.random() < 0.5:
                bl[: n // 2] = bl[0]  # ties exercise repair ordering
            bls.append(bl)
            ks.append(int(rng.integers(0, 300)))
            units.append(float(rng.choice([1.0, 0.25, 1e-3, 1e-9])))
        got = waterfill_counts_many(
            np.stack(bls), np.asarray(ks), np.asarray(units)
        )
        for b in range(batch):
            np.testing.assert_array_equal(
                got[b], waterfill_counts(bls[b], ks[b], units[b]),
                err_msg=f"row {b} diverged from scalar waterfill",
            )


class TestQuantizationInvariants:
    @FAST
    @given(seed=st.integers(0, 999), n=st.integers(1, 2048))
    def test_int8_roundtrip_error_bound(self, seed, n):
        rng = np.random.default_rng(seed)
        g = jnp.asarray(rng.normal(0, 3, n).astype(np.float32))
        q, s = quantize_int8(g)
        err = np.abs(np.asarray(dequantize_int8(q, s) - g))
        assert err.max() <= float(s) * 0.5 + 1e-7
        assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127


class TestGatingInvariants:
    @FAST
    @given(
        t=st.integers(1, 64),
        e=st.integers(2, 64),
        seed=st.integers(0, 99),
    )
    def test_topk_weights_normalized_and_indices_unique(self, t, e, seed):
        import jax

        k = min(4, e)
        logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
        w, idx = topk_gating_ref(logits, k)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
        idx = np.asarray(idx)
        for row in idx:
            assert len(set(row.tolist())) == k  # no duplicate experts


class TestHloParserInvariants:
    @FAST
    @given(
        dims=st.lists(st.integers(1, 512), min_size=0, max_size=4),
        dtype=st.sampled_from(["f32", "bf16", "s8", "pred", "s32"]),
    )
    def test_shape_bytes_matches_numpy(self, dims, dtype):
        import numpy as np

        sizes = {"f32": 4, "bf16": 2, "s8": 1, "pred": 1, "s32": 4}
        expect = int(np.prod(dims)) * sizes[dtype] if dims else sizes[dtype]
        got = shape_bytes(dtype, ",".join(map(str, dims)))
        assert got == expect
