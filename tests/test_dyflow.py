"""Tests for dyflow — the whole-program layer of ``tools/lint``.

Three surfaces:

  * the interprocedural call-graph builder (``tools/lint/graph.py``):
    direct calls, cycles, decorated functions, method dispatch,
    registry dispatch, and the soundness guarantee that an
    unresolvable dynamic call degrades to an UNKNOWN edge, never a
    silent drop;
  * the DY5xx units pass: positives (the deliberately broken fixture
    ``tests/lint_fixtures/unit_broken.py``), negatives (conversion by
    the exact literal), and suppressions;
  * the DY6xx pin-impact pass: the committed ``pin_map.json`` matches
    the graph (staleness is a lint failure), every pin root resolves,
    pin-reachable modules are acknowledged, and policies never write
    through their PolicyContext views.

Like test_dyslint.py this runs on a bare Python — no repro import.
"""

import json
import os
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, ROOT)

from tools.lint import Module  # noqa: E402
from tools.lint import runner  # noqa: E402
from tools.lint.graph import (  # noqa: E402
    MODULE_NODE,
    UNKNOWN,
    ModuleCache,
    Program,
    node_id,
)
from tools.lint.passes import pin_impact, units  # noqa: E402

CONTRACTS = runner.load_contracts()


# --------------------------------------------------------------------- #
# Helpers: build a Program from in-memory sources
# --------------------------------------------------------------------- #

class _FakeCache(ModuleCache):
    """ModuleCache over a dict of repo-relative path -> source."""

    def __init__(self, sources):
        super().__init__(ROOT)
        self._sources = dict(sources)

    def get(self, relpath):
        mod = self._mods.get(relpath)
        if mod is None:
            mod = Module.from_source(
                relpath, textwrap.dedent(self._sources[relpath])
            )
            self._mods[relpath] = mod
        return mod


def _program(sources):
    cache = _FakeCache(sources)
    return Program.build(
        ROOT, CONTRACTS, cache, paths=list(sources)
    )


REAL_PROGRAM = Program.build(ROOT, CONTRACTS, ModuleCache(ROOT))


# --------------------------------------------------------------------- #
# Call graph
# --------------------------------------------------------------------- #

class TestCallGraph:
    def test_direct_call_and_cycle(self):
        prog = _program({"src/repro/a.py": """
            def f():
                return g()

            def g():
                return f()
        """})
        f = node_id("src/repro/a.py", "f")
        g = node_id("src/repro/a.py", "g")
        assert g in prog.edges[f] and f in prog.edges[g]
        # the closure over a cycle terminates and contains both
        assert prog.closure([f]) == {f, g}

    def test_cross_module_import_dispatch(self):
        prog = _program({
            "src/repro/m1.py": """
                from repro.m2 import helper

                def top():
                    return helper(1)
            """,
            "src/repro/m2.py": """
                def helper(x):
                    return x
            """,
        })
        assert node_id("src/repro/m2.py", "helper") in prog.edges[
            node_id("src/repro/m1.py", "top")
        ]

    def test_decorated_function_keeps_its_edges(self):
        prog = _program({"src/repro/d.py": """
            def deco(fn):
                return fn

            @deco
            def work():
                return leaf()

            def leaf():
                return 1
        """})
        work = node_id("src/repro/d.py", "work")
        assert node_id("src/repro/d.py", "leaf") in prog.edges[work]
        # the decorator application references both deco and work
        mod = node_id("src/repro/d.py", MODULE_NODE)
        assert node_id("src/repro/d.py", "deco") in prog.edges[mod]

    def test_method_dispatch_through_annotation_fans_out(self):
        prog = _program({"src/repro/c.py": """
            class Base:
                def hit(self):
                    return 0

            class Child(Base):
                def hit(self):
                    return 1

            def drive(b: Base):
                return b.hit()
        """})
        drive = prog.edges[node_id("src/repro/c.py", "drive")]
        assert node_id("src/repro/c.py", "Base.hit") in drive
        assert node_id("src/repro/c.py", "Child.hit") in drive

    def test_nested_def_closure_env_and_funcref(self):
        prog = _program({"src/repro/n.py": """
            class Widget:
                def spin(self):
                    return 7

            def run(w: Widget):
                def inner():
                    return w.spin()
                return inner()
        """})
        run = node_id("src/repro/n.py", "run")
        inner = node_id("src/repro/n.py", "run.inner")
        assert inner in prog.edges[run]
        # the nested def sees the enclosing annotated param
        assert node_id("src/repro/n.py", "Widget.spin") in \
            prog.edges[inner]

    def test_unresolvable_dynamic_call_degrades_to_unknown(self):
        prog = _program({"src/repro/u.py": """
            def top(table):
                return table["k"]()
        """})
        top = node_id("src/repro/u.py", "top")
        # sound degradation: an UNKNOWN edge, never a silent drop
        assert UNKNOWN in prog.edges[top]
        assert UNKNOWN in prog.closure([top])

    def test_external_library_calls_are_not_unknown(self):
        prog = _program({"src/repro/x.py": """
            import numpy as np

            def top(v):
                return np.sum(v)
        """})
        assert prog.edges[node_id("src/repro/x.py", "top")] == set()

    def test_registry_dispatch_fans_out_to_all_policies(self):
        # the real tree: engine.run routes through policies built by a
        # nested annotated factory; the registry fan-out must reach
        # every registered policy's route/propose
        run = "src/repro/sim/engine.py::MultiQuerySimulator.run"
        closure = REAL_PROGRAM.closure([run])
        for method in (
            "RedistributionPolicy.route",
            "DySkewPolicy.propose",
            "StaticRRPolicy.route",
            "HillClimbPolicy.propose",
        ):
            assert f"src/repro/core/policy.py::{method}" in closure, method

    def test_real_tree_has_no_syntax_breakage(self):
        assert REAL_PROGRAM.broken == {}
        assert len(REAL_PROGRAM.functions) > 300


# --------------------------------------------------------------------- #
# DY5xx units
# --------------------------------------------------------------------- #

FIXTURE = "tests/lint_fixtures/unit_broken.py"


def _lint_fixture():
    active, suppressed = [], []
    checker = units._UnitChecker(REAL_PROGRAM, CONTRACTS)
    with open(os.path.join(ROOT, FIXTURE), encoding="utf-8") as fh:
        text = fh.read()
    mod = Module.from_source(FIXTURE, text)
    checker.check_module(FIXTURE, mod)
    from tools.lint import split_suppressed
    return split_suppressed(checker.findings, mod.lines)


class TestUnitsPass:
    def test_fixture_flags_every_planted_violation(self):
        active, suppressed = _lint_fixture()
        codes = sorted(f.code for f in active)
        assert codes == [
            "DY501", "DY502", "DY502", "DY503", "DY503",
            "DY504", "DY504", "DY504",
        ]

    def test_fixture_suppression_is_honored(self):
        active, suppressed = _lint_fixture()
        assert [f.code for f in suppressed] == ["DY501"]
        assert all(
            f.line != s.line for f in active for s in suppressed
        )

    def test_exact_literal_conversion_is_clean(self):
        active, _ = _lint_fixture()
        # the `ok_gb = ... / float(2 ** 30)` line is NOT flagged
        with open(os.path.join(ROOT, FIXTURE), encoding="utf-8") as fh:
            lines = fh.read().splitlines()
        ok_line = next(
            i for i, l in enumerate(lines, 1) if "ok_gb" in l
        )
        assert all(f.line != ok_line for f in active)

    def test_vocabulary_and_patterns(self):
        u = units.unit_of_name
        assert u("wall_s", CONTRACTS) == ("seconds", 1.0)
        assert u("kv_bytes", CONTRACTS) == ("bytes", 1.0)
        assert u("cap_gb", CONTRACTS) == ("bytes", 2.0 ** 30)
        assert u("deficit_rows", CONTRACTS) == ("rows", 1.0)
        assert u("worker_seconds_spent", CONTRACTS) == \
            ("worker_seconds", 1.0)
        # frac_tokens is a fraction OF tokens, not a token count
        assert u("frac_tokens", CONTRACTS) == ("ratio", 1.0)
        assert u("jain_index", CONTRACTS) == ("ratio", 1.0)
        # a bare suffix with no stem declares nothing
        assert u("s", CONTRACTS) is None
        assert u("plain_name", CONTRACTS) is None

    def test_runner_flags_fixture_when_named_explicitly(self, capsys):
        rc = runner.main([FIXTURE, "--no-baseline"])
        out = capsys.readouterr().out
        assert rc == 1
        assert "DY501" in out and "unit_broken" in out

    def test_directory_sweep_does_not_widen_units_scope(self, capsys):
        # linting the tools/ DIRECTORY must not units-check tools code
        rc = runner.main(["tools", "--no-baseline"])
        out = capsys.readouterr().out
        assert rc == 0, out


# --------------------------------------------------------------------- #
# DY6xx pin impact
# --------------------------------------------------------------------- #

class TestPinImpactPass:
    def test_committed_pin_map_is_fresh(self):
        computed = pin_impact.compute_pin_map(REAL_PROGRAM, CONTRACTS)
        with open(os.path.join(ROOT, CONTRACTS.PIN_MAP_PATH),
                  encoding="utf-8") as fh:
            committed = json.load(fh)
        assert committed == computed, (
            "tools/lint/pin_map.json is stale — regenerate with "
            "`python tools/lint/runner.py --write-pin-map`"
        )

    def test_every_pin_root_resolves(self):
        for pin, spec in CONTRACTS.PINS.items():
            for root in spec["roots"]:
                assert REAL_PROGRAM.resolve_root(root), (pin, root)

    def test_pin_reachable_modules_are_acknowledged(self):
        computed = pin_impact.compute_pin_map(REAL_PROGRAM, CONTRACTS)
        pinned = set(CONTRACTS.PINNED_MODULES)
        for pin, spec in computed["pins"].items():
            missing = [m for m in spec["modules"] if m not in pinned]
            assert not missing, (pin, missing)

    def test_real_tree_is_clean(self):
        findings = pin_impact.run_program(REAL_PROGRAM, CONTRACTS)
        assert findings == []

    def test_stale_map_is_flagged(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            CONTRACTS, "PIN_MAP_PATH",
            os.path.relpath(str(tmp_path / "nope.json"), ROOT),
        )
        findings = pin_impact.run_program(REAL_PROGRAM, CONTRACTS)
        assert [f.code for f in findings] == ["DY601"]
        assert findings[0].path == "src/repro/core/contracts.py"

    def test_unresolvable_root_is_flagged(self, monkeypatch):
        pins = dict(CONTRACTS.PINS)
        pins["ghost"] = {
            "test": "tests/test_ghost.py",
            "roots": ("src/repro/sim/engine.py::Gone.run",),
        }
        monkeypatch.setattr(CONTRACTS, "PINS", pins)
        findings = pin_impact.run_program(REAL_PROGRAM, CONTRACTS)
        codes = [f.code for f in findings]
        assert "DY604" in codes          # the ghost root
        assert "DY601" in codes          # and the map went stale

    def test_policy_ctx_write_is_flagged(self):
        prog = _program({"src/repro/core/policy.py": """
            class RedistributionPolicy:
                def route(self, producer, batch, now):
                    return []

            def register_policy(cls):
                return cls

            @register_policy
            class Sneaky(RedistributionPolicy):
                name = "sneaky"

                def __init__(self, ctx):
                    self.ctx = ctx           # binding the view: legal

                def route(self, producer, batch, now):
                    self.ctx.outstanding()[0] = 0.0
                    self.ctx.workers.append(3)
                    return []
        """})
        findings = []
        pin_impact._check_ownership(prog, CONTRACTS, findings)
        assert sorted(f.code for f in findings) == ["DY603", "DY603"]
        lines = {f.line for f in findings}
        assert len(lines) == 2           # both writes, not __init__

    def test_real_policies_never_write_through_ctx(self):
        findings = []
        pin_impact._check_ownership(REAL_PROGRAM, CONTRACTS, findings)
        assert findings == []

    def test_pin_map_format(self):
        computed = pin_impact.compute_pin_map(REAL_PROGRAM, CONTRACTS)
        assert computed["version"] == pin_impact.PIN_MAP_VERSION
        for pin, spec in computed["pins"].items():
            assert set(spec) == {
                "test", "roots", "functions", "modules",
                "over_approximate",
            }
            assert spec["functions"] == sorted(spec["functions"])
            assert UNKNOWN not in spec["functions"]
            for fn in spec["functions"]:
                mod = fn.split("::")[0]
                assert mod in spec["modules"]
