"""Fair-share multi-tenant admission: planner unit tests, engine
integration (priority ordering, starvation-freedom, Jain's-index bounds
on a synthetic 4-tenant interference scenario), and the open-loop
arrival-process generators."""

import numpy as np
import pytest

from repro.core.admission import FairShareAdmission, FairShareConfig
from repro.sim.engine import ClusterConfig, MultiQuerySimulator, TenantQuery
from repro.sim.replay import (
    dyskew_strategy,
    ideal_latency,
    jain_fairness,
    open_loop_rate,
    open_loop_tenants,
    run_open_loop,
    scan_arrival_gap,
    staggered_tenants,
)
from repro.sim.workload import (
    ArrivalProcess,
    QueryProfile,
    arrival_times,
    generate_query,
    priority_class_suite,
    skew_interference_suite,
)

FS = FairShareConfig(quantum_rows=64.0, heavy_row_bytes=1e6)


class TestFairSharePlanner:
    """Unit tests for the weighted deficit-round-robin planner."""

    def test_rejects_bad_weights(self):
        with pytest.raises(ValueError):
            FairShareAdmission([])
        with pytest.raises(ValueError):
            FairShareAdmission([1.0, 0.0])

    def test_bypass_when_pool_idle(self):
        """Nothing in service → any request admitted (work conservation),
        even one far beyond the tenant's burst allowance."""
        p = FairShareAdmission([1.0, 1.0], FS)
        assert p.try_admit(1, rows=10_000, nbytes=1e12, bytes_per_row=1e9)

    def test_pacing_under_load_and_credit_refill(self):
        """With work in service, an over-share tenant is refused until
        completions deal it credit."""
        cfg = FairShareConfig(quantum_rows=64.0, burst_quanta=4.0)
        p = FairShareAdmission([1.0, 1.0], cfg)
        # cap = burst_quanta * quantum * share = 4 * 64 * 0.5 = 128 rows.
        assert p.try_admit(0, 128, 0.0)          # idle bypass, drains deficit
        assert not p.try_admit(0, 128, 0.0)      # in service now: refused
        assert p.backlogged[0]
        p.on_complete(0, 64)                     # one round → +64 credit
        assert not p.try_admit(0, 128, 0.0)      # 64 < charge and < cap
        p.on_complete(0, 64)                     # deficit reaches cap
        assert p.try_admit(0, 128, 0.0)          # saturated → admissible

    def test_priority_weights_shape_credit(self):
        """Backlogged tenants split each credit round by weight."""
        cfg = FairShareConfig(quantum_rows=100.0, burst_quanta=100.0)
        p = FairShareAdmission([3.0, 1.0], cfg)
        assert p.try_admit(0, 400, 0.0)          # idle bypass
        assert p.try_admit(1, 600, 0.0)          # affordable; leaves cap
        # Oversized asks are refused once below cap: both backlogged now.
        assert not p.try_admit(0, 1e9, 0.0)
        assert not p.try_admit(1, 1e9, 0.0)
        d0, d1 = p.deficit_rows
        p.on_complete(0, 100)
        assert p.deficit_rows[0] - d0 == pytest.approx(75.0)
        assert p.deficit_rows[1] - d1 == pytest.approx(25.0)

    def test_idle_tenants_get_no_credit_when_others_wait(self):
        """Credit is dealt over the backlogged set, so the aggregate
        admission rate tracks the completion rate."""
        p = FairShareAdmission([1.0, 1.0], FS)
        assert p.try_admit(0, 128, 0.0)
        assert not p.try_admit(0, 500, 0.0)      # tenant 0 backlogged
        d1 = p.deficit_rows[1]                   # tenant 1 idle
        p.on_complete(0, 64)
        assert p.deficit_rows[1] == d1           # no credit leaked to idle

    def test_heavy_row_bytes_gates_nic_lane(self):
        """Row Size Model: only heavy-row batches charge byte budget."""
        cfg = FairShareConfig(quantum_rows=1e9, quantum_bytes=100.0,
                              burst_quanta=1.0, heavy_row_bytes=1e6)
        p = FairShareAdmission([1.0, 1.0], cfg)
        assert p.try_admit(0, 1, nbytes=1e9, bytes_per_row=100.0)  # light
        assert p.deficit_bytes[0] == pytest.approx(50.0)  # not charged
        assert p.try_admit(0, 1, nbytes=40.0, bytes_per_row=2e6)  # heavy
        assert p.deficit_bytes[0] == pytest.approx(10.0)  # charged 40

    @pytest.mark.parametrize("weights,want", [((1.0, 1.0), 0.5),
                                              ((3.0, 1.0), 0.75)])
    def test_throughput_converges_to_weights_despite_batch_asymmetry(
        self, weights, want
    ):
        """Demand-matched closed loop: tenant 0 submits 1000-row batches,
        tenant 1 16-row batches, both with unbounded demand, service at a
        fixed rate.  Admitted-row shares must converge to the weights —
        the debt-carrying charge is what prevents the big-batch tenant
        from exceeding its share via the saturation rule."""
        from collections import deque

        p = FairShareAdmission(
            list(weights), FairShareConfig(quantum_rows=64, burst_quanta=4)
        )
        admitted = [0.0, 0.0]
        inflight = deque()
        batch = [1000, 16]
        for _ in range(8000):
            for q in (0, 1):
                while p.try_admit(q, batch[q], 0.0):
                    admitted[q] += batch[q]
                    inflight.append((q, batch[q]))
            served = 0
            while inflight and served < 64:
                q, r = inflight.popleft()
                take = min(r, 64 - served)
                p.on_complete(q, take)
                served += take
                if r > take:
                    inflight.appendleft((q, r - take))
        assert admitted[0] / sum(admitted) == pytest.approx(want, abs=0.05)

    def test_pick_next_token_share_follows_weights(self):
        """DRR pick mode: served cost share converges to the weights."""
        p = FairShareAdmission([3.0, 1.0],
                               FairShareConfig(quantum_rows=16.0))
        served = [0.0, 0.0]
        rng = np.random.default_rng(0)
        costs = rng.uniform(5.0, 20.0, 4000)
        for c in costs:
            q = p.pick_next([float(c), float(c)])
            served[q] += c
        assert served[0] / sum(served) == pytest.approx(0.75, abs=0.05)

    def test_pick_next_skips_missing_items(self):
        p = FairShareAdmission([1.0, 1.0], FS)
        assert p.pick_next([None, 8.0]) == 1
        with pytest.raises(ValueError):
            p.pick_next([None, None])


def _uniform_tenants(cluster, weights, n_rows=1500, seed=10):
    prof = QueryProfile(
        name="t", n_rows=n_rows, mean_row_cost=1.2e-3, cost_sigma=0.8,
        partition_alpha=0.6, hot_fraction=0.1,
    )
    gap = scan_arrival_gap(prof, cluster)
    return [
        TenantQuery(
            f"t{i}", generate_query(prof, cluster.num_workers, seed=seed + i),
            dyskew_strategy(prof), 0.0, gap, weight=w,
        )
        for i, w in enumerate(weights)
    ]


def _total_cost(t: TenantQuery) -> float:
    return sum(float(b.costs.sum()) for s in t.streams for b in s)


class TestFairShareEngine:
    """The admission layer inside the unified multi-tenant event loop."""

    def test_priority_ordering_under_contention(self):
        """A high-weight tenant running the SAME workload as its equal
        neighbours finishes substantially sooner; at equal weights it has
        no such edge."""
        cluster = ClusterConfig(num_nodes=2)
        gold = MultiQuerySimulator(cluster, fair_share=FS).run(
            _uniform_tenants(cluster, (8.0, 1.0, 1.0, 1.0))
        )
        flat = MultiQuerySimulator(cluster, fair_share=FS).run(
            _uniform_tenants(cluster, (1.0, 1.0, 1.0, 1.0))
        )
        others = np.mean([r.latency for r in gold[1:]])
        assert gold[0].latency < 0.8 * others
        assert gold[0].latency < 0.8 * flat[0].latency
        # Equal weights: nobody enjoys a comparable edge.
        flat_lat = [r.latency for r in flat]
        assert min(flat_lat) > 0.85 * max(flat_lat)

    def test_starvation_freedom_every_tenant_completes(self):
        """Even at 100:1 weights every tenant finishes all of its rows
        (work conservation: per-worker busy time equals the tenant's
        total hidden cost)."""
        cluster = ClusterConfig(num_nodes=2)
        tenants = _uniform_tenants(cluster, (100.0, 1.0, 1.0, 1.0))
        results = MultiQuerySimulator(cluster, fair_share=FS).run(tenants)
        assert len(results) == len(tenants)
        for t, r in zip(tenants, results):
            np.testing.assert_allclose(
                r.per_worker_busy.sum(), _total_cost(t), rtol=1e-9
            )
            assert np.isfinite(r.latency) and r.latency > 0

    def test_determinism_with_fair_share(self):
        cluster = ClusterConfig(num_nodes=2)
        r1 = MultiQuerySimulator(cluster, fair_share=FS).run(
            _uniform_tenants(cluster, (4.0, 1.0, 1.0))
        )
        r2 = MultiQuerySimulator(cluster, fair_share=FS).run(
            _uniform_tenants(cluster, (4.0, 1.0, 1.0))
        )
        for a, b in zip(r1, r2):
            assert a.latency == b.latency
            assert a.rows_redistributed == b.rows_redistributed

    def test_jain_bounds_on_interference_scenario(self):
        """Synthetic 4-tenant interference (one skewed aggressor, three
        victims): Jain's index over per-tenant slowdowns stays within its
        mathematical bounds [1/n, 1], and the fair-share run is no less
        fair than the unmanaged one."""
        cluster = ClusterConfig(num_nodes=2)
        profiles = skew_interference_suite(4)

        def run(fair_share):
            ts = staggered_tenants(
                profiles, cluster, dyskew_strategy, seed=0, stagger_frac=0.05
            )
            rs = MultiQuerySimulator(cluster, fair_share=fair_share).run(ts)
            sds = [
                r.latency / max(ideal_latency(t, cluster), 1e-12)
                for t, r in zip(ts, rs)
            ]
            return jain_fairness(sds), rs

        j_nofair, _ = run(None)
        j_fair, rs_fair = run(FS)
        n = len(profiles)
        for j in (j_nofair, j_fair):
            assert 1.0 / n - 1e-9 <= j <= 1.0 + 1e-9
        assert j_fair >= j_nofair - 0.02
        # The victims (everyone but the aggressor) must all have finished.
        for r in rs_fair:
            assert r.latency > 0


class TestOpenLoopWorkload:
    """Open-loop arrival processes + the replay-side aggregation."""

    def test_poisson_rate_and_monotonicity(self):
        t = arrival_times(ArrivalProcess(kind="poisson", rate=4.0), 4000, 1)
        assert np.all(np.diff(t) > 0)
        assert np.diff(t).mean() == pytest.approx(0.25, rel=0.1)

    def test_burst_is_burstier_than_poisson(self):
        """On/off modulation must fatten the inter-arrival distribution:
        squared coefficient of variation > 1 (Poisson's CV² == 1)."""
        bt = arrival_times(ArrivalProcess(kind="burst", rate=2.0), 4000, 1)
        iat = np.diff(bt)
        cv2 = iat.var() / iat.mean() ** 2
        assert cv2 > 1.3

    def test_unknown_process_kind_raises(self):
        with pytest.raises(ValueError):
            arrival_times(ArrivalProcess(kind="weibull"), 10, 0)

    def test_jain_fairness_index_bounds(self):
        assert jain_fairness([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
        assert jain_fairness([1.0, 0.0, 0.0, 0.0]) == pytest.approx(0.25)

    def test_jain_fairness_undefined_inputs_are_nan(self):
        """An empty or all-zero set has no defined fairness: NaN (meaning
        'no completions to share'), not a crash and not a fake 1.0."""
        assert np.isnan(jain_fairness([]))
        assert np.isnan(jain_fairness([0.0, 0.0]))

    def test_summarize_open_loop_handles_missing_completions(self):
        """A priority class whose every query failed to complete (result
        None) reports n=0 with NaN percentiles instead of crashing on an
        empty-percentile / 0-division."""
        from repro.sim.replay import summarize_open_loop
        from repro.sim.workload import generate_query

        cluster = ClusterConfig(num_nodes=2)
        specs = priority_class_suite()
        tenants = open_loop_tenants(
            specs, cluster, dyskew_strategy,
            ArrivalProcess(kind="poisson", rate=5.0), 4, seed=0,
        )
        # Nothing completed at all.
        out = summarize_open_loop(tenants, [None] * len(tenants), cluster)
        assert np.isnan(out["jain"]) and np.isnan(out["mean_latency"])
        for stats in out["per_class"].values():
            assert stats["n"] == 0
            assert np.isnan(stats["p50"]) and np.isnan(stats["p999"])
        # One class completed, the other did not: mixed report.
        results = MultiQuerySimulator(cluster).run(tenants)
        mixed = [
            r if t.name.startswith("gold") else None
            for t, r in zip(tenants, results)
        ]
        out2 = summarize_open_loop(tenants, mixed, cluster)
        assert out2["per_class"]["bulk"]["n"] == 0
        assert np.isnan(out2["per_class"]["bulk"]["p50"])
        assert out2["per_class"]["gold"]["n"] > 0
        assert np.isfinite(out2["per_class"]["gold"]["p50"])
        assert np.isfinite(out2["jain"])

    def test_open_loop_tenants_cycle_specs(self):
        cluster = ClusterConfig(num_nodes=2)
        specs = priority_class_suite()
        tenants = open_loop_tenants(
            specs, cluster, dyskew_strategy,
            ArrivalProcess(kind="poisson", rate=5.0), 8, seed=0,
        )
        assert len(tenants) == 8
        arr = [t.arrival for t in tenants]
        assert arr == sorted(arr)
        assert {t.name.split("#")[0] for t in tenants} == {"gold", "bulk"}
        golds = [t for t in tenants if t.name.startswith("gold")]
        assert all(t.weight == 8.0 for t in golds)

    def test_open_loop_run_reports_classes_and_jain(self):
        """End-to-end: the acceptance scenario — a Poisson open-loop
        stream with two priority classes reports per-class p50/p99 and a
        Jain's index, and fair share does not hurt the gold tail."""
        cluster = ClusterConfig(num_nodes=2)
        specs = priority_class_suite()
        # Offered load high enough that queueing (hence fair share)
        # actually matters — the same regime the bench reports.
        proc = ArrivalProcess(
            kind="poisson",
            rate=open_loop_rate([p for p, _ in specs], cluster, load=0.75),
        )
        base = run_open_loop(specs, cluster, proc, 10, seed=0)
        fair = run_open_loop(specs, cluster, proc, 10, seed=0,
                             fair_share=FS)
        for out in (base, fair):
            assert set(out["per_class"]) == {"gold", "bulk"}
            for stats in out["per_class"].values():
                assert stats["p50"] <= stats["p99"] <= stats["p999"]
            assert 0.0 < out["jain"] <= 1.0 + 1e-9
        # Under contention the high-weight class's tail must not regress.
        assert fair["per_class"]["gold"]["p99"] <= (
            1.05 * base["per_class"]["gold"]["p99"]
        )
