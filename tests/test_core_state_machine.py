"""Unit tests for the adaptive-link state machine (paper Fig. 2)."""

import jax.numpy as jnp
import numpy as np

from repro.core import state_machine
from repro.core.types import DySkewConfig, LinkState, Policy, link_state_init


def _tick(link, cfg, rows, sync=None, density=None, bpr=None):
    n = rows.shape[0]
    return state_machine.tick(
        link,
        cfg,
        rows_this_tick=rows,
        sync_time_this_tick=jnp.zeros(n) if sync is None else sync,
        batch_density=rows if density is None else density,
        bytes_per_row=jnp.full((n,), 8.0) if bpr is None else bpr,
    )


def test_never_policy_goes_local_terminal():
    cfg = DySkewConfig(policy=Policy.NEVER)
    link = link_state_init(4, cfg)
    link, dist = _tick(link, cfg, jnp.array([1000.0, 1.0, 1.0, 1.0]))
    assert np.all(np.asarray(link["state"]) == int(LinkState.LOCAL_TERMINAL))
    assert not bool(jnp.any(dist))
    # Terminal: stays put under any further skew.
    for _ in range(5):
        link, dist = _tick(link, cfg, jnp.array([1000.0, 1.0, 1.0, 1.0]))
    assert np.all(np.asarray(link["state"]) == int(LinkState.LOCAL_TERMINAL))
    assert not bool(jnp.any(dist))


def test_late_policy_full_progression():
    """INIT → DECIDING → (N strikes) → DRAINING → DISTRIBUTING → TERMINAL."""
    cfg = DySkewConfig(policy=Policy.LATE, n_strikes=3, theta=0.5)
    link = link_state_init(4, cfg)
    skew_rows = jnp.array([1000.0, 1.0, 1.0, 1.0])

    link, _ = _tick(link, cfg, skew_rows)  # INIT → DECIDING
    assert int(link["state"][0]) == int(LinkState.DECIDING)

    # Strikes accumulate; fires on the 3rd consecutive detection.
    link, _ = _tick(link, cfg, skew_rows)
    assert int(link["state"][0]) == int(LinkState.DECIDING)
    link, _ = _tick(link, cfg, skew_rows)
    assert int(link["state"][0]) == int(LinkState.DECIDING)
    link, dist = _tick(link, cfg, skew_rows)
    assert int(link["state"][0]) == int(LinkState.DRAINING)
    assert not bool(dist[0])  # draining does not yet route remote

    link, dist = _tick(link, cfg, skew_rows)
    assert int(link["state"][0]) == int(LinkState.DISTRIBUTING)
    assert bool(dist[0])

    link, dist = _tick(link, cfg, skew_rows)
    assert int(link["state"][0]) == int(LinkState.DISTRIBUTED_TERMINAL)
    assert bool(dist[0])
    # Siblings stay in DECIDING (they are not skewed).
    assert int(link["state"][1]) == int(LinkState.DECIDING)


def test_late_policy_no_skew_stays_deciding():
    cfg = DySkewConfig(policy=Policy.LATE, n_strikes=3)
    link = link_state_init(4, cfg)
    rows = jnp.full((4,), 100.0)
    for _ in range(10):
        link, dist = _tick(link, cfg, rows)
    assert np.all(np.asarray(link["state"]) == int(LinkState.DECIDING))
    assert not bool(jnp.any(dist))


def test_strike_reset_prevents_transition():
    """Transient skew (interrupted streak) must not trigger redistribution."""
    cfg = DySkewConfig(policy=Policy.LATE, n_strikes=3, theta=0.5)
    link = link_state_init(4, cfg)
    skew = jnp.array([1000.0, 1.0, 1.0, 1.0])
    balanced = jnp.full((4,), 100.0)
    link, _ = _tick(link, cfg, skew)
    for _ in range(20):
        # Alternate: 2 skewed ticks then 1 clean tick → never 3 consecutive.
        link, _ = _tick(link, cfg, skew)
        link, _ = _tick(link, cfg, skew)
        # A balanced tick large enough to clear Eq. (1) on cumulative rows
        # is impossible here (rows are cumulative), so use a fresh link to
        # assert the property directly on strikes instead.
    # After this loop instance 0 has certainly fired (cumulative skew).
    # Property checked separately in skew-model tests; here just ensure the
    # machine is monotone: once DISTRIBUTED_TERMINAL, always so.
    assert int(link["state"][0]) == int(LinkState.DISTRIBUTED_TERMINAL)


def test_early_policy_distributes_immediately():
    cfg = DySkewConfig(policy=Policy.EARLY)
    link = link_state_init(4, cfg)
    link, dist = _tick(link, cfg, jnp.full((4,), 10.0))
    assert np.all(np.asarray(link["state"]) == int(LinkState.DISTRIBUTING))
    assert bool(jnp.all(dist))
    link, dist = _tick(link, cfg, jnp.full((4,), 10.0))
    assert np.all(np.asarray(link["state"]) == int(LinkState.DISTRIBUTED_TERMINAL))


def test_eager_snowpark_heavy_row_fallback():
    """§III.B: eager redistribution disables itself on heavy rows when the
    idle-time model reports no skew."""
    cfg = DySkewConfig(
        policy=Policy.EAGER_SNOWPARK,
        target_batch_density=4096.0,
        min_batch_density_frac=0.01,
        idle_grace=2,
    )
    link = link_state_init(4, cfg)
    # All instances busy with dense batches → stays DISTRIBUTING.
    dense = jnp.full((4,), 4096.0)
    link, dist = _tick(link, cfg, dense, density=dense)
    assert np.all(np.asarray(link["state"]) == int(LinkState.DISTRIBUTING))
    assert bool(jnp.all(dist))
    link, dist = _tick(link, cfg, dense, density=dense)
    assert np.all(np.asarray(link["state"]) == int(LinkState.DISTRIBUTING))

    # Batch density collapses >99% (heavy rows), no idle siblings → disable.
    sparse = jnp.full((4,), 3.0)
    link, dist = _tick(
        link, cfg, sparse, density=sparse, bpr=jnp.full((4,), 100e9 / 3)
    )
    assert np.all(np.asarray(link["state"]) == int(LinkState.LOCAL_TERMINAL))
    assert not bool(jnp.any(dist))


def test_eager_snowpark_keeps_distributing_when_skewed():
    """Heavy rows + actual skew (idle siblings) → keep redistributing."""
    cfg = DySkewConfig(policy=Policy.EAGER_SNOWPARK, idle_grace=1)
    link = link_state_init(4, cfg)
    # Instance 0 receives everything; siblings idle from tick 2 onward.
    rows = jnp.array([3.0, 0.0, 0.0, 0.0])
    link, _ = _tick(link, cfg, rows, density=rows)
    link, _ = _tick(link, cfg, rows, density=rows)
    link, dist = _tick(link, cfg, rows, density=rows)
    # Instance 0 is skewed (busy among idle) → stays DISTRIBUTING even though
    # its density (3 rows/batch) is under the heavy-row threshold.
    assert int(link["state"][0]) == int(LinkState.DISTRIBUTING)
    assert bool(dist[0])


def test_looping_late_returns_to_deciding():
    cfg = DySkewConfig(policy=Policy.LATE, n_strikes=2, theta=0.5, looping=True)
    link = link_state_init(2, cfg)
    skew = jnp.array([100.0, 1.0])
    for _ in range(4):
        link, _ = _tick(link, cfg, skew)
    assert int(link["state"][0]) == int(LinkState.DISTRIBUTING)
    # Clean ticks: rows balanced from now on; cumulative row counts converge
    # so Eq. (1) stops firing, clean-streak sends it back to DECIDING.
    balanced = jnp.array([1.0, 1000.0])
    for _ in range(10):
        link, _ = _tick(link, cfg, balanced)
    assert int(link["state"][0]) == int(LinkState.DECIDING)


def test_transitions_telemetry_counts_commits():
    cfg = DySkewConfig(policy=Policy.EARLY)
    link = link_state_init(4, cfg)
    link, _ = _tick(link, cfg, jnp.full((4,), 1.0))
    assert np.all(np.asarray(link["transitions"]) == 1)
    link, _ = _tick(link, cfg, jnp.full((4,), 1.0))
    assert np.all(np.asarray(link["transitions"]) == 1)  # no double count
