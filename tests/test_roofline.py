"""Roofline machinery tests: jaxpr cost counter, HLO collective parser,
term classification."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (
    RooflineTerms,
    collective_bytes_loop_aware,
    collective_bytes_per_device,
    shape_bytes,
)
from repro.roofline.jaxpr_cost import analyze_jaxpr, trace_cost


class TestJaxprCost:
    def test_matmul_exact(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        c = trace_cost(lambda x, y: x @ y, a, b)
        assert c["flops"] == 2 * 64 * 128 * 32

    def test_scan_multiplies_by_length(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        ws = jax.ShapeDtypeStruct((16, 32, 32), jnp.float32)

        def f(x, ws):
            def body(c, w):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, ws)
            return out

        c = trace_cost(f, x, ws)
        assert c["flops"] == 16 * 2 * 32**3

    def test_nested_scan(self):
        x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, 3, 16, 16), jnp.float32)

        def f(x, ws):
            def outer(c, wrow):
                def inner(ci, w):
                    return ci @ w, None
                c2, _ = jax.lax.scan(inner, c, wrow)
                return c2, None
            out, _ = jax.lax.scan(outer, x, ws)
            return out

        c = trace_cost(f, x, ws)
        assert c["flops"] == 12 * 2 * 16**3

    def test_remat_grad_counts_recompute(self):
        ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def loss(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(jax.checkpoint(body), x, ws)
            return (out ** 2).sum()

        fwd = trace_cost(loss, ws, x)["flops"]
        bwd = trace_cost(jax.grad(loss), ws, x)["flops"]
        # grad-with-remat ≈ fwd + refwd + 2x bwd matmuls ≈ 4x fwd matmuls
        assert 3.0 <= bwd / fwd <= 4.5

    def test_batched_dot_general(self):
        a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
        c = trace_cost(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert c["flops"] == 2 * 4 * 8 * 16 * 8

    def test_bytes_positive(self):
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = trace_cost(lambda a: jnp.tanh(a) + 1.0, x)
        assert c["bytes"] >= 2 * 128 * 128 * 4


class TestCollectiveParser:
    def test_shape_bytes(self):
        assert shape_bytes("bf16", "128,4096") == 128 * 4096 * 2
        assert shape_bytes("f32", "10") == 40
        assert shape_bytes("s8", "100,2") == 200
        assert shape_bytes("pred", "") == 1

    def test_parses_optimized_hlo_line(self):
        hlo = """
HloModule test
ENTRY %main (a: f32[256,256]) -> f32[256,256] {
  %a = f32[256,256]{1,0} parameter(0)
  ROOT %all-reduce = f32[256,256]{1,0} all-reduce(%a), channel_id=1, replica_groups=[1,8]<=[8], to_apply=%add
}
"""
        total, kinds = collective_bytes_per_device(hlo)
        expected = 2 * 256 * 256 * 4 * 7 / 8
        assert total == int(expected)
        assert kinds["all-reduce"] == int(expected)

    def test_loop_aware_multiplies_trip_count(self):
        hlo = """
HloModule test
%body.1 (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar = f32[64]{0} all-reduce(%x), channel_id=1, replica_groups=[1,4]<=[4], to_apply=%add
}
%cond.2 (p: (s32[], f32[64])) -> pred[] {
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[64]) -> f32[64] {
  %w = (s32[], f32[64]) while(%init), condition=%cond.2, body=%body.1
}
"""
        total, kinds = collective_bytes_loop_aware(hlo)
        one = 2 * 64 * 4 * 3 / 4
        assert total == int(10 * one)


class TestRooflineTerms:
    def test_bottleneck_classification(self):
        t = RooflineTerms(chips=256, flops_global=1e18, hbm_bytes_global=1e12,
                          collective_bytes_global=1e12, by_kind={},
                          model_flops=5e17)
        assert t.bottleneck == "compute"
        assert t.useful_flops_ratio == pytest.approx(0.5)

    def test_terms_formulas(self):
        from repro.roofline import hw

        t = RooflineTerms(chips=256, flops_global=256 * hw.PEAK_FLOPS_BF16,
                          hbm_bytes_global=0, collective_bytes_global=0,
                          by_kind={})
        assert t.t_compute == pytest.approx(1.0)
