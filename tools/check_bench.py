"""Schema validator for the numbered ``BENCH_<n>.json`` trajectory.

``benchmarks/run.py`` appends one record per full bench run; downstream
tooling (perf dashboards, regression triage) assumes every record obeys
the schema that writer has produced since PR 3.  This checker makes the
assumption enforceable: each file must carry the required top-level
keys, every non-skipped bench must report its wall time, every ``ok``
bench's rows must be well-formed, and the file numbers must be
contiguous with non-decreasing creation times (a renamed or
hand-deleted record shows up as a hole).  Wired into ``make
check-bench`` and the CI lint job.
"""

from __future__ import annotations

import argparse
import glob
import importlib.util
import json
import math
import os
import re
import sys
from typing import Any, Dict, List, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCHEMA_VERSION = 1
TOP_KEYS = ("schema", "created_unix", "quick", "only", "benches",
            "total_wall_s")
BENCH_STATUSES = ("ok", "failed", "skipped")
ROW_KEYS = ("name", "us_per_call", "derived")

_NAME = re.compile(r"^BENCH_(\d+)\.json$")

_CONTRACTS_PATH = os.path.join(ROOT, "src", "repro", "core",
                               "contracts.py")


def _load_unit_vocabulary(
    path: str = _CONTRACTS_PATH,
) -> Dict[str, str]:
    """Near-miss suffix -> canonical suffix, from the contracts layer
    (loaded standalone like ``tools/lint`` does: stdlib only, no
    ``repro`` import)."""
    spec = importlib.util.spec_from_file_location(
        "_check_bench_contracts", path
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return dict(mod.UNIT_SUFFIX_NEAR_MISSES)


_NEAR_MISSES: Optional[Dict[str, str]] = None


def _check_unit_key(key: Any, where: str, errs: List[str]) -> None:
    """A metric key carrying a unit-LIKE suffix must use the contracts
    vocabulary: a ``p99_sec`` column is a mislabeled ``p99_s`` that
    every downstream consumer will mis-parse."""
    global _NEAR_MISSES
    if not isinstance(key, str) or "_" not in key:
        return
    if _NEAR_MISSES is None:
        _NEAR_MISSES = _load_unit_vocabulary()
    stem, _, suffix = key.lower().rpartition("_")
    canonical = _NEAR_MISSES.get(suffix)
    if canonical is not None:
        errs.append(
            f"{where}: key {key!r} carries non-vocabulary unit suffix "
            f"_{suffix} — use _{canonical} (see contracts.UNIT_SUFFIXES)"
        )


def _is_number(v: Any) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_row(row: Any, where: str, errs: List[str]) -> None:
    if not isinstance(row, dict):
        errs.append(f"{where}: row is {type(row).__name__}, not object")
        return
    for k in ROW_KEYS:
        if k not in row:
            errs.append(f"{where}: row missing key {k!r}")
    if "name" in row and not (isinstance(row["name"], str) and row["name"]):
        errs.append(f"{where}: row name must be a non-empty string")
    elif "name" in row:
        _check_unit_key(row["name"], where, errs)
    # NaN is nulled by the writer, so None is legal alongside numbers.
    if "us_per_call" in row:
        v = row["us_per_call"]
        if v is not None and not _is_number(v):
            errs.append(f"{where}: us_per_call must be number or null, "
                        f"got {type(v).__name__}")
        elif _is_number(v) and (not math.isfinite(v) or v < 0):
            errs.append(f"{where}: us_per_call must be finite and >= 0, "
                        f"got {v!r}")
    if "derived" in row and not isinstance(row["derived"], dict):
        errs.append(f"{where}: derived must be an object, got "
                    f"{type(row['derived']).__name__}")
    elif isinstance(row.get("derived"), dict):
        for k in row["derived"]:
            _check_unit_key(k, f"{where}.derived", errs)


def _check_bench(bench: Any, where: str, errs: List[str]) -> float:
    """Validate one bench entry; returns its wall_s contribution."""
    if not isinstance(bench, dict):
        errs.append(f"{where}: bench is {type(bench).__name__}, not object")
        return 0.0
    suite = bench.get("suite")
    if not (isinstance(suite, str) and suite):
        errs.append(f"{where}: suite must be a non-empty string")
    status = bench.get("status")
    if status not in BENCH_STATUSES:
        errs.append(f"{where}: status {status!r} not in "
                    f"{'/'.join(BENCH_STATUSES)}")
        return 0.0
    if status == "skipped":
        return 0.0
    # Every bench that actually ran — ok or failed — bills wall time.
    wall = bench.get("wall_s")
    if not _is_number(wall) or not math.isfinite(wall) or wall < 0:
        errs.append(f"{where}: ran (status={status}) but wall_s is "
                    f"{wall!r}, want finite number >= 0")
        wall = 0.0
    if status == "ok":
        rows = bench.get("rows")
        if not isinstance(rows, list):
            errs.append(f"{where}: status=ok but rows is "
                        f"{type(rows).__name__}, not list")
        else:
            for i, row in enumerate(rows):
                _check_row(row, f"{where}.rows[{i}]", errs)
    return float(wall)


def validate_record(data: Any, name: str) -> List[str]:
    """All schema problems in one loaded BENCH record."""
    errs: List[str] = []
    if not isinstance(data, dict):
        return [f"{name}: top level is {type(data).__name__}, not object"]
    for k in TOP_KEYS:
        if k not in data:
            errs.append(f"{name}: missing top-level key {k!r}")
    if data.get("schema") != SCHEMA_VERSION:
        errs.append(f"{name}: schema is {data.get('schema')!r}, "
                    f"want {SCHEMA_VERSION}")
    if "created_unix" in data and (
        not _is_number(data["created_unix"]) or data["created_unix"] <= 0
    ):
        errs.append(f"{name}: created_unix must be a positive number")
    if "quick" in data and not isinstance(data["quick"], bool):
        errs.append(f"{name}: quick must be a bool")
    if "only" in data and not isinstance(data["only"], str):
        errs.append(f"{name}: only must be a string")
    # Provenance fields (added after the first records were minted):
    # validated only when PRESENT so old records stay accepted.
    if "seed" in data and (
        not _is_number(data["seed"]) or isinstance(data["seed"], bool)
        or int(data["seed"]) != data["seed"]
    ):
        errs.append(f"{name}: seed must be an integer")
    if "git_sha" in data and (
        not isinstance(data["git_sha"], str) or not data["git_sha"]
    ):
        errs.append(f"{name}: git_sha must be a non-empty string")
    benches = data.get("benches")
    wall_sum = 0.0
    if benches is not None:
        if not isinstance(benches, list) or not benches:
            errs.append(f"{name}: benches must be a non-empty list")
        else:
            for i, b in enumerate(benches):
                wall_sum += _check_bench(b, f"{name}.benches[{i}]", errs)
    total = data.get("total_wall_s")
    if total is not None:
        if not _is_number(total) or not math.isfinite(total) or total < 0:
            errs.append(f"{name}: total_wall_s must be finite and >= 0")
        elif benches and not math.isclose(
            total, wall_sum, rel_tol=1e-6, abs_tol=1e-6
        ):
            errs.append(f"{name}: total_wall_s {total!r} != sum of bench "
                        f"wall_s {wall_sum!r}")
    return errs


def check_files(root: str = ROOT) -> Tuple[List[str], List[str]]:
    """Validate every BENCH_*.json under ``root``.

    Returns (checked file names, problems).  Numbering must be
    contiguous from the smallest surviving number, and creation times
    must not run backwards — either break means a record was renamed,
    dropped, or back-filled by hand.
    """
    numbered: Dict[int, str] = {}
    errs: List[str] = []
    for path in glob.glob(os.path.join(root, "BENCH_*.json")):
        base = os.path.basename(path)
        m = _NAME.match(base)
        if not m:
            errs.append(f"{base}: name does not match BENCH_<n>.json")
            continue
        numbered[int(m.group(1))] = path
    created: Dict[int, float] = {}
    for n in sorted(numbered):
        base = os.path.basename(numbered[n])
        try:
            with open(numbered[n], encoding="utf-8") as fh:
                data = json.load(fh)
        except (OSError, ValueError) as e:
            errs.append(f"{base}: unreadable ({e})")
            continue
        errs.extend(validate_record(data, base))
        if _is_number(data.get("created_unix") if isinstance(data, dict)
                      else None):
            created[n] = float(data["created_unix"])
    if numbered:
        nums = sorted(numbered)
        want = list(range(nums[0], nums[0] + len(nums)))
        if nums != want:
            missing = sorted(set(want) - set(nums))
            errs.append(
                f"BENCH numbering has holes: have {nums}, missing "
                f"{['BENCH_%d.json' % n for n in missing]}"
            )
        ordered = sorted(created)
        for a, b in zip(ordered, ordered[1:]):
            if created[b] < created[a]:
                errs.append(
                    f"BENCH_{b}.json created_unix ({created[b]}) predates "
                    f"BENCH_{a}.json ({created[a]}): records out of order"
                )
    checked = [os.path.basename(numbered[n]) for n in sorted(numbered)]
    return checked, errs


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=ROOT,
                    help="directory holding the BENCH_*.json records")
    args = ap.parse_args(argv)
    checked, errs = check_files(args.root)
    if errs:
        print(f"bench check FAILED ({len(errs)} problems across "
              f"{len(checked)} record(s)):")
        for e in errs:
            print(f"  {e}")
        return 1
    print(f"bench check OK ({len(checked)} record(s): "
          f"{', '.join(checked) or 'none'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
