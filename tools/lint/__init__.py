"""dyslint: the repo's AST-based invariant linter.

Four passes statically enforce the contracts the bit-identity pins
depend on (see ``src/repro/core/contracts.py`` for the contracts as
data, and ``docs/ARCHITECTURE.md`` for the rationale):

  * ``passes/determinism.py`` (DY1xx) — no global-state RNG, wall
    clocks, or environment-order iteration in sim-path code;
  * ``passes/capability.py``  (DY2xx) — a registered policy's declared
    capability flags must match what its method bodies actually do;
  * ``passes/jax_hazard.py``  (DY3xx) — no host syncs, traced-value
    Python branches, or retrace hazards in jit-reachable functions;
  * ``passes/float_order.py`` (DY4xx) — no order-sensitive reductions
    over unordered containers in bit-identity-pinned modules.

This package holds the framework: findings, per-line
``# dyslint: disable=CODE`` suppressions, and the checked-in baseline
of grandfathered findings (``tools/lint/baseline.json``).  The CLI
lives in ``tools/lint/runner.py`` (``make lint``).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint violation, anchored to a source line."""

    code: str            # e.g. "DY202"
    path: str            # repo-relative, posix separators
    line: int            # 1-based
    col: int             # 0-based
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


@dataclasses.dataclass
class Module:
    """A parsed source file handed to each pass."""

    path: str            # repo-relative, posix separators
    text: str
    tree: ast.Module
    lines: List[str] = dataclasses.field(default_factory=list)

    @classmethod
    def from_source(cls, path: str, text: str) -> "Module":
        return cls(
            path=path, text=text, tree=ast.parse(text),
            lines=text.splitlines(),
        )


# --------------------------------------------------------------------- #
# Inline suppressions
# --------------------------------------------------------------------- #

#: ``# dyslint: disable=DY101`` or ``disable=DY101,DY104 -- reason``.
#: A trailing comment suppresses findings anchored on its own line; a
#: comment-ONLY line suppresses the next line (for statements too long
#: to carry the justification inline).
_SUPPRESS = re.compile(
    r"#\s*dyslint:\s*disable=([A-Za-z0-9_,\s]+?)\s*(?:--.*)?$"
)


def suppressions(lines: Sequence[str]) -> Dict[int, Set[str]]:
    """Map 1-based line number -> set of codes suppressed there."""
    out: Dict[int, Set[str]] = {}
    for ln, line in enumerate(lines, 1):
        m = _SUPPRESS.search(line)
        if not m:
            continue
        codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
        if not codes:
            continue
        target = ln + 1 if line.lstrip().startswith("#") else ln
        out.setdefault(target, set()).update(codes)
    return out


def split_suppressed(
    findings: Iterable[Finding], lines: Sequence[str]
) -> Tuple[List[Finding], List[Finding]]:
    """Partition into (active, suppressed) using the file's inline
    ``# dyslint: disable=`` comments."""
    supp = suppressions(lines)
    active: List[Finding] = []
    silenced: List[Finding] = []
    for f in findings:
        if f.code in supp.get(f.line, ()):
            silenced.append(f)
        else:
            active.append(f)
    return active, silenced


# --------------------------------------------------------------------- #
# Baseline (grandfathered findings)
# --------------------------------------------------------------------- #

BASELINE_VERSION = 1


def _baseline_key(f: Finding, lines: Sequence[str]) -> Tuple[str, str, str]:
    """Baseline identity: (code, path, stripped source line).  Keying on
    line CONTENT instead of line NUMBER keeps the baseline stable when
    unrelated edits shift a file."""
    text = ""
    if 1 <= f.line <= len(lines):
        text = lines[f.line - 1].strip()
    return (f.code, f.path, text)


def load_baseline(path: str) -> Dict[Tuple[str, str, str], int]:
    """Baseline file -> multiset of grandfathered finding keys."""
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"{path}: unsupported baseline version {data.get('version')!r}"
        )
    out: Dict[Tuple[str, str, str], int] = {}
    for e in data.get("findings", []):
        key = (e["code"], e["path"], e.get("line_text", ""))
        out[key] = out.get(key, 0) + 1
    return out


def dump_baseline(
    findings: Sequence[Finding], lines_by_path: Dict[str, Sequence[str]]
) -> str:
    """Serialize ``findings`` as a fresh baseline document."""
    entries = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.code)):
        code, path, text = _baseline_key(f, lines_by_path.get(f.path, []))
        entries.append({"code": code, "path": path, "line_text": text})
    return json.dumps(
        {"version": BASELINE_VERSION, "findings": entries},
        indent=2, sort_keys=True,
    ) + "\n"


def split_baselined(
    findings: Iterable[Finding],
    baseline: Dict[Tuple[str, str, str], int],
    lines_by_path: Dict[str, Sequence[str]],
) -> Tuple[List[Finding], List[Finding], int]:
    """Partition into (new, grandfathered); also returns the number of
    STALE baseline entries (grandfathered findings that no longer occur
    — a prompt to re-run ``--update-baseline`` and shrink the file)."""
    budget = dict(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        key = _baseline_key(f, lines_by_path.get(f.path, []))
        if budget.get(key, 0) > 0:
            budget[key] -= 1
            old.append(f)
        else:
            new.append(f)
    stale = sum(v for v in budget.values() if v > 0)
    return new, old, stale
